"""Fig. 13: gains grow with scan size (paper: 4.0x throughput at 24-item
scans; Honeycomb amortizes node fetches across inlined items while the
baseline chases per-item pointers)."""
from __future__ import annotations

from .common import build_stores, emit, run_mixed, uniform_sampler


def run(n_items: int = 4096, n_ops: int = 1024) -> dict:
    results = {}
    hc, cp = build_stores(n_items)
    for items in (1, 3, 8, 24):
        spec = dict(read_frac=1.0, scan_items=items)
        r_h = run_mixed(hc, uniform_sampler(n_items, seed=11), n_ops=n_ops,
                        n_items=n_items, **spec)
        r_c = run_mixed(cp, uniform_sampler(n_items, seed=11), n_ops=n_ops,
                        n_items=n_items, is_honeycomb=False, **spec)
        h, c = r_h["ops_per_s"], r_c["ops_per_s"]
        results[items] = {"honeycomb_ops_s": h, "baseline_ops_s": c,
                          "speedup": h / c}
        emit(f"scan_{items}items", 1e6 / h, f"speedup={h / c:.2f}x")
    return results


if __name__ == "__main__":
    run()
