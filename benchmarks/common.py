"""Shared benchmark machinery: workload generators (YCSB-style), store
builders, timing, and the byte-cost model.

Scale note: this container executes the accelerator path on XLA:CPU, so
absolute ops/s are NOT the paper's Mops/s — what the benchmarks reproduce
is the paper's *relative* structure (read-heavy gains, write-heavy
penalty, every ablation trend) plus the analytic bytes-per-operation model
(which IS hardware-independent and reproduces the 5x bytes claim).
TDP constants for cost-performance come from the paper (Section 6.3):
127 W CPU-only server, +40 W FPGA board -> 157.9 W for Honeycomb.
"""
from __future__ import annotations

import time

import numpy as np

from repro.baselines.cpu_store import CpuOrderedStore
from repro.core import (FeedTopology, Get, HoneycombConfig, HoneycombService,
                        HoneycombStore, Put, ReplicationConfig, Scan,
                        ShardedHoneycombStore, TelemetryConfig,
                        uniform_int_boundaries)
from repro.core.keys import int_key

TDP_BASELINE_W = 127.0
TDP_HONEYCOMB_W = 157.9

KEY_BYTES = 8

# observability wiring for the scheduled sections (core/telemetry.py):
# every run_scheduled service carries a metrics registry whose snapshot is
# attached to the section record; run.py --metrics raises the sample rate
# so one sampled Perfetto trace lands next to bench_results.json.  The
# bundle of the LAST run_scheduled call is kept for the artifact writers.
TRACE_SAMPLE_RATE = 0.0
LAST_TELEMETRY = None


def zipf_sampler(n: int, theta: float = 0.99, seed: int = 0):
    """Bounded zipfian over [0, n) (YCSB's distribution)."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.power(np.arange(1, n + 1), theta)
    cdf = np.cumsum(w / w.sum())

    def sample(k: int) -> np.ndarray:
        return np.searchsorted(cdf, rng.random(k)).astype(np.int64)
    return sample


def uniform_sampler(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)

    def sample(k: int) -> np.ndarray:
        return rng.integers(0, n, k)
    return sample


def build_stores(n_items: int = 8192, val_bytes: int = 16,
                 cfg: HoneycombConfig | None = None, seed: int = 0,
                 honeycomb: bool = True, baseline: bool = True,
                 shards: int = 1, replicas: int = 1,
                 replica_policy: str = "round_robin",
                 feed: str = "log", relay_fanout: int = 2,
                 relay_depth: int = 0,
                 force_router: bool = False):
    """Load both stores with the same random-order keys (paper: inserts are
    uniform random).  ``shards > 1`` builds the live range-sharded store
    (uniform split of the int-key space) instead of the single-device
    facade — the sweep axis for the scale-out benchmarks; ``replicas > 1``
    adds follower replicas per shard with ``replica_policy`` read
    spreading (the replication sweep axis).  ``feed`` selects the follower
    feed ("log" ships the epoch's encoded op stream and replays it on
    device; "delta" ships dirty image rows), and ``relay_fanout``/
    ``relay_depth`` shape the relay tree the payload fans out through
    (depth 0 = primary feeds every follower directly).  ``force_router``
    builds the routed facade even at shards=1/replicas=1, so sweeps that
    include the baseline point compare like against like."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(n_items)
    val = bytes(val_bytes)
    if not honeycomb:
        hc = None
    elif shards > 1 or replicas > 1 or force_router:
        hc = ShardedHoneycombStore(
            cfg or HoneycombConfig(), shards=shards,
            boundaries=uniform_int_boundaries(n_items, shards),
            replication=ReplicationConfig(
                replicas=replicas, policy=replica_policy, feed=feed,
                topology=FeedTopology(fanout=relay_fanout,
                                      depth=relay_depth)))
    else:
        hc = HoneycombStore(cfg or HoneycombConfig())
    cp = CpuOrderedStore() if baseline else None
    for i in order:
        if hc:
            hc.put(int_key(int(i)), val)
        if cp:
            cp.put(int_key(int(i)), val)
    if hc:
        hc.export_snapshot()
    return hc, cp


def sync_traffic(store) -> dict:
    """Snapshot of a Honeycomb store's host->device sync meters (delta-sync
    subsystem) for paper-comparable traffic reporting."""
    s = store.sync_stats
    return {"bytes_synced": s.bytes_synced, "snapshots": s.snapshots,
            "full_syncs": s.full_syncs, "delta_syncs": s.delta_syncs,
            "pagetable_commands": s.pagetable_commands,
            "read_version_updates": s.read_version_updates,
            "log_entries": s.log_entries,
            "log_wire_bytes": s.log_wire_bytes,
            # node-image DMA meters (core/schema.py packed layout: ONE
            # contiguous image-row DMA per dirty node; legacy: one per field)
            "image_dma_count": s.image_dma_count,
            "image_bytes": s.image_bytes,
            # replica-amplification traffic (follower feed; 0 for the
            # unreplicated store, which has no replication machinery).
            # feed_bytes splits into primary_egress_bytes (edges out of the
            # primary) + relay_hop_bytes (relay->follower edges);
            # log_fallback_epochs counts delta-shipped epochs under the log
            # feed (tree-shape changes the wire stream can't replay)
            "replication_bytes": getattr(store, "replication_bytes", 0),
            "feed_bytes": getattr(store, "feed_bytes", 0),
            "primary_egress_bytes": getattr(store, "primary_egress_bytes", 0),
            "relay_hop_bytes": getattr(store, "relay_hop_bytes", 0),
            "log_fallback_epochs": getattr(store, "log_fallback_epochs", 0),
            "delta_fraction": s.delta_fraction}


_SYNC_DIFF_KEYS = ("bytes_synced", "snapshots", "full_syncs", "delta_syncs",
                   "pagetable_commands", "read_version_updates",
                   "log_entries", "log_wire_bytes", "image_dma_count",
                   "image_bytes", "replication_bytes", "feed_bytes",
                   "primary_egress_bytes", "relay_hop_bytes",
                   "log_fallback_epochs")


def run_mixed(store, sampler, *, n_ops: int, read_frac: float,
              n_items: int, scan_items: int = 0, batch: int = 256,
              is_honeycomb: bool = True, val: bytes = b"x" * 16,
              seed: int = 1) -> dict:
    """Timed mixed workload.  Reads run through the batched accelerator
    path for Honeycomb and per-op for the CPU baseline (that asymmetry IS
    the systems comparison).  Returns ops/s, latency stats and (for
    Honeycomb) the sync traffic the workload generated."""
    start_sync = sync_traffic(store) if is_honeycomb else None
    sharded = is_honeycomb and hasattr(store, "per_shard_sync_stats")
    start_per = ([s.bytes_synced for s in store.per_shard_sync_stats]
                 if sharded else None)
    start_ops = list(store.shard_ops) if sharded else None
    rng = np.random.default_rng(seed)
    ops = rng.random(n_ops) < read_frac
    keys = sampler(n_ops)
    t0 = time.perf_counter()
    done = 0
    i = 0
    while i < n_ops:
        if ops[i]:                       # read burst -> one device batch
            j = i
            while j < n_ops and ops[j] and j - i < batch:
                j += 1
            ks = [int_key(int(k)) for k in keys[i:j]]
            if scan_items:
                his = [int_key(min(int(k) + scan_items, n_items - 1))
                       for k in keys[i:j]]
                store.scan_batch(list(zip(ks, his)))
            else:
                store.get_batch(ks)
            done += j - i
            i = j
        else:
            store.put(int_key(int(keys[i])), val)
            done += 1
            i += 1
    dt = time.perf_counter() - t0
    out = {"ops_per_s": done / dt, "seconds": dt, "ops": done}
    if is_honeycomb:
        end = sync_traffic(store)
        out["sync"] = {k: end[k] - start_sync[k] for k in _SYNC_DIFF_KEYS}
        out["sync"]["bytes_per_op"] = out["sync"]["bytes_synced"] / max(done, 1)
        if sharded:
            per = [s.bytes_synced - b0 for s, b0 in
                   zip(store.per_shard_sync_stats, start_per)]
            out["sync"]["per_shard_bytes_per_op"] = [
                b / max(done, 1) for b in per]
            # imbalance over THIS run's routed requests only (the lifetime
            # counter would be dominated by the balanced load phase)
            ops = [b - a for a, b in zip(start_ops, store.shard_ops)]
            total = sum(ops)
            out["sync"]["load_imbalance"] = (
                max(ops) / (total / len(ops)) if total else 0.0)
    return out


def run_scheduled(store, sampler, *, n_ops: int, read_frac: float,
                  n_items: int, scan_items: int = 0, batch: int = 64,
                  pipeline: str = "serial", val: bytes = b"x" * 16,
                  seed: int = 1) -> dict:
    """Timed mixed workload driven through the typed service front end
    (``HoneycombService`` — core/api.py): ops submitted as first-class
    messages, one ``drain()`` pipeline epoch per ``batch`` submissions,
    routing self-wired from the store.  Returns ops/s plus the service's
    per-stage meters — the sync-stall-time comparison is THE
    pipelined-vs-serial artifact: serial mode blocks on every epoch's sync
    barrier; pipelined mode overlaps the standby scatters with read
    dispatch."""
    global LAST_TELEMETRY
    start_sync = sync_traffic(store)
    svc = HoneycombService(
        store, batch_size=batch, pipeline=pipeline,
        telemetry=TelemetryConfig(trace_sample_rate=TRACE_SAMPLE_RATE))
    LAST_TELEMETRY = svc.telemetry
    rng = np.random.default_rng(seed)
    reads = rng.random(n_ops) < read_frac
    keys = sampler(n_ops)
    t0 = time.perf_counter()
    for i in range(n_ops):
        k = int(keys[i])
        if not reads[i]:
            svc.submit(Put(int_key(k), val))
        elif scan_items:
            svc.submit(Scan(int_key(k),
                            int_key(min(k + scan_items, n_items - 1)),
                            expected_items=scan_items + 1))
        else:
            svc.submit(Get(int_key(k)))
        if (i + 1) % batch == 0:
            svc.drain()
    svc.drain()                          # flush the tail epoch
    dt = time.perf_counter() - t0
    end = sync_traffic(store)
    st = svc.stats
    return {
        "ops_per_s": n_ops / dt, "seconds": dt, "ops": n_ops,
        "pipeline": pipeline, "epochs": st.runs, "syncs": svc.syncs,
        "sync_stall_s": st.sync_stall_s, "stall_fraction": st.stall_fraction,
        "admit_s": st.admit_s, "export_s": st.export_s,
        "dispatch_s": st.dispatch_s, "lane_occupancy": st.lane_occupancy,
        "sync": {k: end[k] - start_sync[k] for k in _SYNC_DIFF_KEYS},
        # the registry view of the same run — counters/gauges from every
        # wired stats surface plus the latency-histogram quantiles (the
        # run.py --metrics table reads THIS, not hand-picked fields)
        "metrics": svc.metrics_snapshot(),
    }


def bytes_model_honeycomb(cfg: HoneycombConfig, height: int) -> int:
    """Bytes fetched per GET per the paper's Section 3.1 accounting:
    header+shortcut+one segment per interior level, + leaf segment + log."""
    per_interior = cfg.header_bytes + cfg.shortcut_bytes + cfg.segment_bytes
    leaf = cfg.header_bytes + cfg.shortcut_bytes + cfg.segment_bytes \
        + cfg.log_bytes
    return per_interior * (height - 1) + leaf


def bytes_model_wholenode(cfg: HoneycombConfig, height: int) -> int:
    """Bytes fetched when whole nodes must be read (no shortcuts)."""
    return cfg.node_bytes * height


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")
