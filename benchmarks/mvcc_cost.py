"""Fig. 15: MVCC cost.  Paper: turning MVCC off helps write-bound
workloads by up to ~14% (fewer accelerator read-version updates), with
negligible effect on read-heavy mixes."""
from __future__ import annotations

import time

import numpy as np

from repro.core import HoneycombConfig, HoneycombStore
from repro.core.keys import int_key
from .common import emit


def _write_tput(mvcc: bool, n_ops: int = 4000) -> float:
    st = HoneycombStore(HoneycombConfig(mvcc=mvcc))
    rng = np.random.default_rng(0)
    ks = rng.integers(0, 4096, n_ops)
    t0 = time.perf_counter()
    for k in ks:
        st.put(int_key(int(k)), b"v" * 16)
    return n_ops / (time.perf_counter() - t0), st


def run() -> dict:
    on, st_on = _write_tput(True)
    off, st_off = _write_tput(False)
    results = {"writes_mvcc_on": on, "writes_mvcc_off": off,
               "write_penalty": (off - on) / off,
               "rv_updates_on": st_on.tree.versions.device_updates,
               "rv_updates_off": st_off.tree.versions.device_updates}
    emit("mvcc_write_penalty", 1e6 / on,
         f"off_gain={(off / on - 1) * 100:.1f}% "
         f"rv_updates={results['rv_updates_on']}->"
         f"{results['rv_updates_off']}")
    return results


if __name__ == "__main__":
    run()
