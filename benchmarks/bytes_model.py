"""Section 3.1 analysis: large nodes + shortcuts fetch fewer bytes per
search than small whole-node trees, and need ~4x less interior cache.
This is the paper's analytic claim, reproduced from the same geometry."""
from __future__ import annotations

from repro.core import HoneycombConfig
from .common import bytes_model_honeycomb, bytes_model_wholenode, emit


def run() -> dict:
    cfg = HoneycombConfig()
    out = {}
    for height in (3, 4, 5):
        shortcut = bytes_model_honeycomb(cfg, height)
        whole = bytes_model_wholenode(cfg, height)
        out[height] = {"shortcut_bytes": shortcut, "whole_bytes": whole,
                       "ratio": shortcut / whole}
        emit(f"bytes_h{height}", 0.0,
             f"shortcut={shortcut} whole={whole} "
             f"ratio={shortcut / whole:.2f}")
    return out


if __name__ == "__main__":
    run()
