"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (shared harness contract).
Absolute CPU-container numbers are not the paper's Mops/s; the reproduced
artifacts are the relative trends and the analytic byte model — see
benchmarks/common.py and EXPERIMENTS.md.

``--shards 1,4`` sweeps the shard axis for the sections that serve the live
range-sharded store (YCSB, cloud-storage).  ``--pipeline serial,pipelined``
sweeps the scheduler's epoch-pipeline modes for the sections that drive it
(YCSB, latency), reporting pipelined-vs-serial throughput and sync-stall
time.  ``--replicas 1,2,4`` sweeps per-shard replica counts for the
replicated read-spreading sections (YCSB), reporting the
read-throughput-vs-replicas and sync-bytes-amplification curves.
``--feed log,delta`` sweeps the follower feed (log-shipped wire-stream
replay vs dirty-image-row delta) and ``--relay-depth 0,2`` the relay-tree
depth the feed payload fans out through, for the replicated sections.
``--layout packed,legacy`` sweeps the device-resident snapshot layout for
the sections that meter node-image DMA traffic (log-block), comparing the
packed one-DMA-per-dirty-node format against the legacy per-field scatters
on identical traffic.  ``--read-backend fused,reference`` sweeps the
device read path for the read-path sections (YCSB, latency, cache-lb):
fused whole-traversal megakernels with the VMEM-pinned cache tier vs the
staged jnp reference, with dispatched-launch counts from the new meter.  ``--tiny`` shrinks every section's workload for CI
smoke runs.  A summary
table of every section's sync meters (log entries, wire bytes, sync bytes,
replica amplification) prints after the sweep; ``--metrics`` adds a second
table sourced from the telemetry REGISTRY snapshots the scheduled sections
attach (core/telemetry.py — device-cache hit rate, image-DMA counts, sync
stall fraction, GET latency p50/p99), raises the per-request trace sample
rate, and writes ``experiments/metrics_snapshot.json`` plus a
Perfetto-loadable ``experiments/bench_trace.json`` next to the results.

The scheduler-driven sections run through the typed service API
(``HoneycombService.submit``/``drain`` with first-class op messages —
core/api.py); ``service_api_smoke`` additionally round-trips every request
through the wire codec and asserts monotone serving-version stamps on a
replicated sharded store.
"""
from __future__ import annotations

import argparse
import inspect
import json
import time
from pathlib import Path

from . import (bytes_model, cache_lb, cloud_storage, common, key_size,
               latency, log_block, mvcc_cost, roofline, scan_size,
               service_smoke, ycsb)

SECTIONS = [
    ("service_api_smoke", service_smoke.run),
    ("fig10_ycsb", ycsb.run),
    ("fig11_cloud_storage", cloud_storage.run),
    ("fig12_latency", latency.run),
    ("fig13_scan_size", scan_size.run),
    ("fig14_key_size", key_size.run),
    ("fig15_mvcc", mvcc_cost.run),
    ("fig16_cache_lb", cache_lb.run),
    ("fig17_log_block", log_block.run),
    ("sec3.1_bytes_model", bytes_model.run),
    ("roofline", roofline.run),
]


# --tiny workload overrides, applied to any section parameter they name
TINY = {"n_items": 512, "n_ops": 192, "reps": 2}


def print_sync_summary(results: dict) -> None:
    """One table of every benchmark run's sync meters: write log entries /
    append-only wire bytes (the paper's log-block accounting), dirty-row
    sync bytes, and the replication amplification bytes the follower delta
    feed added on top — surfaced here so the traffic story is one screen,
    not scattered across sections (log_block.py keeps the deep dive)."""
    rows = []
    for section, recs in results.items():
        if not isinstance(recs, dict):
            continue
        for key, rec in recs.items():
            sync = rec.get("sync") if isinstance(rec, dict) else None
            if isinstance(sync, dict) and "log_wire_bytes" in sync:
                rows.append((f"{section}/{key}",
                             sync.get("log_entries", 0),
                             sync["log_wire_bytes"],
                             sync.get("bytes_synced", 0),
                             sync.get("image_dma_count", 0),
                             sync.get("feed_bytes",
                                      sync.get("replication_bytes", 0)),
                             sync.get("relay_hop_bytes", 0),
                             sync.get("log_fallback_epochs", 0)))
    if not rows:
        return
    print("# --- sync traffic summary ---")
    print(f"# {'run':<44} {'log_ents':>8} {'wire_B':>10} "
          f"{'sync_B':>12} {'img_dmas':>8} {'feed_B':>12} "
          f"{'relay_B':>12} {'fallbacks':>9}")
    for name, ents, wire, synced, dmas, feed, relay, fb in rows:
        print(f"# {name:<44} {ents:>8} {wire:>10} {synced:>12} "
              f"{dmas:>8} {feed:>12} {relay:>12} {fb:>9}")


def _mval(metrics: dict, name: str, **labels) -> float:
    """Sum the scalar registry samples named ``name`` (optionally filtered
    by label equality) out of a flat ``name{k=v,...}`` snapshot."""
    tot = 0.0
    for k, v in metrics.items():
        base, _, rest = k.partition("{")
        if base != name or isinstance(v, dict):
            continue
        if labels:
            ls = dict(p.split("=", 1)
                      for p in rest.rstrip("}").split(",") if "=" in p)
            if any(ls.get(a) != str(b) for a, b in labels.items()):
                continue
        tot += v
    return tot


def _mhist(metrics: dict, name: str) -> dict:
    """First histogram sample named ``name`` (its quantile dict)."""
    for k, v in metrics.items():
        if k.partition("{")[0] == name and isinstance(v, dict):
            return v
    return {}


def print_metrics_summary(results: dict) -> None:
    """One table per --metrics run sourced from the REGISTRY snapshots the
    scheduled sections attach (core/telemetry.py; not hand-picked stats
    fields): device-cache hit rate, image-DMA count, the scheduler's sync
    stall fraction and lane occupancy, and the GET latency p50/p99."""
    rows = []
    for section, recs in results.items():
        if not isinstance(recs, dict):
            continue
        for key, rec in recs.items():
            m = rec.get("metrics") if isinstance(rec, dict) else None
            if not m:
                continue
            g = _mhist(m, "read_get_latency_seconds")
            rows.append((f"{section}/{key}",
                         _mval(m, "cache_device_hit_rate"),
                         int(_mval(m, "sync_image_dma_count",
                                   src="primary")),
                         _mval(m, "pipeline_stall_fraction",
                               src="scheduler"),
                         _mval(m, "pipeline_lane_occupancy",
                               src="scheduler"),
                         g.get("p50", 0.0) * 1e6, g.get("p99", 0.0) * 1e6))
    if not rows:
        return
    print("# --- registry metrics summary ---")
    print(f"# {'run':<44} {'dev_hit':>7} {'img_dmas':>8} {'stall_fr':>8} "
          f"{'lane_occ':>8} {'get_p50us':>10} {'get_p99us':>10}")
    for name, hit, dmas, stall, occ, p50, p99 in rows:
        print(f"# {name:<44} {hit:>7.3f} {dmas:>8} {stall:>8.3f} "
              f"{occ:>8.3f} {p50:>10.1f} {p99:>10.1f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("only", nargs="?", default=None,
                    help="run only sections whose name contains one of "
                         "these comma-separated substrings")
    ap.add_argument("--shards", default="1",
                    help="comma-separated shard counts for the sharded "
                         "sections (e.g. 1,4)")
    ap.add_argument("--pipeline", default="",
                    help="comma-separated scheduler pipeline modes to sweep "
                         "(e.g. serial,pipelined); empty skips the axis")
    ap.add_argument("--replicas", default="",
                    help="comma-separated per-shard replica counts for the "
                         "read-spreading sections (e.g. 1,2,4); empty "
                         "skips the axis")
    ap.add_argument("--feed", default="",
                    help="comma-separated follower feeds to sweep for the "
                         "replicated sections (e.g. log,delta); empty "
                         "uses the default log feed")
    ap.add_argument("--relay-depth", default="",
                    help="comma-separated relay-tree depths to sweep for "
                         "the replicated sections (e.g. 0,2); empty uses "
                         "the flat primary-feeds-all topology")
    ap.add_argument("--read-backend", default="",
                    help="comma-separated device read backends to sweep for "
                         "the read-path sections (e.g. fused,reference): "
                         "fused = whole-traversal megakernels with the "
                         "VMEM-pinned cache tier, reference = staged jnp "
                         "oracle; empty uses each section's default")
    ap.add_argument("--layout", default="packed",
                    help="comma-separated snapshot layouts to sweep for the "
                         "layout-aware sections (e.g. packed,legacy)")
    ap.add_argument("--metrics", action="store_true",
                    help="print a registry metrics summary table (hit "
                         "rates, DMA counts, stall fraction, read "
                         "p50/p99) after the sweep, raise the trace "
                         "sample rate, and write the last section's "
                         "metrics snapshot + a Perfetto trace next to "
                         "bench_results.json")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink workloads to smoke-test sizes (CI)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero if any requested section errored "
                         "(CI gates on this; the default keeps sweeping)")
    args = ap.parse_args()
    shards = tuple(int(s) for s in args.shards.split(","))
    pipeline = tuple(m for m in args.pipeline.split(",") if m)
    replicas = tuple(int(r) for r in args.replicas.split(",") if r)
    feed = tuple(f for f in args.feed.split(",") if f)
    relay_depth = tuple(int(d) for d in args.relay_depth.split(",") if d != "")
    layout = tuple(m for m in args.layout.split(",") if m)
    read_backend = tuple(b for b in args.read_backend.split(",") if b)
    only = tuple(t for t in (args.only or "").split(",") if t)
    if args.metrics:
        common.TRACE_SAMPLE_RATE = 1 / 16   # every 16th request traced
    results = {}
    for name, fn in SECTIONS:
        if only and not any(tok in name for tok in only):
            continue
        params = inspect.signature(fn).parameters
        kwargs = {}
        if "shards" in params:
            kwargs["shards"] = shards
        if "pipeline" in params:
            kwargs["pipeline"] = pipeline
        if "replicas" in params:
            kwargs["replicas"] = replicas
        if "feed" in params and feed:
            kwargs["feed"] = feed
        if "relay_depth" in params and relay_depth:
            kwargs["relay_depth"] = relay_depth
        if "layout" in params and layout:
            kwargs["layout"] = layout
        if "read_backend" in params and read_backend:
            kwargs["read_backend"] = read_backend
        if args.tiny:
            kwargs.update({k: v for k, v in TINY.items() if k in params})
        print(f"# --- {name} ---", flush=True)
        t0 = time.perf_counter()
        try:
            results[name] = fn(**kwargs)
        except Exception as e:  # noqa: BLE001 — keep the suite running
            print(f"{name},0.00,ERROR:{type(e).__name__}:{e}")
            results[name] = {"error": str(e)}
        print(f"# {name} took {time.perf_counter() - t0:.1f}s", flush=True)
    print_sync_summary(results)
    out = Path("experiments/bench_results.json")
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(results, indent=1, default=str))
    print(f"# results -> {out}")
    if args.metrics:
        print_metrics_summary(results)
        tm = common.LAST_TELEMETRY
        if tm is not None:
            snap = out.parent / "metrics_snapshot.json"
            snap.write_text(json.dumps(tm.snapshot(), indent=1))
            trace = out.parent / "bench_trace.json"
            trace.write_text(json.dumps(tm.chrome_trace()))
            print(f"# metrics -> {snap}  trace -> {trace} "
                  f"({len(tm.traces())} sampled)")
    errored = [n for n, r in results.items()
               if isinstance(r, dict) and "error" in r]
    if args.strict and errored:
        raise SystemExit(f"sections errored: {', '.join(errored)}")


if __name__ == "__main__":
    main()
