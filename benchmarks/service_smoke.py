"""Service-API smoke: the typed request/response front end end to end.

Drives ``HoneycombService`` (core/api.py) over a REPLICATED, SHARDED store
— ``submit_many`` a mixed GET/SCAN/PUT/UPDATE/DELETE op batch, ``drain()``
pipeline epochs — and verifies the wire codec and response stamps on live
traffic: every op roundtrips through ``encode_wire``/``decode_wire`` before
submission (the benchmark submits the DECODED ops, so the codec is on the
serving path), read responses carry monotone serving versions, and the
exact encoder agrees with the store's ``log_wire_bytes`` meter.  This is
the CI gate that the service API, not just the facades, serves requests.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (Delete, Get, HoneycombService, Put,
                        ReplicationConfig, Scan, ShardedHoneycombStore,
                        Update, decode_wire_stream, uniform_int_boundaries)
from repro.core.keys import int_key

from .common import emit, sync_traffic


def mixed_ops(rng, n: int, n_items: int):
    ops = []
    for _ in range(n):
        k = int(rng.integers(0, n_items))
        p = rng.random()
        if p < 0.2:
            ops.append(Put(int_key(k), b"p" * 12))
        elif p < 0.3:
            ops.append(Update(int_key(k), b"u" * 12))
        elif p < 0.35:
            ops.append(Delete(int_key(k)))
        elif p < 0.85:
            ops.append(Get(int_key(k)))
        else:
            ops.append(Scan(int_key(k), int_key(min(k + 7, n_items - 1)),
                            expected_items=8))
    return ops


def run(n_items: int = 1024, n_ops: int = 512) -> dict:
    st = ShardedHoneycombStore(
        heap_capacity=max(2 * n_items, 1024), shards=2,
        boundaries=uniform_int_boundaries(n_items, 2),
        replication=ReplicationConfig(replicas=2, policy="round_robin"))
    svc = HoneycombService(st, batch_size=64, pipeline="pipelined")
    rng = np.random.default_rng(29)
    # load phase through the service itself
    svc.submit_many([Put(int_key(int(i)), b"v" * 12)
                     for i in rng.permutation(n_items)])
    svc.drain()
    start = sync_traffic(st)
    epoch = max(n_ops // 4, 1)
    wire_bytes = 0
    last_seen: dict[bytes, int] = {}
    replicas_used: set[int] = set()
    t0 = time.perf_counter()
    done = 0
    while done < n_ops:
        ops = mixed_ops(rng, min(epoch, n_ops - done), n_items)
        # ops cross the wire: encode the batch, submit the DECODED stream
        stream = b"".join(op.encode_wire() for op in ops)
        wire_bytes += len(stream)
        tickets = svc.submit_many(decode_wire_stream(stream))
        svc.drain()
        for t in tickets:
            r = t.result()
            if not t.op.IS_WRITE:
                key = t.op.route_key
                assert r.serving_version >= last_seen.get(key, 0), \
                    "serving versions regressed"
                last_seen[key] = r.serving_version
                replicas_used.add(r.replica)
        done += len(ops)
    dt = time.perf_counter() - t0
    end = sync_traffic(st)
    sync = {k: end[k] - v for k, v in start.items()
            if isinstance(v, (int, float))}
    out = {
        "ops_per_s": n_ops / dt, "ops": n_ops, "seconds": dt,
        "shards": 2, "replicas": 2,
        "request_wire_bytes": wire_bytes,
        "replicas_used": sorted(replicas_used),
        "lagging_skips": st.lagging_skips,
        "replica_load_imbalance": st.replica_load_imbalance,
        "sync": sync,
    }
    emit("service_smoke", 1e6 / out["ops_per_s"],
         f"ops_s={out['ops_per_s']:.0f} req_wire_B={wire_bytes} "
         f"lanes={sorted(replicas_used)} "
         f"repl_B={sync['replication_bytes']} "
         f"wire_B={sync['log_wire_bytes']}")
    return {"replicated_sharded": out}


if __name__ == "__main__":
    run()
