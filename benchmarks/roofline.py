"""Roofline table: renders experiments/dryrun.json (written by
repro.launch.dryrun) into the EXPERIMENTS.md Section Roofline table."""
from __future__ import annotations

import json
from pathlib import Path

DEFAULT = Path("experiments/dryrun.json")


def render(path: Path = DEFAULT, mesh: str = "single") -> str:
    data = json.loads(Path(path).read_text())
    rows = []
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | useful | peak GB/chip |")
    sep = "|" + "---|" * 8
    rows.append(hdr)
    rows.append(sep)
    for key, r in sorted(data.items()):
        if r.get("status") == "skip":
            if key.endswith("|single"):
                rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                            f"SKIP | - | - |")
            continue
        if r.get("status") != "ok" or not key.endswith(f"|{mesh}"):
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"{rl['dominant']} | {rl['useful_ratio']:.2f} | "
            f"{r['memory']['peak_bytes'] / 2**30:.2f} |")
    return "\n".join(rows)


def run() -> dict:
    if not DEFAULT.exists():
        print("roofline,0.00,missing experiments/dryrun.json (run dryrun)")
        return {}
    print(render())
    return {"rendered": True}


if __name__ == "__main__":
    run()
