"""Fig. 12: latency-throughput.  Load is swept via device batch size; we
report median per-op latency at each offered batch (read-only 3-item
scans, the figure's workload)."""
from __future__ import annotations

import time

import numpy as np

from .common import build_stores, emit, uniform_sampler
from repro.core.keys import int_key


def run(n_items: int = 4096, reps: int = 8) -> dict:
    hc, _ = build_stores(n_items, baseline=False)
    sampler = uniform_sampler(n_items, seed=9)
    results = {}
    for batch in (8, 32, 128, 512):
        lats = []
        for _ in range(reps):
            ks = sampler(batch)
            ranges = [(int_key(int(k)),
                       int_key(min(int(k) + 3, n_items - 1))) for k in ks]
            t0 = time.perf_counter()
            hc.scan_batch(ranges)
            lats.append((time.perf_counter() - t0) / batch)
        med = float(np.median(lats)) * 1e6
        tput = batch / (np.median(lats) * batch)
        results[batch] = {"median_us_per_op": med, "ops_per_s": tput}
        emit(f"latency_b{batch}", med, f"ops_s={tput:.0f}")
    return results


if __name__ == "__main__":
    run()
