"""Fig. 12: latency-throughput.  Load is swept via device batch size; we
report median per-op latency at each offered batch (read-only 3-item
scans, the figure's workload).

``read_backend`` sweeps the device read path: "fused" drives the whole
per-batch traversal through ONE megakernel dispatch with the interior
cache tier pinned in VMEM (kernels/fused_read.py); "reference" is the
staged jnp path kept as the tested oracle.  Alongside the throughput
ratio we report the per-batch dispatched-kernel counts from the launch
meter (``kernels/ops.read_dispatch_stats`` — the fused path must stay at
1 launch/batch where the reference path pays one per traversal stage).

``pipeline`` adds a second sweep: the same offered batches driven through
the scheduler's epoch pipeline with a 10% update mix (so every epoch has a
sync), serial vs pipelined — the per-op latency delta plus the
sync-stall-time meter show what the double-buffered flip buys at each
load point (see core/pipeline.py)."""
from __future__ import annotations

import time

import numpy as np

from .common import build_stores, emit, run_scheduled, uniform_sampler
from repro.core import HoneycombConfig
from repro.core.keys import int_key
from repro.kernels import ops as kernel_ops

BATCHES = (8, 32, 128, 512)


def run(n_items: int = 4096, reps: int = 8,
        pipeline: tuple[str, ...] = (),
        read_backend: tuple[str, ...] = ("fused", "reference")) -> dict:
    results = {}
    top_tput = {}                 # backend -> ops/s at the largest batch
    for rb in read_backend:
        hc, _ = build_stores(n_items, baseline=False,
                             cfg=HoneycombConfig(read_backend=rb))
        sampler = uniform_sampler(n_items, seed=9)
        kernel_ops.reset_read_dispatches()
        for batch in BATCHES:
            lats = []
            for _ in range(reps):
                ks = sampler(batch)
                ranges = [(int_key(int(k)),
                           int_key(min(int(k) + 3, n_items - 1)))
                          for k in ks]
                t0 = time.perf_counter()
                hc.scan_batch(ranges)
                lats.append((time.perf_counter() - t0) / batch)
            med = float(np.median(lats)) * 1e6
            tput = batch / (np.median(lats) * batch)
            key = batch if rb == "fused" else f"b{batch}/{rb}"
            results[key] = {"median_us_per_op": med, "ops_per_s": tput,
                            "read_backend": rb}
            top_tput[rb] = tput
            suffix = "" if rb == "fused" else f"_{rb}"
            emit(f"latency_b{batch}{suffix}", med, f"ops_s={tput:.0f}")
        # per-op dispatched-kernel counts from the launch meter: the fused
        # megakernel's whole-traversal claim, measured not asserted
        ds = kernel_ops.read_dispatch_stats()
        results[f"dispatch/{rb}"] = ds
        for op_key, d in sorted(ds.items()):
            emit(f"latency_dispatch_{op_key}", 0.0,
                 f"launches/batch={d['per_batch']:.1f} "
                 f"batches={d['batches']}")
    if "fused" in top_tput and "reference" in top_tput:
        ratio = top_tput["fused"] / top_tput["reference"]
        results["fused_vs_reference"] = {
            "tput_ratio": ratio,
            "batch": max(BATCHES),
            "fused_ops_s": top_tput["fused"],
            "reference_ops_s": top_tput["reference"]}
        emit("latency_fused_vs_reference", 0.0, f"tput_ratio={ratio:.2f}x")
    for mode in pipeline:
        for batch in BATCHES:
            hp, _ = build_stores(n_items, baseline=False)
            r = run_scheduled(hp, uniform_sampler(n_items, seed=9),
                              n_ops=batch * max(reps // 2, 1),
                              n_items=n_items, read_frac=0.9, scan_items=3,
                              batch=batch, pipeline=mode)
            us = 1e6 / r["ops_per_s"]
            results[f"b{batch}/{mode}"] = r
            emit(f"latency_b{batch}_{mode}", us,
                 f"ops_s={r['ops_per_s']:.0f} "
                 f"stall_s={r['sync_stall_s']:.3f} "
                 f"stall_frac={r['stall_fraction']:.2f} "
                 f"syncs={r['syncs']}")
    return results


if __name__ == "__main__":
    run(pipeline=("serial", "pipelined"))
