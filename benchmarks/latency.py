"""Fig. 12: latency-throughput.  Load is swept via device batch size; we
report median per-op latency at each offered batch (read-only 3-item
scans, the figure's workload).

``pipeline`` adds a second sweep: the same offered batches driven through
the scheduler's epoch pipeline with a 10% update mix (so every epoch has a
sync), serial vs pipelined — the per-op latency delta plus the
sync-stall-time meter show what the double-buffered flip buys at each
load point (see core/pipeline.py)."""
from __future__ import annotations

import time

import numpy as np

from .common import build_stores, emit, run_scheduled, uniform_sampler
from repro.core.keys import int_key

BATCHES = (8, 32, 128, 512)


def run(n_items: int = 4096, reps: int = 8,
        pipeline: tuple[str, ...] = ()) -> dict:
    hc, _ = build_stores(n_items, baseline=False)
    sampler = uniform_sampler(n_items, seed=9)
    results = {}
    for batch in BATCHES:
        lats = []
        for _ in range(reps):
            ks = sampler(batch)
            ranges = [(int_key(int(k)),
                       int_key(min(int(k) + 3, n_items - 1))) for k in ks]
            t0 = time.perf_counter()
            hc.scan_batch(ranges)
            lats.append((time.perf_counter() - t0) / batch)
        med = float(np.median(lats)) * 1e6
        tput = batch / (np.median(lats) * batch)
        results[batch] = {"median_us_per_op": med, "ops_per_s": tput}
        emit(f"latency_b{batch}", med, f"ops_s={tput:.0f}")
    for mode in pipeline:
        for batch in BATCHES:
            hp, _ = build_stores(n_items, baseline=False)
            r = run_scheduled(hp, uniform_sampler(n_items, seed=9),
                              n_ops=batch * max(reps // 2, 1),
                              n_items=n_items, read_frac=0.9, scan_items=3,
                              batch=batch, pipeline=mode)
            us = 1e6 / r["ops_per_s"]
            results[f"b{batch}/{mode}"] = r
            emit(f"latency_b{batch}_{mode}", us,
                 f"ops_s={r['ops_per_s']:.0f} "
                 f"stall_s={r['sync_stall_s']:.3f} "
                 f"stall_frac={r['stall_fraction']:.2f} "
                 f"syncs={r['syncs']}")
    return results


if __name__ == "__main__":
    run(pipeline=("serial", "pipelined"))
