"""Fig. 17: log-block size sweep.  Bigger log blocks help inserts (fewer
merges => fewer page-table syncs) and hurt scans (more unsorted bytes per
leaf read) — the paper picks 512 B; here the analogue knob is log_cap.

Also reports the delta-vs-full sync-traffic curve: after a resident snapshot
exists, a batch of W writes delta-syncs O(W) bytes where a wholesale
republish moves the entire store — the log block plus batched page-table
commands are exactly what make the delta small (the paper's PCIe
amortization argument, now measurable end to end).

The node-image DMA accounting rides the same curve: on the packed layout
(core/schema.py) every dirty node crosses as ONE contiguous image-row DMA
of ``node_image_bytes`` (the paper's whole-node transfer); the legacy
per-field layout moves the same bytes in one scatter per field per node.
``layout_compare`` drives BOTH layouts with identical traffic and reports
bytes-per-dirty-node and DMA-invocation counts side by side — the
DMA-collapse factor is exactly the per-node field count."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (HoneycombConfig, HoneycombStore, NodeImageLayout,
                        FIELD_NAMES)
from repro.core.keys import int_key
from .common import emit, uniform_sampler

WRITE_BATCHES = (16, 64, 256)


def sync_traffic_curve(st: HoneycombStore, n_items: int) -> dict:
    """Delta vs full host->device bytes for growing write batches, plus the
    append-only log-entry wire-format estimate (key+value+op per write) —
    the paper's log-block byte accounting.  The wire bytes lower-bound what
    a log-structured delta encoding would move; dirty-node deltas transfer
    whole node images and sit between that bound and a full republish.
    Each batch also reports its node-image DMA meters: invocations, dirty
    nodes, and bytes per dirty node (== node_image_bytes by construction;
    the layouts differ only in the DMA *count*)."""
    layout = NodeImageLayout.for_config(st.cfg)
    st.export_snapshot()                      # make the snapshot resident
    curve = {}
    rng = np.random.default_rng(23)
    for w in WRITE_BATCHES:
        w0 = st.sync_stats.log_wire_bytes
        for k in rng.integers(0, n_items, w):
            st.update(int_key(int(k)), b"u" * 16)
        wire_bytes = st.sync_stats.log_wire_bytes - w0
        b0 = st.sync_stats.bytes_synced
        d0 = st.sync_stats.image_dma_count
        i0 = st.sync_stats.image_bytes
        st.export_snapshot()
        delta_bytes = st.sync_stats.bytes_synced - b0
        image_dmas = st.sync_stats.image_dma_count - d0
        node_bytes = st.sync_stats.image_bytes - i0
        dirty = node_bytes // layout.node_image_bytes
        delta_fraction = st.sync_stats.delta_fraction
        b1 = st.sync_stats.bytes_synced
        st.export_snapshot(full=True)
        full_bytes = st.sync_stats.bytes_synced - b1
        curve[w] = {"delta_bytes": delta_bytes, "full_bytes": full_bytes,
                    "wire_bytes": wire_bytes,
                    "ratio": delta_bytes / full_bytes,
                    "wire_ratio": wire_bytes / full_bytes,
                    "wire_vs_delta": wire_bytes / max(delta_bytes, 1),
                    "delta_fraction": delta_fraction,
                    "image_dmas": image_dmas, "dirty_nodes": dirty,
                    "bytes_per_dirty_node": node_bytes / max(dirty, 1),
                    "dmas_per_dirty_node": image_dmas / max(dirty, 1)}
    return curve


def layout_compare(n_items: int, writes: int | None = None) -> dict:
    """Packed vs legacy on IDENTICAL traffic: same seed, same load, same
    write batch; report DMA invocations and bytes per dirty node for each
    layout plus the collapse factor (legacy_dmas / packed_dmas == the
    per-node field count — the counter the packed layout exists to fix)."""
    writes = writes or min(128, max(16, n_items // 16))
    out = {}
    for lt in ("packed", "legacy"):
        cfg = HoneycombConfig(layout=lt)
        layout = NodeImageLayout.for_config(cfg)
        st = HoneycombStore(cfg)
        rng = np.random.default_rng(0)
        for i in rng.permutation(n_items):
            st.put(int_key(int(i)), b"v" * 16)
        st.export_snapshot()
        d0 = st.sync_stats.image_dma_count
        i0 = st.sync_stats.image_bytes
        b0 = st.sync_stats.bytes_synced
        for k in rng.integers(0, n_items, writes):
            st.update(int_key(int(k)), b"u" * 16)
        st.export_snapshot()
        dmas = st.sync_stats.image_dma_count - d0
        node_bytes = st.sync_stats.image_bytes - i0
        dirty = node_bytes // layout.node_image_bytes
        out[lt] = {"image_dmas": dmas, "dirty_nodes": dirty,
                   "node_bytes": node_bytes,
                   "delta_bytes": st.sync_stats.bytes_synced - b0,
                   "bytes_per_dirty_node": node_bytes / max(dirty, 1),
                   "dmas_per_dirty_node": dmas / max(dirty, 1)}
        emit(f"layout_{lt}_w{writes}", dmas,
             f"dmas={dmas} dirty={dirty} "
             f"B/node={out[lt]['bytes_per_dirty_node']:.0f} "
             f"dma/node={out[lt]['dmas_per_dirty_node']:.1f}")
    out["dma_collapse"] = (out["legacy"]["image_dmas"]
                           / max(out["packed"]["image_dmas"], 1))
    emit("layout_dma_collapse", out["dma_collapse"],
         f"legacy/packed DMA ratio={out['dma_collapse']:.1f} "
         f"(fields/node={len(FIELD_NAMES)})")
    return out


def run(n_items: int = 2048, n_ops: int = 1024,
        layout: tuple[str, ...] = ("packed",)) -> dict:
    results = {}
    for lt in layout:
        for log_cap in (2, 8, 16, 32):
            cfg = HoneycombConfig(log_cap=log_cap, layout=lt)
            st = HoneycombStore(cfg)
            rng = np.random.default_rng(0)
            for i in rng.permutation(n_items):
                st.put(int_key(int(i)), b"v" * 16)
            # insert throughput
            ks = rng.integers(n_items, 2 * n_items, n_ops)
            t0 = time.perf_counter()
            for k in ks:
                st.put(int_key(int(k)), b"v" * 16)
            ins = n_ops / (time.perf_counter() - t0)
            syncs = st.tree.pt.sync_commands
            # 1-item scan throughput
            st.export_snapshot()
            sampler = uniform_sampler(n_items, 17)
            t0 = time.perf_counter()
            for i in range(0, n_ops, 256):
                ks2 = [int_key(int(k)) for k in sampler(min(256, n_ops - i))]
                st.scan_batch([(k, k) for k in ks2])
            sc = n_ops / (time.perf_counter() - t0)
            curve = sync_traffic_curve(st, n_items)
            key = log_cap if len(layout) == 1 else f"{lt}_{log_cap}"
            results[key] = {"layout": lt, "insert_ops_s": ins,
                            "scan_ops_s": sc, "pt_syncs": syncs,
                            "sync_traffic": curve}
            tag = f"logcap_{log_cap}" + ("" if len(layout) == 1 else f"_{lt}")
            emit(tag, 1e6 / ins,
                 f"insert={ins:.0f}/s scan={sc:.0f}/s syncs={syncs}")
            for w, c in curve.items():
                emit(f"{tag}_sync_w{w}", c["delta_bytes"],
                     f"delta={c['delta_bytes']}B full={c['full_bytes']}B "
                     f"wire={c['wire_bytes']}B ratio={c['ratio']:.4f} "
                     f"dmas={c['image_dmas']} dirty={c['dirty_nodes']} "
                     f"B/node={c['bytes_per_dirty_node']:.0f}")
    results["layout_compare"] = layout_compare(n_items)
    return results


if __name__ == "__main__":
    run()
