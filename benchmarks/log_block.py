"""Fig. 17: log-block size sweep.  Bigger log blocks help inserts (fewer
merges => fewer page-table syncs) and hurt scans (more unsorted bytes per
leaf read) — the paper picks 512 B; here the analogue knob is log_cap.

Also reports the delta-vs-full sync-traffic curve: after a resident snapshot
exists, a batch of W writes delta-syncs O(W) bytes where a wholesale
republish moves the entire store — the log block plus batched page-table
commands are exactly what make the delta small (the paper's PCIe
amortization argument, now measurable end to end)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import HoneycombConfig, HoneycombStore
from repro.core.keys import int_key
from .common import emit, uniform_sampler

WRITE_BATCHES = (16, 64, 256)


def sync_traffic_curve(st: HoneycombStore, n_items: int) -> dict:
    """Delta vs full host->device bytes for growing write batches, plus the
    append-only log-entry wire-format estimate (key+value+op per write) —
    the paper's log-block byte accounting.  The wire bytes lower-bound what
    a log-structured delta encoding would move; dirty-row deltas transfer
    whole node rows and sit between that bound and a full republish."""
    st.export_snapshot()                      # make the snapshot resident
    curve = {}
    rng = np.random.default_rng(23)
    for w in WRITE_BATCHES:
        w0 = st.sync_stats.log_wire_bytes
        for k in rng.integers(0, n_items, w):
            st.update(int_key(int(k)), b"u" * 16)
        wire_bytes = st.sync_stats.log_wire_bytes - w0
        b0 = st.sync_stats.bytes_synced
        st.export_snapshot()
        delta_bytes = st.sync_stats.bytes_synced - b0
        delta_fraction = st.sync_stats.delta_fraction
        b1 = st.sync_stats.bytes_synced
        st.export_snapshot(full=True)
        full_bytes = st.sync_stats.bytes_synced - b1
        curve[w] = {"delta_bytes": delta_bytes, "full_bytes": full_bytes,
                    "wire_bytes": wire_bytes,
                    "ratio": delta_bytes / full_bytes,
                    "wire_ratio": wire_bytes / full_bytes,
                    "wire_vs_delta": wire_bytes / max(delta_bytes, 1),
                    "delta_fraction": delta_fraction}
    return curve


def run(n_items: int = 2048, n_ops: int = 1024) -> dict:
    results = {}
    for log_cap in (2, 8, 16, 32):
        cfg = HoneycombConfig(log_cap=log_cap)
        st = HoneycombStore(cfg)
        rng = np.random.default_rng(0)
        for i in rng.permutation(n_items):
            st.put(int_key(int(i)), b"v" * 16)
        # insert throughput
        ks = rng.integers(n_items, 2 * n_items, n_ops)
        t0 = time.perf_counter()
        for k in ks:
            st.put(int_key(int(k)), b"v" * 16)
        ins = n_ops / (time.perf_counter() - t0)
        syncs = st.tree.pt.sync_commands
        # 1-item scan throughput
        st.export_snapshot()
        sampler = uniform_sampler(n_items, 17)
        t0 = time.perf_counter()
        for i in range(0, n_ops, 256):
            ks2 = [int_key(int(k)) for k in sampler(min(256, n_ops - i))]
            st.scan_batch([(k, k) for k in ks2])
        sc = n_ops / (time.perf_counter() - t0)
        curve = sync_traffic_curve(st, n_items)
        results[log_cap] = {"insert_ops_s": ins, "scan_ops_s": sc,
                            "pt_syncs": syncs, "sync_traffic": curve}
        emit(f"logcap_{log_cap}", 1e6 / ins,
             f"insert={ins:.0f}/s scan={sc:.0f}/s syncs={syncs}")
        for w, c in curve.items():
            emit(f"logcap_{log_cap}_sync_w{w}", c["delta_bytes"],
                 f"delta={c['delta_bytes']}B full={c['full_bytes']}B "
                 f"wire={c['wire_bytes']}B ratio={c['ratio']:.4f} "
                 f"wire_ratio={c['wire_ratio']:.5f}")
    return results


if __name__ == "__main__":
    run()
