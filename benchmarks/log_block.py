"""Fig. 17: log-block size sweep.  Bigger log blocks help inserts (fewer
merges => fewer page-table syncs) and hurt scans (more unsorted bytes per
leaf read) — the paper picks 512 B; here the analogue knob is log_cap."""
from __future__ import annotations

import time

import numpy as np

from repro.core import HoneycombConfig, HoneycombStore
from repro.core.keys import int_key
from .common import emit, uniform_sampler


def run(n_items: int = 2048, n_ops: int = 1024) -> dict:
    results = {}
    for log_cap in (2, 8, 16, 32):
        cfg = HoneycombConfig(log_cap=log_cap)
        st = HoneycombStore(cfg)
        rng = np.random.default_rng(0)
        for i in rng.permutation(n_items):
            st.put(int_key(int(i)), b"v" * 16)
        # insert throughput
        ks = rng.integers(n_items, 2 * n_items, n_ops)
        t0 = time.perf_counter()
        for k in ks:
            st.put(int_key(int(k)), b"v" * 16)
        ins = n_ops / (time.perf_counter() - t0)
        syncs = st.tree.pt.sync_commands
        # 1-item scan throughput
        st.export_snapshot()
        sampler = uniform_sampler(n_items, 17)
        t0 = time.perf_counter()
        for i in range(0, n_ops, 256):
            ks2 = [int_key(int(k)) for k in sampler(min(256, n_ops - i))]
            st.scan_batch([(k, k) for k in ks2])
        sc = n_ops / (time.perf_counter() - t0)
        results[log_cap] = {"insert_ops_s": ins, "scan_ops_s": sc,
                            "pt_syncs": syncs}
        emit(f"logcap_{log_cap}", 1e6 / ins,
             f"insert={ins:.0f}/s scan={sc:.0f}/s syncs={syncs}")
    return results


if __name__ == "__main__":
    run()
