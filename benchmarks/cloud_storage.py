"""Fig. 11: cloud-storage workload — 3-4-item SCANs, read fraction swept
50%..100%, uniform and zipfian.  The paper's headline: throughput and
cost-performance grow with read share (>=80% reads: >2x / >1.9x).

Shards are a sweep axis: the sharded store serves the identical workload
through the router (cross-shard scans decomposed per shard), with per-shard
sync bytes/op and load imbalance metered."""
from __future__ import annotations

from .common import (TDP_BASELINE_W, TDP_HONEYCOMB_W, build_stores, emit,
                     run_mixed, uniform_sampler, zipf_sampler)


def run(n_items: int = 4096, n_ops: int = 2048,
        shards: tuple[int, ...] = (1,)) -> dict:
    results = {}
    for ns in shards if isinstance(shards, (tuple, list)) else (shards,):
        hc, cp = build_stores(n_items, shards=ns)
        tag = "" if ns == 1 else f"/s{ns}"
        for dist in ("uniform", "zipfian"):
            mk = uniform_sampler if dist == "uniform" else zipf_sampler
            for read_pct in (50, 80, 90, 95, 100):
                spec = dict(read_frac=read_pct / 100, scan_items=3)
                r_h = run_mixed(hc, mk(n_items, seed=5), n_ops=n_ops,
                                n_items=n_items, **spec)
                r_c = run_mixed(cp, mk(n_items, seed=5), n_ops=n_ops,
                                n_items=n_items, is_honeycomb=False, **spec)
                h, c = r_h["ops_per_s"], r_c["ops_per_s"]
                eff = (h / TDP_HONEYCOMB_W) / (c / TDP_BASELINE_W)
                sync = r_h["sync"]
                results[f"{dist}/{read_pct}{tag}"] = {
                    "honeycomb_ops_s": h, "baseline_ops_s": c,
                    "speedup": h / c, "eff_ratio": eff,
                    "shards": ns, "sync_bytes_per_op": sync["bytes_per_op"],
                    "load_imbalance": sync.get("load_imbalance"),
                    "per_shard_bytes_per_op": sync.get(
                        "per_shard_bytes_per_op")}
                extra = ""
                if "load_imbalance" in sync:
                    extra = f" imbal={sync['load_imbalance']:.2f}"
                emit(f"cloud_{dist}_{read_pct}r{tag.replace('/', '_')}",
                     1e6 / h, f"speedup={h / c:.2f}x eff={eff:.2f}x"
                     f" sync_B/op={sync['bytes_per_op']:.0f}{extra}")
    return results


if __name__ == "__main__":
    run(shards=(1, 4))
