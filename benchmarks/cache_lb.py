"""Fig. 16: interior-node cache + load balancer — modeled AND measured.

The measured half replays a Zipfian hot-key workload through the REAL
``StoreShard`` read path: the fused megakernels resolve the first
``cfg.cache_levels`` descend levels from the VMEM-pinned cache tier
(``vmem_hits``) and fall through to the heap image below the frontier
(``heap_gathers``), while ``cfg.lb_fraction`` deterministically routes a
slice of cache-hit lanes down the heap pipe anyway — the paper's
dual-pipe load balancer, with the byte split read straight off the device
meters.  The same workload on ``read_backend="reference"`` gives the
fused-vs-reference throughput ratio on identical store contents.

The modeled half keeps the original host metadata-table sweep (hit rates
by cache size, two-pipe completion-time model, NoLB ablation) — the
Fig. 16 curve shape the measured meters are compared against."""
from __future__ import annotations

import time

import numpy as np

from repro.core import HoneycombConfig, HoneycombStore
from repro.core.keys import int_key
from repro.core.schema import NodeImageLayout
from .common import emit, uniform_sampler, zipf_sampler

FAST_BPS = 4.0e9     # modeled on-board DRAM pipe
SLOW_BPS = 1.3e9     # modeled PCIe pipe (13 GB/s / 10 for scale)


def _measured_point(cfg: HoneycombConfig, n_items: int, n_ops: int,
                    batch: int = 256) -> dict:
    """One Zipfian GET workload through the live store at ``cfg``,
    returning throughput plus the device cache/pipe meters."""
    st = HoneycombStore(cfg)
    rng = np.random.default_rng(0)
    for i in rng.permutation(n_items):
        st.put(int_key(int(i)), b"v" * 16)
    st.export_snapshot()
    sampler = zipf_sampler(n_items, seed=19)
    keys = [int_key(int(k)) for k in sampler(n_ops)]
    st.get_batch(keys[:batch])            # warm the jit bucket
    s0 = st.cache.stats
    v0, h0, r0 = s0.vmem_hits, s0.heap_gathers, s0.lb_routed
    t0 = time.perf_counter()
    for i in range(0, n_ops, batch):
        st.get_batch(keys[i:i + batch])
    dt = time.perf_counter() - t0
    s = st.cache.stats
    node_b = NodeImageLayout.for_config(cfg).node_image_bytes
    vmem, heap = s.vmem_hits - v0, s.heap_gathers - h0
    total = vmem + heap
    return {
        "ops_per_s": n_ops / dt,
        "vmem_hits": vmem, "heap_gathers": heap,
        "lb_routed": s.lb_routed - r0,
        "device_hit_rate": vmem / total if total else 0.0,
        # dual-pipe byte split: each resolved level moves one node image
        "vmem_bytes": vmem * node_b, "heap_bytes": heap * node_b,
    }


def run(n_items: int = 8192, n_ops: int = 4096,
        read_backend: tuple[str, ...] = ("fused", "reference"),
        lb_fractions: tuple[float, ...] = (0.0, 0.25, 0.5)) -> dict:
    results = {}
    # ---- measured: the real device read path, both backends ----------
    tput = {}
    for rb in read_backend:
        fracs = lb_fractions if rb == "fused" else (0.0,)
        for frac in fracs:
            cfg = HoneycombConfig(read_backend=rb, lb_fraction=frac)
            r = _measured_point(cfg, n_items, n_ops)
            name = f"measured_{rb}" + (f"_lb{frac:g}" if frac else "")
            results[name] = r
            tput.setdefault(rb, r["ops_per_s"])
            emit(name, 1e6 / r["ops_per_s"],
                 f"hit={r['device_hit_rate']:.2f} "
                 f"vmem_B={r['vmem_bytes']} heap_B={r['heap_bytes']} "
                 f"lb_routed={r['lb_routed']}")
    if "fused" in tput and "reference" in tput:
        ratio = tput["fused"] / tput["reference"]
        results["measured_fused_vs_reference"] = {"tput_ratio": ratio}
        emit("cache_lb_fused_vs_reference", 0.0,
             f"tput_ratio={ratio:.2f}x")
    # ---- modeled: host metadata-table sweep (the Fig. 16 shape) ------
    for cache_slots, lb in ((8, True), (64, True), (256, True),
                            (256, False)):
        cfg = HoneycombConfig(cache_slots=cache_slots, load_balance=lb)
        st = HoneycombStore(cfg)
        rng = np.random.default_rng(0)
        for i in rng.permutation(n_items):
            st.put(int_key(int(i)), b"v" * 16)
        st.export_snapshot()
        cache = st.cache
        sampler = uniform_sampler(n_items, 19)
        tree = st.tree
        nbytes = cfg.header_bytes + cfg.shortcut_bytes + cfg.segment_bytes
        for k in sampler(n_ops):
            klanes, klen = tree._pack(int_key(int(k)))
            lid = tree.root_lid
            for _ in range(tree.height - 1):
                phys = tree.pt.lookup(lid)
                cache.route(lid, phys, nbytes)
                lid, _ = tree._interior_child(phys, klanes, klen)
        stats = cache.stats
        # two-pipe completion-time model: both pipes drain concurrently
        t_fast = stats.fast_bytes / FAST_BPS
        t_slow = stats.slow_bytes / SLOW_BPS
        t = max(t_fast, t_slow)
        mtput = n_ops / t if t else float("inf")
        name = f"cache{cache_slots}_{'lb' if lb else 'nolb'}"
        results[name] = {"hit_rate": stats.hit_rate,
                         "fast_bytes": stats.fast_bytes,
                         "slow_bytes": stats.slow_bytes,
                         "modeled_ops_s": mtput}
        emit(name, 1e6 * t / n_ops,
             f"hit={stats.hit_rate:.2f} modeled_ops_s={mtput:.2e}")
    return results


if __name__ == "__main__":
    run()
