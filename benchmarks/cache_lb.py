"""Fig. 16: interior-node cache + load balancer.  The cache model meters
hit rates and fast/slow-path byte flows; removing the balancer (NoLB)
leaves the slow path idle while the fast path saturates — reproduced via
the two paths' byte counters and a two-pipe service-time model."""
from __future__ import annotations

import numpy as np

from repro.core import HoneycombConfig, HoneycombStore
from repro.core.cache import InteriorCache
from repro.core.keys import int_key
from .common import emit, uniform_sampler

FAST_BPS = 4.0e9     # modeled on-board DRAM pipe
SLOW_BPS = 1.3e9     # modeled PCIe pipe (13 GB/s / 10 for scale)


def run(n_items: int = 8192, n_ops: int = 4096) -> dict:
    results = {}
    for cache_slots, lb in ((8, True), (64, True), (256, True),
                            (256, False)):
        cfg = HoneycombConfig(cache_slots=cache_slots, load_balance=lb)
        st = HoneycombStore(cfg)
        rng = np.random.default_rng(0)
        for i in rng.permutation(n_items):
            st.put(int_key(int(i)), b"v" * 16)
        st.export_snapshot()
        cache = st.cache
        sampler = uniform_sampler(n_items, 19)
        tree = st.tree
        nbytes = cfg.header_bytes + cfg.shortcut_bytes + cfg.segment_bytes
        for k in sampler(n_ops):
            klanes, klen = tree._pack(int_key(int(k)))
            lid = tree.root_lid
            for _ in range(tree.height - 1):
                phys = tree.pt.lookup(lid)
                cache.route(lid, phys, nbytes)
                lid, _ = tree._interior_child(phys, klanes, klen)
        stats = cache.stats
        # two-pipe completion-time model: both pipes drain concurrently
        t_fast = stats.fast_bytes / FAST_BPS
        t_slow = stats.slow_bytes / SLOW_BPS
        t = max(t_fast, t_slow)
        tput = n_ops / t if t else float("inf")
        name = f"cache{cache_slots}_{'lb' if lb else 'nolb'}"
        results[name] = {"hit_rate": stats.hit_rate,
                         "fast_bytes": stats.fast_bytes,
                         "slow_bytes": stats.slow_bytes,
                         "modeled_ops_s": tput}
        emit(name, 1e6 * t / n_ops,
             f"hit={stats.hit_rate:.2f} modeled_ops_s={tput:.2e}")
    return results


if __name__ == "__main__":
    run()
