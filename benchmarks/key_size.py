"""Fig. 14: 1-item SCAN throughput vs key/value size.  Both systems slow
with larger keys; Honeycomb's tree depth is stable (large nodes) while the
bytes per fetched segment grow — reproduced via the byte model as well."""
from __future__ import annotations

import numpy as np

from repro.core import HoneycombConfig, HoneycombStore
from repro.baselines.cpu_store import CpuOrderedStore
from .common import emit, run_mixed, uniform_sampler
from repro.core.keys import int_key


def run(n_items: int = 2048, n_ops: int = 512) -> dict:
    results = {}
    for key_bytes in (8, 16, 32):
        kw = max(2, key_bytes // 4)
        cfg = HoneycombConfig(key_words=kw, val_words=max(2, kw // 2))
        hc = HoneycombStore(cfg)
        cp = CpuOrderedStore()
        pad = key_bytes - 8
        rng = np.random.default_rng(0)
        for i in rng.permutation(n_items):
            k = int_key(int(i)) + b"p" * pad
            v = bytes(key_bytes)
            hc.put(k, v)
            cp.put(k, v)
        hc.export_snapshot()

        import time
        ks = [int_key(int(i)) + b"p" * pad
              for i in uniform_sampler(n_items, 13)(n_ops)]
        t0 = time.perf_counter()
        for i in range(0, n_ops, 256):
            hc.scan_batch([(k, k) for k in ks[i:i + 256]])
        h = n_ops / (time.perf_counter() - t0)
        t0 = time.perf_counter()
        for k in ks:
            cp.scan(k, k, max_items=1)
        c = n_ops / (time.perf_counter() - t0)
        results[key_bytes] = {"honeycomb_ops_s": h, "baseline_ops_s": c,
                              "speedup": h / c}
        emit(f"keysize_{key_bytes}B", 1e6 / h, f"speedup={h / c:.2f}x")
    return results


if __name__ == "__main__":
    run()
