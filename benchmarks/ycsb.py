"""Fig. 10: YCSB A-F, uniform and zipfian, Honeycomb vs CPU baseline.

Workloads (Table 2): A 50/50 update, B 95/5, C read-only, D 95/5 insert,
E scan-heavy (1..100-item scans, here capped for CPU scale), F
read-modify-write.  Reported: ops/s and ops/s/W (TDP model from the paper).

Shards are a sweep axis: the same workloads drive the live range-sharded
``ShardedHoneycombStore`` (the paper's Section 7 scale-out shape), with
per-shard sync bytes/op and router load imbalance metered alongside the
single-device numbers.

Pipeline is a second axis (``--pipeline serial,pipelined``): the same
workloads drive the typed service front end (``HoneycombService`` with
first-class ``Put``/``Get``/``Scan`` op messages — core/api.py, routing
self-wired from the store) through its epoch pipeline in each mode,
reporting pipelined-vs-serial throughput and the sync-stall-time meter
(serial blocks on the sync barrier every epoch; pipelined overlaps the
standby scatters with read dispatch — see core/pipeline.py).

Replicas are a third axis (``--replicas 1,2,4``): the read-heavy workloads
(B, C — uniform and the zipfian skew where read spreading wins, per F2)
drive the replicated store (core/replica.py) with round-robin read
spreading, reporting the read-throughput-vs-replicas curve plus the
sync-bytes-amplification curve (follower feed bytes per op on top of the
primary's sync traffic).

Feed is a fourth axis (``--feed log,delta`` x ``--relay-depth 0,2``): the
write-heavy workload A drives the replicated store under both follower
feeds, reporting the per-follower feed-bytes-per-epoch amplification
curve — the log-shipping artifact: the log feed ships the epoch's encoded
op wire stream (~tens of bytes per write) where the delta feed ships
whole dirty image rows (~5 KB each), so per-follower feed bytes collapse
by >=10x; epochs whose tree shape changed fall back to the image delta
and are excluded from the ratio but reported alongside it.  Relay depth
reshapes WHO pays: the total feed bytes are topology-invariant while the
primary's own egress drops to its O(fanout) direct edges.
"""
from __future__ import annotations

import dataclasses

from repro.core import HoneycombConfig
from repro.core.keys import int_key
from repro.kernels import ops as kernel_ops

from .common import (TDP_BASELINE_W, TDP_HONEYCOMB_W, build_stores, emit,
                     run_mixed, run_scheduled, uniform_sampler, zipf_sampler)

WORKLOADS = {
    "A": dict(read_frac=0.5, scan_items=0),
    "B": dict(read_frac=0.95, scan_items=0),
    "C": dict(read_frac=1.0, scan_items=0),
    "D": dict(read_frac=0.95, scan_items=0),
    "E": dict(read_frac=0.95, scan_items=8),
    "F": dict(read_frac=0.666, scan_items=0),
}


def run(n_items: int = 4096, n_ops: int = 2048,
        shards: tuple[int, ...] = (1,),
        pipeline: tuple[str, ...] = (),
        replicas: tuple[int, ...] = (),
        feed: tuple[str, ...] = (),
        relay_depth: tuple[int, ...] = (),
        read_backend: tuple[str, ...] = ()) -> dict:
    results = {}
    # read-backend axis: the read-heavy workloads through the fused
    # megakernel path (ONE dispatch per batch, cache tier in VMEM —
    # kernels/fused_read.py) vs the staged jnp reference, on identical
    # store contents; dispatched-launch counts come from the launch meter
    rb_tput = {}
    for rb in read_backend:
        hb, _ = build_stores(n_items, baseline=False,
                             cfg=HoneycombConfig(read_backend=rb))
        kernel_ops.reset_read_dispatches()
        for wl in ("C", "B"):
            r = run_mixed(hb, zipf_sampler(n_items, seed=3), n_ops=n_ops,
                          n_items=n_items, **WORKLOADS[wl])
            rb_tput[(wl, rb)] = r["ops_per_s"]
            cs = hb.cache.stats
            results[f"{wl}/zipfian/{rb}"] = {
                "honeycomb_ops_s": r["ops_per_s"], "read_backend": rb,
                "device_hit_rate": cs.device_hit_rate,
                "vmem_hits": cs.vmem_hits, "heap_gathers": cs.heap_gathers,
                "sync": r["sync"]}
            emit(f"ycsb_{wl}_zipfian_{rb}", 1e6 / r["ops_per_s"],
                 f"ops_s={r['ops_per_s']:.0f} "
                 f"hit={cs.device_hit_rate:.2f} "
                 f"vmem={cs.vmem_hits} heap={cs.heap_gathers}")
        results[f"dispatch/{rb}"] = kernel_ops.read_dispatch_stats()
    for wl in ("C", "B"):
        if (wl, "fused") in rb_tput and (wl, "reference") in rb_tput:
            ratio = rb_tput[(wl, "fused")] / rb_tput[(wl, "reference")]
            results[f"{wl}/fused_vs_reference"] = {"tput_ratio": ratio}
            emit(f"ycsb_{wl}_fused_vs_reference", 0.0,
                 f"tput_ratio={ratio:.2f}x")
    # feed axis: write-heavy A over log vs delta follower feeds and relay
    # depths — per-follower feed bytes per epoch is the amplification
    # artifact (acceptance: pure log feed <= 10% of the delta feed's,
    # fallback epochs excluded from the ratio and reported)
    per_follower = {}
    for nr in replicas if (feed or relay_depth) else ():
        if nr < 2:
            continue
        for fd in feed or ("log",):
            for depth in relay_depth or (0,):
                hf, _ = build_stores(n_items, shards=1, replicas=nr,
                                     replica_policy="round_robin", feed=fd,
                                     relay_depth=depth, baseline=False,
                                     force_router=True)
                fs0 = dataclasses.asdict(hf.shards[0].feed_stats)
                r = run_mixed(hf, uniform_sampler(n_items, seed=3),
                              n_ops=n_ops, n_items=n_items, batch=64,
                              **WORKLOADS["A"])
                d = {k: v - fs0[k] for k, v in
                     dataclasses.asdict(hf.shards[0].feed_stats).items()}
                nf = nr - 1
                if fd == "log":       # pure log deliveries only
                    per_fe = d["log_bytes"] / max(d["log_feed_epochs"] * nf,
                                                  1)
                else:                 # delta deliveries minus catch-ups
                    per_fe = ((d["feed_bytes"] - d["catchup_bytes"])
                              / max(d["delta_feed_epochs"] * nf, 1))
                per_follower[(nr, depth, fd)] = per_fe
                key = f"A/feed/{fd}/replicas{nr}/depth{depth}"
                results[key] = {
                    "honeycomb_ops_s": r["ops_per_s"], "replicas": nr,
                    "feed": fd, "relay_depth": depth,
                    "per_follower_feed_B_per_epoch": per_fe,
                    "feed_delta": d, "sync": r["sync"]}
                emit(f"ycsb_A_feed_{fd}_r{nr}_d{depth}",
                     1e6 / r["ops_per_s"],
                     f"perF_B/epoch={per_fe:.0f} "
                     f"feed_B={d['feed_bytes']} "
                     f"egress_B={d['primary_egress_bytes']} "
                     f"relay_B={d['relay_hop_bytes']} "
                     f"fallbacks={d['log_fallback_epochs']}")
    for (nr, depth, fd), log_b in sorted(per_follower.items()):
        if fd != "log" or (nr, depth, "delta") not in per_follower:
            continue
        ratio = log_b / max(per_follower[(nr, depth, "delta")], 1e-9)
        results[f"A/feed_ratio/replicas{nr}/depth{depth}"] = {
            "log_over_delta": ratio, "replicas": nr, "relay_depth": depth}
        emit(f"ycsb_A_feed_ratio_r{nr}_d{depth}", 0.0,
             f"log/delta={ratio:.4f} (target<=0.10, fallbacks excluded)")
    # replication axis: read-heavy workloads over growing replica sets —
    # read throughput should scale with serving lanes while writes (and
    # their delta feed) stay on the primary; the amplification meter is
    # the cost side of that curve
    warmed = not replicas
    for nr in replicas:
        # force_router: the replicas=1 baseline point runs the SAME routed
        # facade as the replicated points, so the curve compares like
        # against like
        hr, _ = build_stores(n_items, shards=1, replicas=nr,
                             replica_policy="round_robin", baseline=False,
                             force_router=True)
        if not warmed:
            # pre-compile the read-path and delta-scatter jit buckets once
            # (shapes are identical across replica counts) so compile time
            # is not charged to the sweep's first point
            for b in (1, 2, 4, 8, 16, 32, 64):
                hr.get_batch([int_key(0)] * b)
            for w in (4, 16, 48):
                for i in range(w):
                    hr.update(int_key(i), b"x" * 16)
                hr.export_snapshot()
            warmed = True
        for wl, dist in (("C", "zipfian"), ("B", "zipfian"),
                         ("B", "uniform")):
            mk = zipf_sampler if dist == "zipfian" else uniform_sampler
            lanes0 = [list(ops) for ops in hr.per_shard_replica_ops]
            # smaller read bursts than the default so even tiny runs
            # dispatch several batches — one policy pick per batch is what
            # spreads the load over replica lanes
            r = run_mixed(hr, mk(n_items, seed=3), n_ops=n_ops,
                          n_items=n_items, batch=64, **WORKLOADS[wl])
            sync = r["sync"]
            amp = sync["replication_bytes"] / max(r["ops"], 1)
            # THIS workload's per-lane spread (the store is reused, so the
            # lifetime counters must be diffed per run)
            lanes = [b - a for a, b in
                     zip(lanes0[0], hr.per_shard_replica_ops[0])]
            results[f"{wl}/{dist}/replicas{nr}"] = {
                "honeycomb_ops_s": r["ops_per_s"], "replicas": nr,
                "replica_ops": lanes, "sync": sync}
            emit(f"ycsb_{wl}_{dist}_r{nr}", 1e6 / r["ops_per_s"],
                 f"reads/s={r['ops_per_s']:.0f} replicas={nr} "
                 f"repl_B/op={amp:.0f} sync_B/op={sync['bytes_per_op']:.0f} "
                 f"lanes={lanes}")
    for ns in shards if isinstance(shards, (tuple, list)) else (shards,):
        hc, cp = build_stores(n_items, shards=ns)
        tag = "" if ns == 1 else f"/s{ns}"
        for dist in ("uniform", "zipfian"):
            for wl, spec in WORKLOADS.items():
                mk = uniform_sampler if dist == "uniform" else zipf_sampler
                r_h = run_mixed(hc, mk(n_items, seed=3), n_ops=n_ops,
                                n_items=n_items, **spec)
                r_c = run_mixed(cp, mk(n_items, seed=3), n_ops=n_ops,
                                n_items=n_items, is_honeycomb=False, **spec)
                h, c = r_h["ops_per_s"], r_c["ops_per_s"]
                eff_h = h / TDP_HONEYCOMB_W
                eff_c = c / TDP_BASELINE_W
                sync = r_h["sync"]
                results[f"{wl}/{dist}{tag}"] = {
                    "honeycomb_ops_s": h, "baseline_ops_s": c,
                    "speedup": h / c, "eff_ratio": eff_h / eff_c,
                    "shards": ns, "sync": sync}
                extra = ""
                if "load_imbalance" in sync:
                    extra = f" imbal={sync['load_imbalance']:.2f}"
                emit(f"ycsb_{wl}_{dist}{tag.replace('/', '_')}", 1e6 / h,
                     f"speedup={h / c:.2f}x eff={eff_h / eff_c:.2f}x "
                     f"sync_B/op={sync['bytes_per_op']:.0f} "
                     f"wire_B={sync['log_wire_bytes']} "
                     f"deltas={sync['delta_syncs']}/{sync['snapshots']} "
                     f"pt_cmds={sync['pagetable_commands']}{extra}")
        # pipeline axis: scheduler-driven epochs, serial vs pipelined, on
        # a write-heavy and a scan-heavy point (A, E) where the sync
        # barrier matters most
        for mode in pipeline:
            for wl in ("A", "E"):
                hp, _ = build_stores(n_items, shards=ns, baseline=False)
                r = run_scheduled(hp, uniform_sampler(n_items, seed=3),
                                  n_ops=n_ops, n_items=n_items,
                                  pipeline=mode, **WORKLOADS[wl])
                results[f"{wl}/pipeline{tag}/{mode}"] = r
                emit(f"ycsb_{wl}{tag.replace('/', '_')}_{mode}",
                     1e6 / r["ops_per_s"],
                     f"stall_s={r['sync_stall_s']:.3f} "
                     f"stall_frac={r['stall_fraction']:.2f} "
                     f"syncs={r['syncs']} epochs={r['epochs']} "
                     f"occ={r['lane_occupancy']:.2f}")
    return results


if __name__ == "__main__":
    run(shards=(1, 4), pipeline=("serial", "pipelined"), replicas=(1, 2, 4),
        feed=("log", "delta"), relay_depth=(0, 2))
