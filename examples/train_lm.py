"""End-to-end training driver example: train a ~10M-parameter qwen-family
model for a few hundred steps with checkpoint/restart and straggler
telemetry — the full substrate (data pipeline, optimizer, checkpoint
catalog on a Honeycomb store) at laptop scale.

Run:  PYTHONPATH=src python examples/train_lm.py
"""
import dataclasses
import shutil

from repro.configs import get_smoke_config
from repro.train.train_loop import LoopConfig, build_smoke_loop

CKPT = "/tmp/repro_example_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = dataclasses.replace(get_smoke_config("qwen2p5_3b"),
                          n_layers=4, d_model=128, d_ff=256, vocab=512)
loop = build_smoke_loop(cfg, batch=16, seq=64, ckpt_dir=CKPT,
                        loop_cfg=LoopConfig(total_steps=200, ckpt_every=100,
                                            log_every=20))
summary = loop.run()
print("metrics:")
for m in loop.metrics_log:
    print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
          f"gnorm {m['gnorm']:.3f}  {m['step_time_s']*1e3:.0f} ms")
print("summary:", summary)
first, last = loop.metrics_log[0]["loss"], loop.metrics_log[-1]["loss"]
print(f"loss {first:.3f} -> {last:.3f} "
      f"({'LEARNING' if last < first - 0.5 else 'check lr'})")

# restart drill: restore from the Honeycomb-cataloged checkpoint
print("\ncheckpoint catalog steps:", loop.ckpt.all_steps())
print("restore floor lookup latest<=150:", loop.ckpt.latest_step(150))
loop.pipeline.close()
