"""LLM serving with a Honeycomb-indexed paged KV cache.

Demonstrates the paper's technique as a serving-framework feature: page
tables are an ordered store (host writes allocate/free pages, the
accelerator path resolves block tables in batch), continuous batching, and
real token generation on a reduced qwen config.

Run:  PYTHONPATH=src python examples/kv_serving.py
"""
import time

import numpy as np

from repro.configs import get_smoke_config
from repro.serving.engine import ServingEngine

cfg = get_smoke_config("qwen2p5_3b")
eng = ServingEngine(cfg, batch_size=4, max_seq=128, page_size=16)

rng = np.random.default_rng(0)
t0 = time.perf_counter()
rids = [eng.submit(rng.integers(1, cfg.vocab, (rng.integers(8, 24),)),
                   max_new_tokens=8) for _ in range(8)]
outs = eng.run_until_done()
dt = time.perf_counter() - t0

print(f"served {len(outs)} requests / {eng.stats['tokens']} tokens "
      f"in {dt:.1f}s")
print(f"engine stats: {eng.stats}")
t = eng.kv.table
print(f"honeycomb page table: puts={t.stats.puts} deletes={t.stats.deletes} "
      f"log-appends={t.stats.fast_path} merges={t.stats.merges}")
print(f"page-table sync commands (the 'PCIe' metric the log block "
      f"amortizes): {t.tree.pt.sync_commands}")
for rid in rids[:4]:
    print(f"  rid {rid}: {outs[rid]}")
