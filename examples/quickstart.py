"""Quickstart: the Honeycomb ordered store in five minutes.

Covers the paper's core loop: host writes (PUT/UPDATE/DELETE, log blocks,
merges, splits) + accelerator reads (batched wait-free GET/SCAN with MVCC
snapshots) + the PCIe-sync accounting the design exists to amortize.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import random

from repro.core import HoneycombConfig, HoneycombStore
from repro.core.keys import int_key

random.seed(7)

# a store with small nodes so structure changes are visible at toy scale
store = HoneycombStore(HoneycombConfig(node_cap=16, log_cap=4,
                                       n_shortcuts=4))

# --- host-side writes (the CPU half of the paper) --------------------------
print("== writes ==")
for i in range(500):
    store.put(int_key(i), f"value-{i}".encode())
for i in range(0, 500, 7):
    store.update(int_key(i), f"updated-{i}".encode())
for i in range(0, 500, 13):
    store.delete(int_key(i))
s = store.stats
print(f"puts={s.puts} updates={s.updates} deletes={s.deletes}")
print(f"fast-path appends={s.fast_path} merges={s.merges} "
      f"splits={s.splits} tree-height={store.tree.height}")

# --- accelerator-side batched reads (the FPGA half) -------------------------
print("\n== batched GET (wait-free, MVCC) ==")
keys = [int_key(i) for i in (0, 1, 7, 13, 490, 499)]
for k, v in zip(keys, store.get_batch(keys)):
    print(f"  {int.from_bytes(k, 'big'):4d} -> {v}")

print("\n== batched SCAN (floor-start semantics, Section 3.3) ==")
ranges = [(int_key(100), int_key(104)), (int_key(250), int_key(254))]
for (lo, hi), items in zip(ranges, store.scan_batch(ranges)):
    lo_i, hi_i = int.from_bytes(lo, 'big'), int.from_bytes(hi, 'big')
    got = [(int.from_bytes(k, 'big'), v.decode()) for k, v in items]
    print(f"  scan[{lo_i},{hi_i}] -> {got}")

# --- the synchronization the log blocks amortize ----------------------------
print("\n== host->accelerator sync accounting ==")
print(f"page-table commands: {store.tree.pt.sync_commands} "
      f"(1 per merge/split, NOT 1 per write)")
print(f"read-version updates: {store.tree.versions.device_updates}")
print(f"garbage list: {len(store.tree.gc.list)} entries; "
      f"reclaimed now: {store.collect_garbage()}")
