#!/usr/bin/env bash
# Tier-1 verification gate (fast, deterministic).
#
#   scripts/verify.sh          # fast gate: everything not marked slow
#   scripts/verify.sh --all    # full suite, including slow tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--all" ]]; then
    exec python -m pytest -x -q
fi
exec python -m pytest -x -q -m "not slow"
