#!/usr/bin/env bash
# Tier-1 verification gate (fast, deterministic).
#
#   scripts/verify.sh          # fast gate: everything not marked slow
#   scripts/verify.sh --all    # full suite, including slow tests
#   scripts/verify.sh --smoke  # benchmark smoke only (tiny sizes): the
#                              # HoneycombService smoke (typed op messages,
#                              # submit_many + drain over a replicated
#                              # sharded store, wire-codec roundtrip),
#                              # serial-vs-pipelined YCSB+latency plus a
#                              # --replicas 1,2 read-spreading sweep and a
#                              # --feed log,delta x --relay-depth 0,2
#                              # follower-feed amplification sweep, the
#                              # log-block sweep on BOTH snapshot layouts
#                              # (packed one-DMA-per-dirty-node vs legacy
#                              # per-field), a --read-backend
#                              # fused,reference sweep of the device read
#                              # path (fused megakernels + VMEM cache tier
#                              # vs the jnp reference), and both
#                              # store_dryrun LIVE smokes (sharded +
#                              # replicated with the log-shipped feed
#                              # engaged and fused-vs-reference equality
#                              # + vmem_hits asserted) on the packed
#                              # layout; results land in
#                              # experiments/bench_results.json
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--all" ]]; then
    exec python -m pytest -x -q
fi
if [[ "${1:-}" == "--smoke" ]]; then
    python -m benchmarks.run \
        service_api,fig10_ycsb,fig12_latency,fig17_log_block \
        --tiny --pipeline serial,pipelined --replicas 1,2 \
        --feed log,delta --relay-depth 0,2 \
        --layout packed,legacy --read-backend fused,reference --strict
    # live deployment-shape smokes on the packed layout: assert the
    # one-image-DMA-per-dirty-node invariant survives the full stack,
    # and that the replicated store actually shipped (and replayed) the
    # log feed rather than silently regressing to image-row deltas
    python - <<'EOF'
import json
from repro.launch.store_dryrun import live_replicated_smoke, live_sharded_smoke
sh = live_sharded_smoke(shards=2, n_items=256, batch=32)
assert sh["layout"] == "packed" and sh["image_dma_count"] > 0, sh
# fused read path: the cache tier actually served descend levels from
# VMEM, and the smoke's in-place fused-vs-reference equality held
assert sh["read_path"]["backend"] == "fused", sh
assert sh["read_path"]["vmem_hits"] > 0, sh
assert sh["read_path"]["fused_matches_reference"], sh
rp = live_replicated_smoke(shards=2, replicas=2, n_items=256, batch=32)
assert rp["layout"] == "packed" and rp["primary_image_dmas"] > 0, rp
feed = rp["feed"]
assert feed["log_feed_epochs"] > 0 and feed["log_replays"] > 0, feed
assert feed["log_bytes"] > 0 and feed["wire_bytes"] > 0, feed
# followers inherited the cache tier over the feeds and their fused
# reads matched the reference fallback
assert rp["read_path"]["vmem_hits"] > 0, rp
assert rp["read_path"]["followers_cache_resident"], rp
assert rp["read_path"]["fused_matches_reference"], rp
print(json.dumps({"live_sharded": sh, "live_replicated": rp},
                 indent=1, default=str))
EOF
    exit 0
fi
exec python -m pytest -x -q -m "not slow"
