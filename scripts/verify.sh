#!/usr/bin/env bash
# Tier-1 verification gate (fast, deterministic).
#
#   scripts/verify.sh           # fast gate: everything not marked slow
#   scripts/verify.sh --all     # full suite, including slow tests
#   scripts/verify.sh --analyze # honeylint static analysis + EpochSan:
#                               # the repo-specific AST lint pass
#                               # (raw-clock / aliased-publish /
#                               # magic-offset / stats-collect /
#                               # bare-except rules + the pinned
#                               # NODE_SCHEMA/wire-codec golden), the
#                               # kernel jaxpr checker over every Pallas
#                               # entry point (f64 / callbacks /
#                               # input_output_aliases on in-place
#                               # scatters / single-dispatch fusion /
#                               # VMEM block budget), both merged into
#                               # experiments/analysis_report.json, then
#                               # the epoch/replica test surface re-run
#                               # under HONEYCOMB_EPOCHSAN=1 (runtime
#                               # sanitizer at the staging/flip/dispatch/
#                               # GC seams); nonzero on any finding
#   scripts/verify.sh --smoke  # benchmark smoke only (tiny sizes): the
#                              # HoneycombService smoke (typed op messages,
#                              # submit_many + drain over a replicated
#                              # sharded store, wire-codec roundtrip),
#                              # serial-vs-pipelined YCSB+latency plus a
#                              # --replicas 1,2 read-spreading sweep and a
#                              # --feed log,delta x --relay-depth 0,2
#                              # follower-feed amplification sweep, the
#                              # log-block sweep on BOTH snapshot layouts
#                              # (packed one-DMA-per-dirty-node vs legacy
#                              # per-field), a --read-backend
#                              # fused,reference sweep of the device read
#                              # path (fused megakernels + VMEM cache tier
#                              # vs the jnp reference), and both
#                              # store_dryrun LIVE smokes (sharded +
#                              # replicated with the log-shipped feed
#                              # engaged and fused-vs-reference equality
#                              # + vmem_hits asserted) on the packed
#                              # layout, with telemetry asserts: the
#                              # Prometheus export parses, key meters are
#                              # nonzero, and a sampled replicated trace
#                              # carries the full submit->resolve span
#                              # chain; results land in
#                              # experiments/bench_results.json (+
#                              # metrics_snapshot.json, bench_trace.json)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--all" ]]; then
    exec python -m pytest -x -q
fi
if [[ "${1:-}" == "--analyze" ]]; then
    # static half: lint rules + schema golden + kernel jaxpr checks;
    # exits nonzero on any unbaselined finding
    python -m repro.analysis --json experiments/analysis_report.json
    # runtime half: the epoch/snapshot protocol surface under EpochSan
    # (strict mode — the first violated seam invariant raises there)
    HONEYCOMB_EPOCHSAN=1 python -m pytest -x -q -m "not slow" \
        tests/test_analysis.py tests/test_pipeline_engine.py \
        tests/test_replica.py tests/test_delta_sync.py \
        tests/test_scheduler_cache.py tests/test_log_feed.py
    exit 0
fi
if [[ "${1:-}" == "--smoke" ]]; then
    python -m benchmarks.run \
        service_api,fig10_ycsb,fig12_latency,fig17_log_block \
        --tiny --pipeline serial,pipelined --replicas 1,2 \
        --feed log,delta --relay-depth 0,2 \
        --layout packed,legacy --read-backend fused,reference \
        --metrics --strict
    # live deployment-shape smokes on the packed layout: assert the
    # one-image-DMA-per-dirty-node invariant survives the full stack,
    # and that the replicated store actually shipped (and replayed) the
    # log feed rather than silently regressing to image-row deltas
    python - <<'EOF'
import json
from repro.launch.store_dryrun import live_replicated_smoke, live_sharded_smoke
sh = live_sharded_smoke(shards=2, n_items=256, batch=32)
assert sh["layout"] == "packed" and sh["image_dma_count"] > 0, sh
# fused read path: the cache tier actually served descend levels from
# VMEM, and the smoke's in-place fused-vs-reference equality held
assert sh["read_path"]["backend"] == "fused", sh
assert sh["read_path"]["vmem_hits"] > 0, sh
assert sh["read_path"]["fused_matches_reference"], sh
rp = live_replicated_smoke(shards=2, replicas=2, n_items=256, batch=32)
assert rp["layout"] == "packed" and rp["primary_image_dmas"] > 0, rp
feed = rp["feed"]
assert feed["log_feed_epochs"] > 0 and feed["log_replays"] > 0, feed
assert feed["log_bytes"] > 0 and feed["wire_bytes"] > 0, feed
# followers inherited the cache tier over the feeds and their fused
# reads matched the reference fallback
assert rp["read_path"]["vmem_hits"] > 0, rp
assert rp["read_path"]["followers_cache_resident"], rp
assert rp["read_path"]["fused_matches_reference"], rp
# telemetry (core/telemetry.py): the Prometheus export must PARSE and the
# key meters of every wired stats surface must be live on the smokes
from repro.core import parse_prometheus, prom_value
for label, smoke in (("sharded", sh), ("replicated", rp)):
    tele = smoke["telemetry"]
    pv = parse_prometheus(tele["prometheus"])
    for meter in ("hc_sync_bytes_synced", "hc_sync_image_dma_count",
                  "hc_tree_puts", "hc_cache_vmem_hits",
                  "hc_pipeline_flips", "hc_read_batches",
                  "hc_read_get_latency_seconds_count"):
        assert prom_value(pv, meter) > 0, (label, meter, tele["prometheus"])
    assert tele["sampled_traces"] > 0, (label, tele)
assert prom_value(parse_prometheus(rp["telemetry"]["prometheus"]),
                  "hc_replication_log_feed_epochs") > 0, rp["telemetry"]
# one sampled replicated pipelined trace shows the full lifecycle chain
# with the (shard, replica, epoch, serving_version) stamps attached
tr = rp["telemetry"]["last_trace"]
spans = tr["spans"]
assert spans[0] == "submit" and spans[-1] == "resolve", tr
assert "dispatch" in spans or tr["kind"] in ("put", "update"), tr
assert {"shard", "replica", "epoch", "serving_version"} <= set(tr["tags"]), tr
print(json.dumps({"live_sharded": sh, "live_replicated": rp},
                 indent=1, default=str))
EOF
    # the smoke's --metrics artifacts exist and the trace file is
    # Chrome-trace-shaped (CI uploads both next to bench_results.json)
    python - <<'EOF'
import json
from pathlib import Path
snap = json.loads(Path("experiments/metrics_snapshot.json").read_text())
assert any(k.startswith("sync_") for k in snap), list(snap)[:5]
trace = json.loads(Path("experiments/bench_trace.json").read_text())
assert isinstance(trace.get("traceEvents"), list), trace.keys()
print(f"metrics snapshot keys: {len(snap)}; "
      f"trace events: {len(trace['traceEvents'])}")
EOF
    exit 0
fi
exec python -m pytest -x -q -m "not slow"
