#!/usr/bin/env bash
# Tier-1 verification gate (fast, deterministic).
#
#   scripts/verify.sh          # fast gate: everything not marked slow
#   scripts/verify.sh --all    # full suite, including slow tests
#   scripts/verify.sh --smoke  # benchmark smoke only (tiny sizes): the
#                              # HoneycombService smoke (typed op messages,
#                              # submit_many + drain over a replicated
#                              # sharded store, wire-codec roundtrip),
#                              # serial-vs-pipelined YCSB+latency plus a
#                              # --replicas 1,2 read-spreading sweep;
#                              # results land in experiments/bench_results.json
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--all" ]]; then
    exec python -m pytest -x -q
fi
if [[ "${1:-}" == "--smoke" ]]; then
    exec python -m benchmarks.run service_api,fig10_ycsb,fig12_latency \
        --tiny --pipeline serial,pipelined --replicas 1,2 --strict
fi
exec python -m pytest -x -q -m "not slow"
