"""Telemetry layer tests (core/telemetry.py).

Pins: histogram percentile accuracy against a sorted-array oracle (random
and adversarial distributions) and exact merge semantics; the one
injectable clock shared by shard/replica/scheduler; registry aggregation
equal to the old per-layer sums (the merge_stats move is a refactor, not
a behaviour change); the sampled trace lifecycle (span ordering,
epoch/serving-version tags matching the Response stamps on a replicated
pipelined store, ring-buffer bound, rate-0 => nothing allocated); the
Prometheus export round trip; and the all-six-surfaces snapshot.
"""
import numpy as np
import pytest

from repro.core import (CLOCK, Get, Histogram, HoneycombConfig,
                        HoneycombService, Put, ReplicationConfig,
                        ShardedHoneycombStore, TelemetryConfig, Tracer,
                        Update, merge_stats, parse_prometheus, prom_value,
                        uniform_int_boundaries)
from repro.core import replica as replica_mod
from repro.core import scheduler as scheduler_mod
from repro.core import shard as shard_mod
from repro.core.keys import int_key
from repro.core.shard import SyncStats

N_ITEMS = 96


def _traffic(svc, n_items, ops=48, seed=3):
    rng = np.random.default_rng(seed)
    tickets = svc.submit_many(
        op for _ in range(ops // 2)
        for op in (Update(int_key(int(rng.integers(0, n_items))), b"t" * 8),
                   Get(int_key(int(rng.integers(0, n_items))))))
    out = svc.drain()
    return tickets, out


@pytest.fixture(scope="module")
def replicated_service():
    """One replicated sharded pipelined store + a rate-1 traced service,
    drained once — the shared subject for the aggregation/trace tests."""
    st = ShardedHoneycombStore(
        HoneycombConfig(), heap_capacity=512, shards=2,
        boundaries=uniform_int_boundaries(N_ITEMS, 2),
        replication=ReplicationConfig(replicas=2, policy="round_robin"))
    rng = np.random.default_rng(7)
    for i in rng.permutation(N_ITEMS):
        st.put(int_key(int(i)), b"v" * 8)
    st.export_snapshot()
    svc = HoneycombService(
        st, batch_size=8, pipeline="pipelined",
        telemetry=TelemetryConfig(trace_sample_rate=1.0,
                                  trace_capacity=4096))
    tickets, out = _traffic(svc, N_ITEMS)
    epochs_after = list(st.per_shard_epochs)
    return st, svc, tickets, out, epochs_after


# ----------------------------------------------------------------- histogram
BUCKET_FACTOR = 10.0 ** (1.0 / 16)       # one default bucket's ratio


def _oracle(data, p):
    return float(np.percentile(np.asarray(data), p,
                               method="inverted_cdf"))


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "heavy_tail",
                                  "two_point", "constant"])
def test_histogram_percentiles_vs_oracle(dist):
    rng = np.random.default_rng(11)
    data = {
        "lognormal": np.exp(rng.normal(-8.0, 1.5, 4000)),
        "uniform": rng.uniform(1e-5, 1e-2, 4000),
        "heavy_tail": np.concatenate([rng.uniform(1e-6, 1e-5, 3900),
                                      rng.uniform(0.1, 10.0, 100)]),
        "two_point": np.array([1e-4] * 900 + [1e-1] * 100),
        "constant": np.full(1000, 3.3e-3),
    }[dist]
    h = Histogram()
    for v in data:
        h.record(float(v))
    assert h.count == len(data)
    assert h.total == pytest.approx(float(np.sum(data)), rel=1e-9)
    assert h.vmin == float(np.min(data)) and h.vmax == float(np.max(data))
    for p in (50, 95, 99, 99.9):
        est, want = h.percentile(p), _oracle(data, p)
        # accuracy contract: within one bucket ratio of the rank oracle
        # (plus epsilon for the clamp at the observed extremes)
        assert want / (BUCKET_FACTOR * 1.01) <= est <= \
            want * BUCKET_FACTOR * 1.01, (dist, p, est, want)


def test_histogram_constant_is_exact():
    h = Histogram()
    for _ in range(100):
        h.record(2.5e-4)
    for p in (50, 99, 99.9):
        assert h.percentile(p) == pytest.approx(2.5e-4)


def test_histogram_under_overflow_and_weighted():
    h = Histogram(lo=1e-3, hi=1e0)
    h.record(1e-6, n=10)                 # underflow bucket
    h.record(50.0, n=2)                  # overflow bucket
    assert h.count == 12
    assert h.percentile(50) == pytest.approx(1e-6)   # clamped to vmin
    assert h.percentile(99.9) == pytest.approx(50.0)  # clamped to vmax
    hw, hs = Histogram(), Histogram()
    hw.record(1e-4, n=5)
    for _ in range(5):
        hs.record(1e-4)
    assert hw.counts == hs.counts and hw.count == hs.count
    assert hw.total == pytest.approx(hs.total)


def test_histogram_merge_equals_union():
    rng = np.random.default_rng(5)
    a, b = rng.uniform(1e-6, 1e-1, 500), np.exp(rng.normal(-6, 2, 500))
    ha, hb, hu = Histogram(), Histogram(), Histogram()
    for v in a:
        ha.record(float(v))
    for v in b:
        hb.record(float(v))
    for v in np.concatenate([a, b]):
        hu.record(float(v))
    ha.merge(hb)
    assert ha.counts == hu.counts
    assert ha.count == hu.count
    assert ha.total == pytest.approx(hu.total)
    assert ha.vmin == hu.vmin and ha.vmax == hu.vmax
    for p in (50, 95, 99, 99.9):
        assert ha.percentile(p) == hu.percentile(p)
    with pytest.raises(AssertionError):
        ha.merge(Histogram(lo=1e-6))     # geometry mismatch refuses


# --------------------------------------------------------------------- clock
def test_one_clock_everywhere():
    """The satellite's point: shard, replica and scheduler read THE same
    injectable clock object — freezing it freezes all three."""
    assert shard_mod._now is CLOCK
    assert replica_mod._now is CLOCK
    assert scheduler_mod._now is CLOCK
    with CLOCK.frozen(100.0):
        assert shard_mod._now() == 100.0
        assert scheduler_mod._now() == 100.0
        CLOCK.advance(2.5)
        assert replica_mod._now() == 102.5
    t0 = CLOCK()                          # unfrozen again: monotonic
    assert CLOCK() >= t0


def test_frozen_clock_zeroes_stage_timings():
    st = ShardedHoneycombStore(HoneycombConfig(), heap_capacity=512,
                               shards=1)
    for i in range(32):
        st.put(int_key(i), b"v" * 8)
    with CLOCK.frozen(50.0):
        svc = HoneycombService(st, batch_size=8)
        _traffic(svc, 32, ops=16)
        assert svc.stats.admit_s == 0.0
        assert svc.stats.sync_stall_s == 0.0
        assert svc.stats.dispatch_s == 0.0


# -------------------------------------------------- aggregation regression
def test_registry_aggregates_equal_per_layer_sums(replicated_service):
    st, svc, _, _, _ = replicated_service
    tm = svc.telemetry
    # sync (primaries): registry == router aggregate == hand sum
    assert tm.value("sync_log_entries", src="primary") == \
        st.sync_stats.log_entries == \
        sum(sh.sync_stats.log_entries for sh in st.shards)
    assert tm.value("sync_bytes_synced", src="primary") == \
        st.sync_stats.bytes_synced
    # replication amplification (followers)
    assert tm.value("sync_bytes_synced", src="followers") == \
        st.replication_stats.bytes_synced == \
        sum(f.sync_stats.bytes_synced
            for sh in st.shards for f in sh.followers)
    # tree, pipeline (store side), cache, feed
    assert tm.value("tree_puts") == st.stats.puts == \
        sum(sh.stats.puts for sh in st.shards)
    assert tm.value("pipeline_flips", src="store") == \
        st.pipeline_stats.flips
    assert tm.value("cache_vmem_hits") == st.cache_stats.vmem_hits == \
        sum(sh.cache_stats.vmem_hits for sh in st.shards)
    assert tm.value("replication_feed_bytes") == st.feed_stats.feed_bytes
    # scheduler meters come in through the same registry
    assert tm.value("scheduler_applied_writes") == \
        svc.scheduler.applied_writes
    # delta_fraction merges by MAX (SyncStats.merge), not sum
    assert st.sync_stats.delta_fraction == \
        max(sh.sync_stats.delta_fraction for sh in st.shards)


def test_merge_stats_matches_manual_field_sums():
    a = SyncStats(snapshots=2, bytes_synced=100, delta_fraction=0.25)
    b = SyncStats(snapshots=3, bytes_synced=50, delta_fraction=0.75)
    agg = merge_stats([a, b], SyncStats)
    assert agg.snapshots == 5 and agg.bytes_synced == 150
    assert agg.delta_fraction == 0.75     # max-merged, per SyncStats.merge


def test_six_surfaces_in_one_snapshot(replicated_service):
    _, svc, _, _, _ = replicated_service
    snap = svc.metrics_snapshot()
    prefixes = {k.split("{")[0].split("_")[0] for k in snap}
    for want in ("sync", "tree", "pipeline", "cache", "replication",
                 "read", "scheduler"):
        assert want in prefixes, (want, sorted(prefixes))
    # the kernel meter rode in as plain tuples with op/backend labels
    assert any(k.startswith("read_batches{") for k in snap), sorted(snap)[:8]


# ---------------------------------------------------------------- exporters
def test_prometheus_round_trip(replicated_service):
    _, svc, _, _, _ = replicated_service
    text = svc.prometheus()
    parsed = parse_prometheus(text)      # raises on any unparseable line
    assert prom_value(parsed, "hc_sync_log_entries", src="primary") == \
        svc.telemetry.value("sync_log_entries", src="primary")
    assert prom_value(parsed, "hc_tree_puts") == \
        svc.telemetry.value("tree_puts")
    # histograms export as summaries with quantile + sum + count series
    assert prom_value(parsed, "hc_read_get_latency_seconds_count") > 0
    assert "hc_read_get_latency_seconds" in parsed
    with pytest.raises(ValueError):
        parse_prometheus("not a metric line at all {")


def test_chrome_trace_export(replicated_service):
    _, svc, _, _, _ = replicated_service
    ct = svc.chrome_trace()
    assert ct["traceEvents"], "no events exported"
    ev = ct["traceEvents"][0]
    for field in ("name", "ph", "ts", "dur", "pid", "tid", "args"):
        assert field in ev
    assert ev["ph"] == "X"
    assert all(e["dur"] >= 0.0 for e in ct["traceEvents"])


# ------------------------------------------------------------------ tracing
def test_trace_lifecycle_and_response_stamps(replicated_service):
    st, svc, tickets, out, epochs_after = replicated_service
    traces = {t.rid: t for t in svc.traces()}
    assert len(traces) == len(tickets)    # rate 1.0: every request traced
    for ticket in tickets:
        tr = traces[ticket.rid]
        resp = out[ticket.rid]
        names = tr.span_names()
        assert names[0] == "submit" and names[-1] == "resolve", names
        if ticket.op.IS_WRITE:
            assert "admit" in names, names
        else:
            assert "dispatch" in names, names
        assert "export_stage" in names and "flip" in names, names
        assert names.index("export_stage") < names.index("flip")
        # span times are ordered along the lifecycle
        starts = [s.t0 for s in tr.spans]
        assert starts == sorted(starts), names
        assert tr.t1 >= tr.t0
        # the finish stamps ARE the response stamps
        assert tr.tags["shard"] == resp.shard
        assert tr.tags["replica"] == resp.replica
        assert tr.tags["serving_version"] == resp.serving_version
        assert tr.tags["status"] == resp.status
        assert tr.tags["epoch"] == epochs_after[resp.shard]
        # the dispatch span carries the serving pins too
        if not ticket.op.IS_WRITE:
            disp = tr.spans[names.index("dispatch")]
            assert disp.tags["serving_version"] == resp.serving_version
            assert disp.tags["replica"] == resp.replica


def test_trace_ring_buffer_bound():
    st = ShardedHoneycombStore(HoneycombConfig(), heap_capacity=512,
                               shards=1)
    for i in range(32):
        st.put(int_key(i), b"v" * 8)
    svc = HoneycombService(
        st, batch_size=8,
        telemetry=TelemetryConfig(trace_sample_rate=1.0, trace_capacity=8))
    tickets, _ = _traffic(svc, 32, ops=40)
    tr = svc.traces()
    assert len(tr) == 8                   # bounded ring
    # the ring keeps the newest traces
    assert [t.rid for t in tr] == \
        sorted(t.rid for t in tickets)[-8:]
    assert svc.telemetry.tracer.sampled == len(tickets)


def test_sample_rate_zero_allocates_nothing():
    st = ShardedHoneycombStore(HoneycombConfig(), heap_capacity=512,
                               shards=1)
    for i in range(16):
        st.put(int_key(i), b"v" * 8)
    svc = HoneycombService(st, batch_size=8)      # default rate 0
    assert svc.telemetry is not None
    assert svc.telemetry.tracer is None           # no tracer object at all
    _traffic(svc, 16, ops=8)
    assert svc.traces() == []
    # the submit->resolve histogram only fills from traces => stays empty
    assert svc.scheduler._req_hist.count == 0


def test_tracer_deterministic_sampling():
    tr = Tracer(sample_rate=0.25, capacity=16)
    live = [tr.begin(rid, "get") is not None for rid in range(12)]
    assert live == [True, False, False, False] * 3
    assert tr.live_count == 3 and tr.sampled == 3
    assert not tr.is_live(1)              # unsampled rid allocated nothing
    tr.span(1, "dispatch", 0.0, 1.0)      # no-op, not an error
    assert tr.finish(1) is None


def test_disabled_telemetry_is_absent():
    st = ShardedHoneycombStore(HoneycombConfig(), heap_capacity=512,
                               shards=1)
    for i in range(16):
        st.put(int_key(i), b"v" * 8)
    svc = HoneycombService(st, batch_size=8,
                           telemetry=TelemetryConfig(enabled=False))
    assert svc.telemetry is None
    assert svc.scheduler.telemetry is None
    _, out = _traffic(svc, 16, ops=8)
    assert all(r.status in ("ok", "not_found") for r in out.values())
    assert svc.metrics_snapshot() == {}
    assert svc.prometheus() == ""
    assert svc.traces() == []
    assert svc.chrome_trace() == {"traceEvents": []}


def test_latency_histograms_fill_at_dispatch(replicated_service):
    _, svc, tickets, _, _ = replicated_service
    tm = svc.telemetry
    n_reads = sum(1 for t in tickets if not t.op.IS_WRITE)
    h = tm.registry.histogram("read_get_latency_seconds",
                              layer="scheduler")
    assert h.count == n_reads             # one weighted record per batch
    assert 0.0 < tm.quantile("read_get_latency_seconds", 50) <= \
        tm.quantile("read_get_latency_seconds", 99.9)
    req = tm.registry.histogram("request_latency_seconds",
                                layer="scheduler")
    assert req.count == len(tickets)
