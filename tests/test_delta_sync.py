"""Incremental host->device delta snapshot sync (the PCIe-amortization
subsystem): equivalence with wholesale republish, threshold fallback,
O(writes) traffic scaling, sync policies, scheduler-batched sync, and the
Pallas scatter kernel."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import HoneycombConfig, HoneycombStore, OutOfOrderScheduler
from repro.core.keys import int_key

SMALL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)


def snapshots_equal(a, b) -> bool:
    return all(bool(jnp.array_equal(getattr(a, f), getattr(b, f)))
               for f in a._fields)


def apply_random_ops(store, oracle, rng, n):
    for _ in range(n):
        k = int_key(int(rng.integers(0, 200)))
        op = rng.random()
        if op < 0.55:
            v = bytes(rng.integers(65, 91, 8))
            store.put(k, v)
            oracle[k] = v
        elif op < 0.8:
            v = bytes(rng.integers(97, 123, 8))
            store.update(k, v)
            oracle[k] = v
        else:
            store.delete(k)
            oracle.pop(k, None)


def test_delta_equals_full_republish_after_random_ops():
    """The delta-synced resident snapshot is bit-identical to a wholesale
    republish after arbitrary put/update/delete mixes (including splits,
    underflow merges and GC wipes)."""
    store = HoneycombStore(SMALL, heap_capacity=256)
    oracle = {}
    rng = np.random.default_rng(7)
    store.export_snapshot()                      # first publish: full
    for round_ in range(8):
        apply_random_ops(store, oracle, rng, 40)
        if round_ % 3 == 2:                      # let GC wipe some rows too
            store.tree.epochs.cpu_begin(0)
            store.collect_garbage()
        snap = store.export_snapshot()
        full = store.export_snapshot(full=True)
        assert snapshots_equal(snap, full), f"round {round_}"
        # and the device path agrees with the host oracle
        keys = [int_key(i) for i in range(0, 200, 7)]
        assert store.get_batch(keys) == [oracle.get(k) for k in keys]
    assert store.sync_stats.delta_syncs > 0


def test_delta_traffic_scales_with_writes_not_store_size():
    """After a full export, W writes sync O(W) bytes, not O(S): the paper's
    log-block/PCIe-amortization claim, metered end to end."""
    store = HoneycombStore(HoneycombConfig(), heap_capacity=2048)
    for i in range(2000):
        store.put(int_key(i), b"v" * 8)
    store.export_snapshot()
    nodes = store.tree.heap.live_slots
    w = max(1, nodes // 10)

    deltas = []
    for mult in (1, 4):                          # growing write batches
        # stride the keys so each batch spreads over ~W*mult leaves
        for i in range(w * mult):
            store.update(int_key((i * 37) % 2000), b"u" * 8)
        b0 = store.sync_stats.bytes_synced
        store.export_snapshot()
        deltas.append(store.sync_stats.bytes_synced - b0)
        assert store.sync_stats.delta_fraction < 1.0
        b1 = store.sync_stats.bytes_synced
        store.export_snapshot(full=True)
        full_bytes = store.sync_stats.bytes_synced - b1
        assert deltas[-1] < 0.25 * full_bytes, (deltas[-1], full_bytes)
    assert deltas[1] > deltas[0]                 # more writes -> more bytes
    assert store.sync_stats.delta_syncs == 2


def test_threshold_falls_back_to_full_republish():
    """Dirty fraction above delta_full_threshold -> wholesale republish."""
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                          delta_full_threshold=0.02)
    store = HoneycombStore(cfg, heap_capacity=256)
    for i in range(150):
        store.put(int_key(i), b"v")
    store.export_snapshot()
    fulls = store.sync_stats.full_syncs
    for i in range(100):                          # touches >2% of rows
        store.update(int_key(i), b"u")
    store.export_snapshot()
    assert store.sync_stats.full_syncs == fulls + 1
    assert store.sync_stats.delta_syncs == 0
    # a single-row touch is under the threshold even at 2%
    store.update(int_key(0), b"w")
    store.export_snapshot()
    assert store.sync_stats.delta_syncs == 1


def test_heap_growth_forces_full_republish():
    """Array growth changes device shapes; the next sync must republish."""
    store = HoneycombStore(SMALL, heap_capacity=32)
    for i in range(20):
        store.put(int_key(i), b"v")
    store.export_snapshot()
    gen = store.tree.heap.generation
    for i in range(20, 400):                      # forces heap growth
        store.put(int_key(i), b"v")
    assert store.tree.heap.generation > gen
    fulls = store.sync_stats.full_syncs
    store.export_snapshot()
    assert store.sync_stats.full_syncs == fulls + 1
    # reads still correct after the republish
    assert store.get_batch([int_key(5), int_key(399)]) == [b"v", b"v"]


def test_sync_policy_every_k():
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                          sync_policy="every_k", sync_every_k=10)
    store = HoneycombStore(cfg, heap_capacity=256)
    for i in range(25):
        store.put(int_key(i), b"v")
    # 25 writes at K=10 -> 2 automatic syncs, remainder pending
    assert store.sync_stats.snapshots == 2
    store.export_snapshot()
    assert store.sync_stats.snapshots == 3


def test_sync_policy_explicit_reads_stale_snapshot():
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                          sync_policy="explicit")
    store = HoneycombStore(cfg, heap_capacity=256)
    for i in range(50):
        store.put(int_key(i), b"old")
    store.export_snapshot()
    store.update(int_key(0), b"new")
    # device read is stale-but-consistent until the explicit sync
    assert store.get_batch([int_key(0)]) == [b"old"]
    store.export_snapshot()
    assert store.get_batch([int_key(0)]) == [b"new"]


def test_scheduler_batches_writes_between_syncs():
    """scheduler.run(): many writes, ONE host->device sync, then reads —
    the paper's batched synchronization."""
    store = HoneycombStore(SMALL, heap_capacity=256)
    for i in range(100):
        store.put(int_key(i), b"v%d" % i)
    store.export_snapshot()
    snaps_before = store.sync_stats.snapshots
    sched = OutOfOrderScheduler(batch_size=8)
    write_rids = [sched.submit("update", int_key(i), value=b"w%d" % i)
                  for i in range(30)]
    write_rids.append(sched.submit("delete", int_key(99)))
    read_rids = {sched.submit("get", int_key(i)): i for i in range(0, 100, 9)}
    out = sched.run(store)
    assert sched.syncs == 1
    assert store.sync_stats.snapshots == snaps_before + 1
    assert all(out[r] is None for r in write_rids)
    for rid, i in read_rids.items():
        want = None if i == 99 else (b"w%d" % i if i < 30 else b"v%d" % i)
        assert out[rid] == want
    assert sched.applied_writes == 31


def test_scheduler_burst_defers_every_k_policy():
    """A scheduler write burst performs exactly ONE sync even when the
    store's own policy would sync every K writes mid-burst."""
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                          sync_policy="every_k", sync_every_k=4)
    store = HoneycombStore(cfg, heap_capacity=256)
    with store.deferred_sync():                  # quiet load phase
        for i in range(60):
            store.put(int_key(i), b"v")
    store.export_snapshot()
    snaps = store.sync_stats.snapshots
    sched = OutOfOrderScheduler()
    for i in range(30):                          # would trigger 7 every_k syncs
        sched.submit("update", int_key(i), value=b"w")
    rid = sched.submit("get", int_key(29))
    out = sched.run(store)
    assert store.sync_stats.snapshots == snaps + 1
    assert sched.syncs == 1
    assert out[rid] == b"w"


def test_pagetable_commands_accumulate_across_syncs():
    """Regression: multi-sync runs report cumulative PCIe command counts
    (they were overwritten per export)."""
    store = HoneycombStore(SMALL, heap_capacity=256)
    for i in range(100):
        store.put(int_key(i), b"v")
    store.export_snapshot()
    c1 = store.sync_stats.pagetable_commands
    r1 = store.sync_stats.read_version_updates
    assert c1 == store.tree.pt.sync_commands
    for i in range(100, 200):
        store.put(int_key(i), b"v")
    store.export_snapshot()
    assert store.sync_stats.pagetable_commands == store.tree.pt.sync_commands
    assert store.sync_stats.pagetable_commands > c1
    assert store.sync_stats.read_version_updates > r1


def test_old_snapshots_survive_delta_syncs():
    """Delta application is functional: snapshots held by in-flight batches
    keep answering at their read version (wait-free MVCC)."""
    from repro.core.keys import pack_keys
    from repro.core.read_path import batched_get
    cfg = SMALL
    store = HoneycombStore(cfg, heap_capacity=256)
    for i in range(50):
        store.put(int_key(i), b"old")
    old_snap = store.export_snapshot()
    for gen in range(3):                          # several delta syncs
        for i in range(50):
            store.update(int_key(i), b"new")
        store.export_snapshot()
    assert store.sync_stats.delta_syncs > 0
    lanes, lens = pack_keys([int_key(i) for i in range(50)], cfg.key_words)
    res = batched_get(old_snap, jnp.asarray(lanes), jnp.asarray(lens), cfg)
    vals = np.asarray(res.vals)
    assert bool(res.found.all())
    for i in range(50):
        assert vals[i].astype(">u4").tobytes()[:3] == b"old"


def test_delta_scatter_kernel_matches_ref():
    """Pallas interpret-mode scatter == jnp oracle (duplicate-row padding
    included)."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    dst = jnp.asarray(rng.integers(0, 2**31, (64, 12)).astype(np.uint32))
    rows = np.array([3, 17, 40, 40], np.int32)    # padded repeat
    upd = rng.integers(0, 2**31, (3, 12)).astype(np.uint32)
    upd = jnp.asarray(np.concatenate([upd, upd[-1:]]))
    want = ops.snapshot_delta_scatter(dst, jnp.asarray(rows), upd,
                                      backend="ref")
    got = ops.snapshot_delta_scatter(dst, jnp.asarray(rows), upd,
                                     backend="interpret")
    assert bool(jnp.array_equal(want, got))
