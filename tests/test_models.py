"""Model zoo: per-arch smoke tests (reduced configs, one forward/train step
on CPU, output shapes + no NaNs) and layer-level equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import layers as ll
from repro.models import mamba2 as mm
from repro.models import moe as me
from repro.models import schema as sc
from repro.models import transformer as tf

B, S = 2, 32


def make_batch(cfg):
    batch = {"labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.embeds_in:
        batch["embeds"] = jnp.full((B, S, cfg.d_model), 0.01, jnp.bfloat16)
    else:
        batch["tokens"] = jnp.zeros((B, S), jnp.int32)
    if cfg.n_enc_layers:
        batch["enc_embeds"] = jnp.full((B, S // 4, cfg.d_model), 0.01,
                                       jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_backward(arch):
    cfg = get_smoke_config(arch)
    params = sc.init(tf.schema(cfg), jax.random.key(0))
    batch = make_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: tf.lm_loss(p, cfg, batch)))(params)
    assert jnp.isfinite(loss), arch
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm), arch
    logits = tf.forward(params, cfg, tokens=batch.get("tokens"),
                        embeds=batch.get("embeds"),
                        enc_out=(tf.encode(params, cfg, batch["enc_embeds"])
                                 if cfg.n_enc_layers else None),
                        remat=False)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_schema_consistency(arch):
    """Full (assigned) configs: schema instantiates abstractly, parameter
    count sane, pattern divides depth.  No allocation happens here."""
    cfg = get_config(arch)
    tree = tf.schema(cfg)
    abstract = sc.abstract(tree)
    n = sc.n_params(tree)
    assert n > 100e6, (arch, n)
    assert cfg.n_layers % len(cfg.pattern) == 0
    leaves = jax.tree.leaves(abstract)
    assert all(hasattr(l, "shape") for l in leaves)
    if cfg.vocab:
        assert cfg.vocab % 16 == 0, "vocab must shard on the model axis"


def test_ssd_chunked_equals_recurrence():
    cfg = get_smoke_config("mamba2_1p3b")
    rng = np.random.default_rng(0)
    b, s = 2, 16
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xh = jnp.asarray(rng.normal(size=(b, s, H, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.normal(size=(b, s, H))))
    A = -jnp.exp(jnp.asarray(rng.normal(size=(H,)), jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(b, s, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, s, N)), jnp.float32)
    y4, h4 = mm._ssd_chunked(xh, dt, A, Bm, Cm, chunk=4)
    y16, h16 = mm._ssd_chunked(xh, dt, A, Bm, Cm, chunk=16)
    h = jnp.zeros((b, H, P, N))
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A[None, :])
        h = h * dA[:, :, None, None] + jnp.einsum(
            "bn,bh,bhp->bhpn", Bm[:, t], dt[:, t], xh[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], h))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h4), np.asarray(h), atol=2e-5)


def test_mamba_prefill_state_matches_decode_steps():
    """Running prefill then decoding == decoding token by token."""
    cfg = get_smoke_config("mamba2_1p3b")
    p = sc.init(mm.mamba_schema(cfg), jax.random.key(1))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)) * 0.1,
                    jnp.float32)
    _, st_pref = mm.mamba_block(p, x, cfg, return_state=True)
    st = mm.init_state(cfg, 1)
    for t in range(8):
        _, st = mm.mamba_decode(p, x[:, t: t + 1], st, cfg)
    np.testing.assert_allclose(np.asarray(st_pref.ssm), np.asarray(st.ssm),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_pref.conv),
                               np.asarray(st.conv), atol=2e-4)


def test_chunked_attention_equals_dense():
    cfg = get_smoke_config("qwen2p5_3b")
    p = sc.init(ll.attention_schema(cfg), jax.random.key(2))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)) * 0.1,
                    jnp.bfloat16)
    dense, _ = ll.attention(p, x, cfg, local=False, q_chunk=64)
    chunked, _ = ll.attention(p, x, cfg, local=False, q_chunk=16)
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(chunked, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_local_window_mask():
    cfg = dataclasses.replace(get_smoke_config("gemma2_27b"), window=8)
    p = sc.init(ll.attention_schema(cfg), jax.random.key(3))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 32, cfg.d_model)) * 0.1,
                    jnp.bfloat16)
    local, _ = ll.attention(p, x, cfg, local=True)
    # perturbing a token beyond the window must not change the output
    x2 = x.at[:, 0].add(1.0)
    local2, _ = ll.attention(p, x2, cfg, local=True)
    np.testing.assert_allclose(np.asarray(local[:, 20:], np.float32),
                               np.asarray(local2[:, 20:], np.float32),
                               atol=1e-2)
    glob, _ = ll.attention(p, x2, cfg, local=False)
    assert not np.allclose(np.asarray(glob[:, 20:], np.float32),
                           np.asarray(local2[:, 20:], np.float32),
                           atol=1e-3)


def test_moe_ragged_equals_dense():
    cfg = get_smoke_config("olmoe_1b_7b")
    p = sc.init(me.moe_schema(cfg), jax.random.key(4))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)) * 0.1,
                    jnp.float32)
    yd = me.moe_dense(p, x, cfg)
    yr = me.moe_ragged(p, x, cfg)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)


def test_param_counts_match_published():
    expect = {"mixtral_8x22b": (140e9, 142e9), "olmoe_1b_7b": (6.5e9, 7.3e9),
              "gemma2_27b": (27e9, 29e9), "jamba_v0p1_52b": (50e9, 53e9),
              "qwen2p5_3b": (3.1e9, 3.6e9), "mamba2_1p3b": (1.3e9, 1.5e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    cfg = get_config("mixtral_8x22b")
    na = cfg.active_param_count()
    assert 38e9 <= na <= 41e9, na
