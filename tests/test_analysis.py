"""honeylint + kernel checker + EpochSan (repro/analysis).

Three layers, mirroring the analysis package:

  * lint rules — each rule catches a known-bad fixture (written to
    tmp_path and run through ``lint_file``), and the repo at HEAD lints
    clean under the shipped baseline;
  * kernel checker — ``check_jaxpr`` flags a deliberately mis-aliased
    in-place scatter, a split "fused" path, an f64 leak, a host
    callback, and a VMEM-budget overrun; the real kernel registry
    traces clean;
  * EpochSan — each injected protocol violation (unflipped standby
    read, pinned-epoch GC, follower freshness, stale cache rows,
    unflipped standby after export) raises ``EpochSanViolation`` at the
    seam, and the same flows run clean without the injected bug.
"""
from __future__ import annotations

import json
import os
import textwrap

import pytest

from repro.analysis import epochsan, kernel_check, lint
from repro.analysis.lint import Finding


# --------------------------------------------------------------------------
# lint rules against bad fixtures
# --------------------------------------------------------------------------

def _lint_src(tmp_path, rel, source):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return lint.lint_file(path, root=tmp_path)


def _rules(findings):
    return {f.rule for f in findings}


def test_no_raw_clock_flags_time_calls(tmp_path):
    fs = _lint_src(tmp_path, "mod.py", """\
        import time

        def f():
            t0 = time.perf_counter()
            return time.time() - t0
    """)
    assert [f.rule for f in fs] == ["no-raw-clock", "no-raw-clock"]
    assert "telemetry.CLOCK" in fs[0].message


def test_no_raw_clock_exempts_the_clock_owner(tmp_path):
    fs = _lint_src(tmp_path, "core/telemetry.py", """\
        import time

        def now():
            return time.perf_counter()
    """)
    assert fs == []


def test_inline_suppression_with_reason(tmp_path):
    fs = _lint_src(tmp_path, "mod.py", """\
        import time

        def f():
            # honeylint: disable=no-raw-clock -- calibrating CLOCK itself
            return time.perf_counter()
    """)
    assert fs == []


def test_no_bare_except_flags_broad_handlers(tmp_path):
    fs = _lint_src(tmp_path, "mod.py", """\
        def f():
            try:
                g()
            except:
                pass
            try:
                g()
            except Exception:
                pass
            try:
                g()
            except (ValueError, KeyError):
                raise
    """)
    assert [f.rule for f in fs] == ["no-bare-except", "no-bare-except"]


def test_no_aliased_publish_flags_live_array_asarray(tmp_path):
    # jnp.asarray of an attribute chain (live host heap) inside a
    # publish-path function of a publish file — the PR 1 flake class
    fs = _lint_src(tmp_path, "core/shard.py", """\
        import jax.numpy as jnp

        def _publish_image(h):
            rows = h.ntype
            return jnp.asarray(rows)
    """)
    assert _rules(fs) == {"no-aliased-publish"}


def test_no_aliased_publish_passes_copied_arrays(tmp_path):
    fs = _lint_src(tmp_path, "core/shard.py", """\
        import jax.numpy as jnp
        import numpy as np

        def _publish_image(h):
            rows = np.array(h.ntype, copy=True)
            return jnp.asarray(rows)

        def helper(h):
            return jnp.asarray(h.ntype)   # not a publish-path function
    """)
    assert fs == []


def test_no_magic_image_offsets_flags_literal_indices(tmp_path):
    fs = _lint_src(tmp_path, "src/repro/kernels/bad.py", """\
        def kern(rows_ref, out_ref):
            r = rows_ref[0]
            out_ref[r, 1217 + 3] = 1
    """)
    assert _rules(fs) == {"no-magic-image-offsets"}
    assert "1217" in fs[0].message


def test_no_magic_image_offsets_passes_layout_derived(tmp_path):
    fs = _lint_src(tmp_path, "src/repro/kernels/good.py", """\
        def kern(rows_ref, out_ref, *, offs):
            r = rows_ref[0]
            out_ref[r, offs[0] + 3] = 1     # layout-derived
            out_ref[r, 4] = 2               # small lane arithmetic is fine
    """)
    assert fs == []


def test_stats_must_collect(tmp_path):
    fs = _lint_src(tmp_path, "mod.py", """\
        import dataclasses

        @dataclasses.dataclass
        class OrphanStats:
            n: int = 0

        @dataclasses.dataclass
        class WiredStats:
            n: int = 0

            def collect(self):
                return []

        @dataclasses.dataclass
        class NotAStatsThing:
            n: int = 0
    """)
    assert [f.rule for f in fs] == ["stats-must-collect"]
    assert "OrphanStats" in fs[0].message


def test_baseline_suppresses_by_rule_and_path(tmp_path):
    (tmp_path / "mod.py").write_text("import time\nt = time.time()\n")
    bp = tmp_path / "baseline.json"
    bp.write_text(json.dumps(
        [{"rule": "no-raw-clock", "path": "mod.py", "reason": "test debt"}]))
    findings, suppressed = lint.run_lint(
        ("mod.py",), root=tmp_path, baseline=bp, golden=None)
    assert findings == [] and suppressed == 1
    # without the baseline the finding comes back
    findings, suppressed = lint.run_lint(
        ("mod.py",), root=tmp_path, baseline=None, golden=None)
    assert _rules(findings) == {"no-raw-clock"} and suppressed == 0


def test_repo_at_head_lints_clean():
    """The acceptance gate: zero findings on HEAD with <= 2 baselined
    suppressions (the shipped baseline has exactly one justified entry)."""
    findings, suppressed = lint.run_lint()
    assert findings == [], "\n".join(map(str, findings))
    assert suppressed <= 2
    base = lint.load_baseline()
    assert all(b.get("reason") for b in base), "baseline entries need reasons"
    # with NO baseline the only exposure is the deliberately-kept (and
    # justified) broad handler in the dry-run sweep driver
    bare, n = lint.run_lint(baseline=None)
    assert {(f.rule, f.path) for f in bare} <= {
        ("no-bare-except", "src/repro/launch/dryrun.py")} and n == 0


def test_golden_schema_pin_roundtrip(tmp_path):
    golden = tmp_path / "golden.json"
    assert _rules(lint.check_golden(golden)) == {"schema-golden-drift"}
    lint.pin_golden(golden)
    assert lint.check_golden(golden) == []
    # tamper: a drifted fingerprint must name what changed
    blob = json.loads(golden.read_text())
    blob["sha256"] = "0" * 64
    blob["detail"]["image_words"] = -1
    golden.write_text(json.dumps(blob))
    fs = lint.check_golden(golden)
    assert _rules(fs) == {"schema-golden-drift"}
    assert "image_words" in fs[0].message


def test_repo_golden_matches_current_schema():
    assert lint.check_golden() == []


# --------------------------------------------------------------------------
# kernel checker
# --------------------------------------------------------------------------

def _tiny_pallas_scatter():
    """A pallas_call with NO input_output_aliases — the mis-aliased
    in-place scatter the checker exists to flag."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kern(dst_ref, upd_ref, out_ref):
        out_ref[...] = dst_ref[...] + upd_ref[...]

    def scatter(dst, upd):
        return pl.pallas_call(
            kern, out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        )(dst, upd)

    a = jax.ShapeDtypeStruct((16, 8), jnp.uint32)
    return scatter, a


def test_kernel_check_flags_missing_inplace_alias():
    import jax
    scatter, a = _tiny_pallas_scatter()
    jaxpr = jax.make_jaxpr(scatter)(a, a)
    fs = kernel_check.check_jaxpr("bad.scatter", "x.py", jaxpr.jaxpr,
                                  in_place=True)
    assert _rules(fs) == {"kernel-inplace-alias"}
    # the same jaxpr audited as a plain kernel is clean
    assert kernel_check.check_jaxpr("ok", "x.py", jaxpr.jaxpr) == []


def test_kernel_check_flags_split_fused_path():
    import jax
    scatter, a = _tiny_pallas_scatter()
    jaxpr = jax.make_jaxpr(lambda d, u: scatter(scatter(d, u), u))(a, a)
    fs = kernel_check.check_jaxpr("split.fused", "x.py", jaxpr.jaxpr,
                                  fused=True)
    assert _rules(fs) == {"kernel-single-dispatch"}
    assert "2 pallas_call" in fs[0].message


def test_kernel_check_flags_vmem_budget_overrun():
    import jax
    scatter, a = _tiny_pallas_scatter()
    jaxpr = jax.make_jaxpr(scatter)(a, a)
    fs = kernel_check.check_jaxpr("fat.kernel", "x.py", jaxpr.jaxpr,
                                  vmem_budget=64)
    assert _rules(fs) == {"kernel-vmem-budget"}


def test_kernel_check_flags_f64():
    import jax
    import jax.numpy as jnp
    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(lambda x: x.astype(jnp.float64) * 2.0)(
            jax.ShapeDtypeStruct((8,), jnp.float32))
    fs = kernel_check.check_jaxpr("leaky.f64", "x.py", jaxpr.jaxpr)
    assert "kernel-no-f64" in _rules(fs)


def test_kernel_check_flags_host_callback():
    import jax
    import jax.numpy as jnp

    def fn(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct((8,), jnp.float32), x)

    jaxpr = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8,), jnp.float32))
    fs = kernel_check.check_jaxpr("chatty", "x.py", jaxpr.jaxpr)
    assert "kernel-no-callback" in _rules(fs)


def test_kernel_registry_traces_clean():
    """Every real Pallas entry point traces and passes all kernel rules
    at the default geometry and VMEM budget."""
    entries = kernel_check.kernel_entries()
    assert len(entries) >= 10
    fs = kernel_check.run_kernel_checks()
    assert fs == [], "\n".join(map(str, fs))


# --------------------------------------------------------------------------
# EpochSan
# --------------------------------------------------------------------------

def _seeded_shard(cfg=None, n=20):
    from repro.core.shard import StoreShard
    s = StoreShard(cfg) if cfg is not None else StoreShard()
    for i in range(n):
        s.put(f"k{i:03d}".encode(), b"v" * 8)
    s.export_snapshot()
    return s


def test_epochsan_clean_lifecycle_counts_checks():
    with epochsan.enabled() as san:
        s = _seeded_shard()
        assert s.get_batch([b"k001"]) == [b"v" * 8]
        for i in range(20):
            s.put(f"k{i:03d}".encode(), b"w" * 8)
        s.begin_export()
        s.flip()
        s.collect_garbage()
        assert s.get_batch([b"k001"]) == [b"w" * 8]
    assert san.violations == []
    st = san.stats
    assert st.read_checks > 0 and st.stagings > 0 and st.flips > 0
    assert st.gc_audits > 0 and st.violations == 0


def test_epochsan_catches_standby_read():
    with epochsan.enabled() as san:
        s = _seeded_shard()
        s.put(b"k000", b"x" * 8)
        s.begin_export()            # staged, NOT flipped
        with pytest.raises(epochsan.EpochSanViolation) as ei:
            s._device_get(s._standby, [b"k000"])
        assert ei.value.kind == epochsan.STANDBY_READ
    assert san.stats.violations == 1


def test_epochsan_nonstrict_records_without_raising():
    with epochsan.enabled(strict=False) as san:
        s = _seeded_shard()
        s.put(b"k000", b"x" * 8)
        s.begin_export()
        s._device_get(s._standby, [b"k000"])   # recorded, not raised
    assert [v.kind for v in san.violations] == [epochsan.STANDBY_READ]
    assert san.report()[0]["kind"] == epochsan.STANDBY_READ


def test_epochsan_catches_pinned_epoch_gc(monkeypatch):
    from repro.core import gc as gc_mod
    from repro.core.config import HoneycombConfig

    with epochsan.enabled() as san:
        # "explicit" pins the exported snapshot's accelerator epoch; the
        # default on_read policy holds no pin, so nothing would be
        # wrongly reclaimable there
        s = _seeded_shard(HoneycombConfig(sync_policy="explicit"), n=40)
        for i in range(40):
            s.update(f"k{i:03d}".encode(), b"w" * 8)   # old versions -> gc
        assert s.tree.gc.list, "updates must have deferred garbage"
        # inject the bug: a GC that ignores the pinned epoch window
        monkeypatch.setattr(gc_mod.GarbageCollector, "_reclaimable",
                            lambda self, e: True)
        with pytest.raises(epochsan.EpochSanViolation) as ei:
            s.collect_garbage()
        assert ei.value.kind == epochsan.PINNED_EPOCH_GC
    assert san.stats.violations >= 1


def test_epochsan_catches_follower_freshness(monkeypatch):
    from repro.core.config import ReplicationConfig
    from repro.core.replica import ReplicaGroup
    from repro.core.shard import StoreShard

    with epochsan.enabled() as san:
        g = ReplicaGroup(StoreShard(), ReplicationConfig(replicas=2))
        for i in range(20):
            g.put(f"k{i:03d}".encode(), b"v" * 8)
        g.export_snapshot()
        assert g.get_batch([b"k001"], replica=1) == [b"v" * 8]
        assert san.stats.dispatch_checks > 0 and not san.violations

        # a paused follower falls behind the primary's published epoch;
        # then the freshness rule itself "breaks" and routes to it anyway
        g.pause_follower(1)
        for i in range(20):
            g.put(f"k{i:03d}".encode(), b"w" * 8)
        g.export_snapshot()
        g.resume_follower(1)
        monkeypatch.setattr(ReplicaGroup, "_covers",
                            lambda self, f: True)
        with pytest.raises(epochsan.EpochSanViolation) as ei:
            g.get_batch([b"k001"], replica=1)
        assert ei.value.kind == epochsan.FOLLOWER_FRESHNESS


def test_epochsan_catches_stale_cache_rows():
    with epochsan.enabled() as san:
        s = _seeded_shard()
        s.put(b"k000", b"w" * 8)
        s.tree.pt.remap(0, s.tree.pt.lookup(0))    # remap hits the cache
        s.cache.refresh = lambda tree: None        # "forgot to refresh"
        with pytest.raises(epochsan.EpochSanViolation) as ei:
            s.begin_export()
        assert ei.value.kind == epochsan.STALE_CACHE_ROWS
    assert san.stats.violations == 1


def test_epochsan_remap_then_refresh_stages_clean():
    with epochsan.enabled() as san:
        s = _seeded_shard()
        s.put(b"k000", b"w" * 8)
        s.tree.pt.remap(0, s.tree.pt.lookup(0))
        s.export_snapshot()     # begin_export refreshes the cache itself
    assert san.violations == []


def test_epochsan_catches_unflipped_export():
    from repro.core.scheduler import OutOfOrderScheduler
    from repro.core.shard import StoreShard

    with epochsan.enabled() as san:
        s = StoreShard()
        for i in range(10):
            s.put(f"k{i:03d}".encode(), b"v" * 8)
        sched = OutOfOrderScheduler(pipeline="pipelined")
        s.flip = lambda: None                      # "forgot to publish"
        with pytest.raises(epochsan.EpochSanViolation) as ei:
            sched.stage_export(s)
        assert ei.value.kind == epochsan.UNFLIPPED_EXPORT
    assert san.stats.violations == 1


def test_epochsan_gating_matches_environment():
    """Off by default; `enabled()` scopes strictly and restores the
    previous sanitizer (the env-driven one under HONEYCOMB_EPOCHSAN=1)."""
    before = epochsan.get()
    env_on = os.environ.get(epochsan.ENV_VAR, "").strip() not in (
        "", "0", "false")
    if env_on:
        assert before is not None
    with epochsan.enabled() as san:
        assert epochsan.get() is san and san is not before
    assert epochsan.get() is before


def test_epochsan_stats_collects_registry_samples():
    with epochsan.enabled() as san:
        _seeded_shard(n=5)
        names = {s.name for s in san.stats.collect()}
    assert any("epochsan" in n and "staging" in n for n in names), names


# --------------------------------------------------------------------------
# driver wiring
# --------------------------------------------------------------------------

def test_finding_formatting():
    f = Finding("no-raw-clock", "src/x.py", 7, "msg")
    assert str(f) == "src/x.py:7: [no-raw-clock] msg"
    assert f.to_json() == {"rule": "no-raw-clock", "path": "src/x.py",
                           "line": 7, "message": "msg"}


def test_runner_writes_report(tmp_path):
    from repro.analysis import runner
    out = tmp_path / "report.json"
    rc = runner.main(["--json", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["ok"] and report["lint"] == [] \
        and report["kernel_check"] == []
    assert report["entry_points"] >= 10 and report["baselined"] <= 2
