"""Epoch-pipelined execution engine (core/pipeline.py): double-buffered
snapshot staging/flip (incl. survival under GC churn), pipelined-vs-serial
scheduler equivalence (results AND sync byte counts), the serial mode's
op-for-op match with the legacy inline sequence, the fused multi-field
delta scatter, and the shared power-of-two bucket schedule."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (HoneycombConfig, HoneycombStore, OutOfOrderScheduler,
                        ShardedHoneycombStore, apply_snapshot_delta,
                        batched_get, bucket_pow2, uniform_int_boundaries)
from repro.core.keys import int_key, pack_keys

SMALL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)
B4 = uniform_int_boundaries(200, 4)


def submit_random_mixed(scheds, rng, n, key_space=200):
    """Submit an identical randomized put/update/delete/get/scan mix to
    every scheduler; returns nothing (rids align across schedulers)."""
    for _ in range(n):
        k = int(rng.integers(0, key_space))
        op = rng.random()
        for s in scheds:
            if op < 0.25:
                s.submit("put", int_key(k), value=b"v%03d" % k)
            elif op < 0.35:
                s.submit("update", int_key(k), value=b"u%03d" % k)
            elif op < 0.45:
                s.submit("delete", int_key(k))
            elif op < 0.8:
                s.submit("get", int_key(k))
            else:
                s.submit("scan", int_key(k),
                         int_key(min(k + 7, key_space - 1)),
                         expected_items=8)


# ---------------------------------------------------------------- flip path
def test_standby_invisible_until_flip():
    """begin_export stages the next epoch without touching the active
    snapshot; only flip() publishes it."""
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                          sync_policy="explicit")
    st = HoneycombStore(cfg, heap_capacity=256)
    for i in range(50):
        st.put(int_key(i), b"old")
    st.export_snapshot()
    assert st.epoch == 1
    st.update(int_key(3), b"new")
    assert st.begin_export()
    # device reads still answer from the active (pre-flip) epoch
    assert st.get_batch([int_key(3)]) == [b"old"]
    st.flip()
    assert st.epoch == 2
    assert st.get_batch([int_key(3)]) == [b"new"]
    # flip with nothing staged is a no-op
    snap = st.flip()
    assert st.epoch == 2 and snap is not None


def test_flip_under_gc_churn():
    """An old-epoch snapshot still answers at its read version after two
    staged flips plus collect_garbage() — the MVCC/GC pins survive the
    double-buffer path."""
    st = HoneycombStore(SMALL, heap_capacity=256)
    for i in range(50):
        st.put(int_key(i), b"old")
    old_snap = st.export_snapshot()
    for round_ in range(2):
        for i in range(50):
            st.update(int_key(i), b"new%d" % round_)
        assert st.begin_export()
        st.flip()
        st.tree.epochs.cpu_begin(0)
        st.collect_garbage()
    assert st.epoch == 3
    assert st.sync_stats.delta_syncs > 0
    lanes, lens = pack_keys([int_key(i) for i in range(50)], SMALL.key_words)
    res = batched_get(old_snap, jnp.asarray(lanes), jnp.asarray(lens), SMALL)
    assert bool(res.found.all())
    vals = np.asarray(res.vals)
    for i in range(50):
        assert vals[i].astype(">u4").tobytes()[:3] == b"old", i
    # and the flipped epoch answers fresh
    assert st.get_batch([int_key(7)]) == [b"new1"]


def test_first_stage_not_respun_by_reads_before_flip():
    """Regression: a read (lazy export) landing between the FIRST-ever
    begin_export and its flip must not re-stage a spurious sync — the
    clean-check honors a staged standby even when no active snapshot
    exists yet."""
    st = HoneycombStore(SMALL, heap_capacity=256)
    for i in range(40):
        st.put(int_key(i), b"v")
    assert st.begin_export()
    assert st.sync_stats.snapshots == 1
    # on_read policy: get_batch routes through export_snapshot, which must
    # only flip the staged standby, not meter a second sync
    assert st.get_batch([int_key(1)]) == [b"v"]
    assert st.sync_stats.snapshots == 1
    assert st.sync_stats.delta_syncs == 0
    assert st.epoch == 1


def test_restaged_standby_accumulates_deltas():
    """Two begin_export calls without an intervening flip accumulate into
    ONE standby; the eventual flip publishes both write bursts."""
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                          sync_policy="explicit")
    st = HoneycombStore(cfg, heap_capacity=256)
    for i in range(40):
        st.put(int_key(i), b"a")
    st.export_snapshot()
    st.update(int_key(1), b"b")
    assert st.begin_export()
    st.update(int_key(2), b"c")
    assert st.begin_export()
    assert st.get_batch([int_key(1), int_key(2)]) == [b"a", b"a"]
    st.flip()
    assert st.get_batch([int_key(1), int_key(2)]) == [b"b", b"c"]
    assert st.sync_stats.snapshots == 3     # one per begin_export


def test_router_flips_dirty_shards_independently():
    """begin_export stages ONLY dirty shards; per-shard epochs advance
    independently at flip."""
    sh = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                               boundaries=B4)
    for i in range(0, 200, 2):
        sh.put(int_key(i), b"v")
    sh.export_snapshot()
    assert sh.per_shard_epochs == [1, 1, 1, 1]
    for i in range(100, 140, 2):            # shard 2 only
        sh.update(int_key(i), b"u")
    assert sh.begin_export() == [2]
    sh.flip()
    assert sh.per_shard_epochs == [1, 1, 2, 1]
    assert sh.pipeline_stats.flips == 5
    assert sh.get_batch([int_key(100), int_key(2)]) == [b"u", b"v"]


# ------------------------------------------- pipelined-vs-serial equivalence
def test_pipelined_equals_serial_randomized():
    """Randomized mixed workload: pipelined mode returns the same responses
    AND the same SyncStats (byte counts included) as serial mode."""
    a = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                              boundaries=B4)
    b = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                              boundaries=B4)
    sa = OutOfOrderScheduler(batch_size=8, routing=a.routing(),
                             pipeline="serial")
    sb = OutOfOrderScheduler(batch_size=8, routing=b.routing(),
                             pipeline="pipelined")
    rng = np.random.default_rng(17)
    for round_ in range(4):
        submit_random_mixed((sa, sb), rng, 70)
        out_a = sa.run(a)
        out_b = sb.run(b)
        assert out_a == out_b, round_
        assert a.sync_stats == b.sync_stats, round_
        assert sa.syncs == sb.syncs
    assert a.sync_stats.delta_syncs > 0     # the mix exercised delta syncs
    assert sa.dispatched_requests == sb.dispatched_requests
    # pipelined mode actually staged and flipped standby buffers
    assert b.pipeline_stats.staged_exports >= sb.syncs
    assert b.pipeline_stats.flips >= sb.syncs


def test_serial_run_matches_legacy_inline_sequence():
    """pipeline="serial" is op-for-op the pre-refactor run(): apply writes
    under deferred_sync, ONE facade export_snapshot(), dispatch
    ready_batches — same responses, same sync byte counts."""
    a = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                              boundaries=B4)
    b = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                              boundaries=B4)
    sched = OutOfOrderScheduler(batch_size=8, routing=a.routing(),
                                pipeline="serial")
    legacy = OutOfOrderScheduler(batch_size=8, routing=b.routing())
    rng = np.random.default_rng(5)
    submit_random_mixed((sched, legacy), rng, 90)
    out = sched.run(a)
    # the literal pre-refactor sequence, inlined:
    out_legacy = {}
    with b.deferred_sync():
        for r in legacy._writes:
            if r.kind == "put":
                b.put(r.key, r.value)
            elif r.kind == "update":
                b.update(r.key, r.value)
            else:
                b.delete(r.key)
            out_legacy[r.rid] = None
    legacy._writes.clear()
    if out_legacy:
        b.export_snapshot()
    for kind, batch in legacy.ready_batches(flush=True):
        if kind == "get":
            res = b.get_batch([r.key for r in batch])
        else:
            res = b.scan_batch([(r.key, r.hi) for r in batch])
        for r, v in zip(batch, res):
            out_legacy[r.rid] = v
    assert out == out_legacy
    assert a.sync_stats == b.sync_stats


def test_pipeline_stage_meters():
    """The stage meters accumulate: stall/stage timings, lane occupancy
    (bucket_pow2 padding), runs."""
    st = HoneycombStore(SMALL, heap_capacity=256)
    sched = OutOfOrderScheduler(batch_size=8, pipeline="pipelined")
    for i in range(20):
        sched.submit("put", int_key(i), value=b"v")
    for i in range(0, 20, 2):
        sched.submit("get", int_key(i))
    sched.run(st)
    s = sched.stats
    assert s.runs == 1
    assert s.admit_s > 0 and s.dispatch_s > 0
    assert s.dispatched_lanes == 10
    # 10 gets at batch_size=8 -> one full 8-batch + one 2-batch (pads to 2)
    assert s.padded_lanes == 8 + 2
    assert s.lane_occupancy == 1.0
    assert 0.0 <= s.stall_fraction <= 1.0
    assert st.pipeline_stats.staged_exports == sched.syncs == 1

    with pytest.raises(AssertionError):
        OutOfOrderScheduler(pipeline="warp")


# ------------------------------------------------------ fused delta scatter
def test_fused_multi_field_scatter_matches_oracle():
    """apply_snapshot_delta(backend="interpret") on the packed image layout
    — ONE contiguous image-row scatter per dirty node — is bit-identical to
    the jnp oracle on a materialized snapshot/delta pair."""
    from repro.launch.store_dryrun import abstract_delta, abstract_snapshot
    cfg = SMALL
    snap_abs, S = abstract_snapshot(cfg, n_items=64, shards=1)
    rng = np.random.default_rng(0)
    mat = lambda s: jnp.asarray(rng.integers(0, 100, s.shape).astype(s.dtype))
    snap = jax.tree.map(mat, snap_abs)
    delta = jax.tree.map(mat, abstract_delta(cfg, snap_abs, 3, 2))
    delta = delta._replace(
        rows=jnp.asarray(np.array([1, 4, S - 1], np.int32)),
        pt_lids=jnp.asarray(np.array([0, 2], np.int32)),
        pt_phys=jnp.asarray(np.array([5, 6], np.int32)))
    want = apply_snapshot_delta(snap, delta)
    got = apply_snapshot_delta(snap, delta, backend="interpret")
    for f in want._fields:
        w, g = getattr(want, f), getattr(got, f)
        if w is None or g is None:     # unattached cache tier (no cfg given)
            assert w is None and g is None, f
        else:
            assert bool(jnp.array_equal(w, g)), f


def test_multi_scatter_kernel_duplicate_rows():
    """The raw fused kernel handles bucket-padded duplicate rows (identical
    data) across fields with distinct widths/dtypes."""
    from repro.kernels import ops
    rng = np.random.default_rng(1)
    dsts = [jnp.asarray(rng.integers(0, 2**31, (32, 12)).astype(np.uint32)),
            jnp.asarray(rng.integers(0, 99, (32, 1)).astype(np.int32))]
    rows = jnp.asarray(np.array([3, 9, 9], np.int32))     # padded repeat
    u0 = rng.integers(0, 2**31, (2, 12)).astype(np.uint32)
    u1 = rng.integers(0, 99, (2, 1)).astype(np.int32)
    upd = [jnp.asarray(np.concatenate([u0, u0[-1:]])),
           jnp.asarray(np.concatenate([u1, u1[-1:]]))]
    want = ops.snapshot_multi_scatter(dsts, rows, upd, backend="ref")
    got = ops.snapshot_multi_scatter(dsts, rows, upd, backend="interpret")
    for w, g in zip(want, got):
        assert bool(jnp.array_equal(w, g))


# ------------------------------------------------------- bucket schedule
def test_bucket_schedule_pinned():
    """The shared power-of-two bucket schedule (one jit compile per bucket)
    is pinned, and every padded path (shard read batches + delta vectors;
    the scheduler consumes the shard's lane meters) uses the ONE helper in
    config — the former shard-local ``_bucket`` copy is gone."""
    assert [bucket_pow2(n) for n in range(11)] == \
        [1, 1, 2, 4, 4, 8, 8, 8, 8, 16, 16]
    assert bucket_pow2(256) == 256 and bucket_pow2(257) == 512
    from repro.core import config, shard
    assert shard.bucket_pow2 is config.bucket_pow2
    assert not hasattr(shard, "_bucket")
    # the scheduler's device-lane meters agree with the shard's padding
    st = HoneycombStore(SMALL, heap_capacity=256)
    for i in range(20):
        st.put(int_key(i), b"v")
    st.export_snapshot()
    sched = OutOfOrderScheduler(batch_size=8)
    for i in range(5):
        sched.submit("get", int_key(i))
    sched.run(st)
    assert sched.stats.dispatched_lanes == 5
    assert sched.stats.padded_lanes == bucket_pow2(5)
