"""Replication subsystem (core/replica.py): the replicas=1/primary_only
op-for-op equivalence invariant (results AND sync byte counts), randomized
read-spreading correctness under concurrent writes (freshness rule: a
lagging follower is never served), O(replicas x dirty_rows) delta feeding,
epoch/read-version lag meters, policy auto-sync feeding, pause/resume
catch-up, and scheduler replica bucketing."""
import numpy as np
import pytest

from repro.core import (HoneycombConfig, HoneycombStore, OutOfOrderScheduler,
                        ReplicationConfig, ShardedHoneycombStore,
                        uniform_int_boundaries)
from repro.core.keys import int_key

SMALL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)
EXPL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                       sync_policy="explicit")
B2 = uniform_int_boundaries(200, 2)


def replicated(cfg=SMALL, shards=1, replicas=2, policy="round_robin",
               feed="log"):
    return ShardedHoneycombStore(
        cfg, heap_capacity=256, shards=shards,
        boundaries=B2 if shards == 2 else None,
        replication=ReplicationConfig(replicas=replicas, policy=policy,
                                      feed=feed))


def apply_random_ops(stores, oracle, rng, n, key_space=200):
    for _ in range(n):
        k = int_key(int(rng.integers(0, key_space)))
        op = rng.random()
        if op < 0.55:
            v = bytes(rng.integers(65, 91, 8))
            for s in stores:
                s.put(k, v)
            oracle[k] = v
        elif op < 0.8:
            v = bytes(rng.integers(97, 123, 8))
            for s in stores:
                s.update(k, v)
            oracle[k] = v
        else:
            for s in stores:
                s.delete(k)
            oracle.pop(k, None)


# ---------------------------------------------------------------- (a) the
# equivalence invariant, mirroring PR 2's shards=1 and PR 3's serial mode
def test_replicas1_primary_only_identical_to_unreplicated():
    """replicas=1 + primary_only is operation-for-operation the
    unreplicated store: same results, same sync byte counts, no follower
    traffic, no replica machinery on any path."""
    un = HoneycombStore(SMALL, heap_capacity=256)
    rp = replicated(replicas=1, policy="primary_only")
    oracle = {}
    rng = np.random.default_rng(9)
    for round_ in range(4):
        apply_random_ops((un, rp), oracle, rng, 60)
        keys = [int_key(i) for i in range(0, 200, 7)]
        assert un.get_batch(keys) == rp.get_batch(keys) \
            == [oracle.get(k) for k in keys]
        ranges = [(int_key(a), int_key(a + 9)) for a in range(0, 180, 31)]
        assert un.scan_batch(ranges) == rp.scan_batch(ranges)
        un.export_snapshot()
        rp.export_snapshot()
        assert un.sync_stats == rp.sync_stats, round_
    assert un.sync_stats.delta_syncs > 0       # the delta path was exercised
    assert rp.replication_bytes == 0           # zero follower amplification
    assert rp.lagging_skips == 0
    assert rp.shards[0].n_replicas == 1


# ------------------------------------------------------- (b) read spreading
def test_randomized_spreading_matches_primary_only_under_writes():
    """Randomized round-robin read spreading returns results identical to
    primary-only under concurrent writes — including with an injected
    lagging follower, which is skipped (freshness rule), never served
    stale."""
    ref = ShardedHoneycombStore(
        EXPL, heap_capacity=256, shards=2, boundaries=B2,
        replication=ReplicationConfig(1, "primary_only"))
    spr = ShardedHoneycombStore(
        EXPL, heap_capacity=256, shards=2, boundaries=B2,
        replication=ReplicationConfig(3, "round_robin"))
    oracle = {}
    rng = np.random.default_rng(17)
    paused = spr.shards[0]
    for round_ in range(5):
        apply_random_ops((ref, spr), oracle, rng, 40)
        if round_ == 2:                 # inject replication lag on shard 0
            paused.pause_follower(1)
        ref.export_snapshot()
        spr.export_snapshot()
        # writes AFTER the sync: device reads stay at the admitted version
        apply_random_ops((ref, spr), oracle, rng, 12)
        keys = [int_key(int(k)) for k in rng.integers(0, 200, 24)]
        assert spr.get_batch(keys) == ref.get_batch(keys)
        ranges = [(int_key(a), int_key(a + 15)) for a in
                  (3, 47, 92, 120, 160)]          # 47/92 cross the boundary
        assert spr.scan_batch(ranges) == ref.scan_batch(ranges)
    # the spread actually happened: follower replicas served requests...
    follower_ops = [f.served_ops for g in spr.shards for f in g.followers]
    assert sum(follower_ops) > 0
    # ...but the paused follower froze the moment it started lagging: the
    # policy routes around it at pick time (no per-turn redirects), and an
    # explicit pin is redirected by the dispatch-time freshness backstop
    assert paused.replica_lag_epochs[0] > 0
    assert 1 not in paused.eligible_replicas()
    frozen = paused.followers[0].served_ops
    keys = [int_key(i) for i in range(0, 100, 5)]
    for _ in range(4):
        assert spr.get_batch(keys) == ref.get_batch(keys)
    assert paused.followers[0].served_ops == frozen
    skips0 = paused.lagging_skips
    assert paused.get_batch([int_key(3)], replica=1) \
        == ref.shards[0].get_batch([int_key(3)])
    assert paused.lagging_skips == skips0 + 1
    # resume + resync: the follower catches up (full copy) and serves again
    paused.resume_follower(1)
    paused.resync_follower(1)
    assert paused.replica_lag_epochs[0] == 0
    assert paused.replica_staleness[0] == 0
    before = paused.followers[0].served_ops
    for _ in range(6):
        assert spr.get_batch(keys) == ref.get_batch(keys)
    assert paused.followers[0].served_ops > before


def test_least_loaded_policy_balances_replica_lanes():
    st = replicated(replicas=3, policy="least_loaded")
    for i in range(200):
        st.put(int_key(i), b"v%d" % i)
    st.export_snapshot()
    keys = [int_key(i) for i in range(0, 200, 10)]
    for _ in range(9):
        assert st.get_batch(keys) == [b"v%d" % i for i in range(0, 200, 10)]
    ops = st.shards[0].replica_ops
    assert all(o > 0 for o in ops)
    assert max(ops) - min(ops) <= len(keys)    # within one batch of even
    assert st.replica_load_imbalance == pytest.approx(1.0, abs=0.35)


# --------------------------------------------------------- (c) feed costs
def test_delta_feed_costs_o_replicas_times_dirty_rows():
    """Feeding N followers costs O(N x dirty_rows) bytes — each follower
    re-applies exactly the primary's delta (same bytes, same rows) — not
    O(N x store_size), measured via per-replica SyncStats.  Pinned to the
    image-row delta feed; the log feed's (much cheaper) accounting is
    covered by tests/test_log_feed.py."""
    st = replicated(replicas=3, feed="delta")
    for i in range(200):
        st.put(int_key(i), b"v" * 8)
    st.export_snapshot()                  # full publish + full follower copy
    g = st.shards[0]
    assert [f.sync_stats.full_syncs for f in g.followers] == [1, 1]
    full_bytes = g.followers[0].sync_stats.bytes_synced
    assert full_bytes > 0
    p0 = g.primary.sync_stats.bytes_synced
    pr0 = g.primary.sync_stats.delta_rows
    f0 = [f.sync_stats.bytes_synced for f in g.followers]
    for i in range(100, 108):             # small dirty set
        st.update(int_key(i), b"u" * 8)
    st.export_snapshot()
    p_delta = g.primary.sync_stats.bytes_synced - p0
    p_rows = g.primary.sync_stats.delta_rows - pr0
    assert 0 < p_rows < 20
    for f, b0 in zip(g.followers, f0):
        fd = f.sync_stats.bytes_synced - b0
        assert fd == p_delta              # byte-identical delta per replica
        assert f.sync_stats.delta_rows == p_rows
        assert f.sync_stats.delta_syncs == 1
        assert fd < 0.25 * full_bytes     # O(dirty), not O(store)
    assert st.replication_bytes == sum(f.sync_stats.bytes_synced
                                       for f in g.followers)
    # amplification is exactly (replicas - 1) x the primary's delta
    assert st.replication_bytes - sum(f0) == 2 * p_delta


def test_follower_reads_serve_from_follower_snapshot():
    """A batch pinned to a follower executes against the FOLLOWER's device
    image (its own buffers), not the primary's — proven by divergence when
    the follower is frozen under the explicit sync policy."""
    st = replicated(cfg=EXPL, replicas=2)
    for i in range(50):
        st.put(int_key(i), b"old%d" % i)
    st.export_snapshot()
    g = st.shards[0]
    keys = [int_key(i) for i in range(0, 50, 5)]
    # follower pinned explicitly serves the same data
    assert g.get_batch(keys, replica=1) == [b"old%d" % i
                                            for i in range(0, 50, 5)]
    assert g.followers[0].served_ops == len(keys)
    # freeze the follower, move the primary ahead one epoch
    g.pause_follower(1)
    for i in range(50):
        st.update(int_key(i), b"new%d" % i)
    st.export_snapshot()
    # a batch pinned to the lagging follower is NOT served stale: the
    # freshness rule redirects it to the primary's (new) snapshot
    assert g.get_batch(keys, replica=1) == [b"new%d" % i
                                            for i in range(0, 50, 5)]
    assert g.lagging_skips == 1
    assert g.followers[0].served_ops == len(keys)   # unchanged


# ------------------------------------------------------------- lag meters
def test_epoch_and_staleness_lag_meters():
    st = replicated(cfg=EXPL, replicas=2)
    g = st.shards[0]
    for i in range(40):
        st.put(int_key(i), b"a")
    st.export_snapshot()
    assert g.replica_lag_epochs == [0]
    assert g.replica_staleness == [0]
    g.pause_follower(1)
    for round_ in range(2):               # two epochs while paused
        for i in range(40):
            st.update(int_key(i), b"b%d" % round_)
        st.export_snapshot()
    assert g.replica_lag_epochs == [2]
    assert g.replica_staleness[0] > 0
    # resync: immediate full catch-up, metered as a follower full sync
    full0 = g.followers[0].sync_stats.full_syncs
    g.resume_follower(1)
    g.resync_follower(1)
    assert g.replica_lag_epochs == [0]
    assert g.replica_staleness == [0]
    assert g.followers[0].sync_stats.full_syncs == full0 + 1


def test_resumed_follower_catches_up_full_on_next_sync():
    """A follower that missed a delta cannot replay later deltas onto its
    stale base: the next feed after resume is a FULL copy, after which
    delta feeding resumes (pinned to the delta feed so the resumed-path
    meters stay delta_syncs, not log_replays)."""
    st = replicated(cfg=EXPL, replicas=2, feed="delta")
    g = st.shards[0]
    for i in range(60):
        st.put(int_key(i), b"v")
    st.export_snapshot()
    g.pause_follower(1)
    for i in range(8):
        st.update(int_key(i), b"w")
    st.export_snapshot()                  # missed by the follower
    g.resume_follower(1)
    f = g.followers[0]
    deltas0, fulls0 = f.sync_stats.delta_syncs, f.sync_stats.full_syncs
    for i in range(8):
        st.update(int_key(i), b"x")
    st.export_snapshot()                  # catch-up round
    assert f.sync_stats.full_syncs == fulls0 + 1
    assert f.sync_stats.delta_syncs == deltas0
    assert g.replica_lag_epochs == [0]
    keys = [int_key(i) for i in range(8)]
    assert g.get_batch(keys, replica=1) == [b"x"] * 8
    for i in range(8):
        st.update(int_key(i), b"y")
    st.export_snapshot()                  # back on the delta feed
    assert f.sync_stats.delta_syncs == deltas0 + 1


def test_every_k_policy_auto_sync_feeds_followers():
    """A sync triggered by the shard's own "every_k" policy — not through
    the group facade — still feeds every follower (the staging/flip hooks
    fire on every path)."""
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                          sync_policy="every_k", sync_every_k=8)
    st = ShardedHoneycombStore(
        cfg, heap_capacity=256, shards=1,
        replication=ReplicationConfig(2, "round_robin"))
    for i in range(32):
        st.put(int_key(i), b"v%d" % i)    # 4 automatic policy syncs
    g = st.shards[0]
    assert g.primary.epoch >= 4
    assert g.replica_lag_epochs == [0]
    assert g.followers[0].sync_stats.snapshots \
        == g.primary.sync_stats.snapshots
    assert g.get_batch([int_key(3)], replica=1) == [b"v3"]


# ---------------------------------------------------------------- scheduler
def test_scheduler_buckets_by_replica_and_spreads_reads():
    """The scheduler pins each read to a replica at submit, buckets by
    (shard, replica, kind, cost_class), and dispatch spreads over the
    replica set with correct, in-arrival-order responses."""
    st = replicated(replicas=2, policy="round_robin")
    for i in range(100):
        st.put(int_key(i), b"v%d" % i)
    st.export_snapshot()
    sched = OutOfOrderScheduler(batch_size=4, routing=st.routing())
    rids = {sched.submit("get", int_key(i * 7 % 100)): i * 7 % 100
            for i in range(16)}
    out = sched.run(st)
    for rid, k in rids.items():
        assert out[rid] == b"v%d" % k
    # 16 gets round-robined over 2 replicas -> two 8-deep buckets -> 4
    # replica-homogeneous batches
    assert sched.dispatched_batches == 4
    ops = st.shards[0].replica_ops
    assert ops == [8, 8]
    # writes interleave correctly and the pipelined export feeds replicas
    sched2 = OutOfOrderScheduler(batch_size=4, routing=st.routing(),
                                 pipeline="pipelined")
    for i in range(8):
        sched2.submit("update", int_key(i), value=b"w%d" % i)
    for i in range(8):
        sched2.submit("get", int_key(i))
    out2 = sched2.run(st)
    gets = [v for v in out2.values() if v is not None]
    assert sorted(gets) == sorted(b"w%d" % i for i in range(8))
    assert st.shards[0].replica_lag_epochs == [0]


def test_round_robin_rotates_within_every_shard():
    """Multi-shard batches rotate EVERY shard's replica assignment: the
    cursor is per shard, so a batch spanning N shards cannot freeze each
    shard onto one fixed replica by cursor parity."""
    st = replicated(shards=2, replicas=2)
    for i in range(200):
        st.put(int_key(i), b"v%d" % i)
    st.export_snapshot()
    keys = [int_key(10), int_key(150)]        # spans both shards every call
    for _ in range(8):
        assert st.get_batch(keys) == [b"v10", b"v150"]
    for g in st.shards:                        # both lanes of BOTH shards
        assert g.replica_ops == [4, 4]


def test_policies_route_around_lagging_follower():
    """A paused/lagging follower drops out of the eligible set, so
    least_loaded neither soaks assignments into the dead lane nor redirects
    every turn — the healthy lanes split the load."""
    st = replicated(cfg=EXPL, replicas=3, policy="least_loaded")
    g = st.shards[0]
    for i in range(100):
        st.put(int_key(i), b"v%d" % i)
    st.export_snapshot()
    g.pause_follower(1)
    for i in range(10):
        st.update(int_key(i), b"w%d" % i)
    st.export_snapshot()                       # follower 1 now lags
    assert g.eligible_replicas() == [0, 2]
    keys = [int_key(i) for i in range(0, 100, 10)]
    for _ in range(8):
        st.get_batch(keys)
    assert st.lagging_skips == 0               # routed around, no redirects
    assert g.followers[0].served_ops == 0
    assert g.replica_ops[0] > 0 and g.replica_ops[2] > 0
    assert abs(g.replica_ops[0] - g.replica_ops[2]) <= len(keys)


def test_missed_staging_keeps_epoch_lag_honest():
    """A follower that missed an intermediate staging does NOT publish its
    older standby under the new epoch: the lag meters stay truthful and
    the freshness rule redirects pinned reads."""
    st = replicated(cfg=EXPL, replicas=2)
    g = st.shards[0]
    for i in range(40):
        st.put(int_key(i), b"v")
    st.export_snapshot()
    for i in range(8):
        st.update(int_key(i), b"a")
    st.begin_export()                          # D1: follower stages too
    g.pause_follower(1)
    for i in range(8):
        st.update(int_key(i), b"b")
    st.begin_export()                          # D2: follower misses it
    g.resume_follower(1)
    st.flip()
    # the follower's D1-content standby must not masquerade as caught up
    assert g.replica_lag_epochs == [1]
    assert g.replica_staleness[0] > 0
    assert g.get_batch([int_key(0)], replica=1) == [b"b"]   # redirected
    assert g.lagging_skips == 1


def test_scheduler_least_loaded_spreads_within_a_burst():
    """least_loaded picks by ASSIGNED batches, so a whole burst pinned at
    submit time (before any dispatch updates served_ops) still spreads
    over the replica set instead of degenerating onto one lane."""
    st = replicated(replicas=2, policy="least_loaded")
    for i in range(100):
        st.put(int_key(i), b"v%d" % i)
    st.export_snapshot()
    sched = OutOfOrderScheduler(batch_size=4, routing=st.routing())
    rids = {sched.submit("get", int_key(i * 3 % 100)): i * 3 % 100
            for i in range(16)}
    out = sched.run(st)
    for rid, k in rids.items():
        assert out[rid] == b"v%d" % k
    assert st.shards[0].replica_ops == [8, 8]


def test_replication_config_validation():
    with pytest.raises(AssertionError):
        ReplicationConfig(replicas=0)
    with pytest.raises(AssertionError):
        ReplicationConfig(replicas=2, policy="chaos")
    ReplicationConfig(replicas=4, policy="least_loaded")
