"""Range-sharded serving stack: router edge cases, shards=1 equivalence
with the unsharded store (results AND sync byte counts), per-shard delta
independence, shard-aware scheduling, and the explicit-policy host-fallback
read-version pin."""
import numpy as np
import pytest

from repro.core import (HoneycombConfig, HoneycombStore, OutOfOrderScheduler,
                        ReplicationConfig, ShardedHoneycombStore,
                        ShardingConfig, bucket_pow2, uniform_int_boundaries)
from repro.core.keys import int_key
from repro.core.shard import WIRE_ENTRY_OVERHEAD

SMALL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)
B4 = uniform_int_boundaries(200, 4)     # 4 shards over int keys [0, 200)


def apply_random_ops(stores, oracle, rng, n, key_space=200):
    for _ in range(n):
        k = int_key(int(rng.integers(0, key_space)))
        op = rng.random()
        if op < 0.55:
            v = bytes(rng.integers(65, 91, 8))
            for s in stores:
                s.put(k, v)
            oracle[k] = v
        elif op < 0.8:
            v = bytes(rng.integers(97, 123, 8))
            for s in stores:
                s.update(k, v)
            oracle[k] = v
        else:
            for s in stores:
                s.delete(k)
            oracle.pop(k, None)


def test_single_shard_router_equivalent_to_unsharded():
    """ShardedHoneycombStore(shards=1) is operation-for-operation the
    unsharded store: same results, same sync byte counts, same meters."""
    un = HoneycombStore(SMALL, heap_capacity=256)
    sh = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=1)
    oracle = {}
    rng = np.random.default_rng(9)
    for round_ in range(4):
        apply_random_ops((un, sh), oracle, rng, 60)
        keys = [int_key(i) for i in range(0, 200, 7)]
        assert un.get_batch(keys) == sh.get_batch(keys) \
            == [oracle.get(k) for k in keys]
        ranges = [(int_key(a), int_key(a + 9)) for a in range(0, 180, 31)]
        assert un.scan_batch(ranges) == sh.scan_batch(ranges)
        un.export_snapshot()
        sh.export_snapshot()
        assert un.sync_stats == sh.sync_stats, round_
    assert un.sync_stats.delta_syncs > 0
    assert sh.scan(int_key(3), int_key(170), max_items=11) \
        == un.scan(int_key(3), int_key(170), max_items=11)


def test_cross_shard_scan_matches_unsharded():
    """A scan spanning >= 3 shards returns exactly the unsharded result,
    stitched in key order."""
    un = HoneycombStore(SMALL, heap_capacity=256)
    sh = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                               boundaries=B4)
    oracle = {}
    rng = np.random.default_rng(3)
    apply_random_ops((un, sh), oracle, rng, 250)
    un.export_snapshot()
    sh.export_snapshot()
    # (5, 195) spans all four shards; (40, 160) spans three
    ranges = [(int_key(5), int_key(195)), (int_key(40), int_key(160)),
              (int_key(51), int_key(99))]
    got = sh.scan_batch(ranges)
    assert got == un.scan_batch(ranges)
    for (lo, hi), items in zip(ranges, got):
        assert items == un.tree.scan(lo, hi)
        assert [k for k, _ in items] == sorted(k for k, _ in items)
    # host-side facade agrees too (incl. max_items truncation)
    assert sh.scan(int_key(5), int_key(195), max_items=17) \
        == un.scan(int_key(5), int_key(195), max_items=17)


def test_empty_shards_and_floor_backfill():
    """Shards holding no keys scan/get cleanly, and the global floor item is
    back-filled from the nearest non-empty shard to the left."""
    un = HoneycombStore(SMALL, heap_capacity=256)
    sh = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                               boundaries=B4)
    for i in range(0, 40):                      # shard 0 only (keys < 50)
        for s in (un, sh):
            s.put(int_key(i), b"v%d" % i)
    un.export_snapshot()
    sh.export_snapshot()
    # GETs routed to empty shards
    assert sh.get_batch([int_key(60), int_key(120), int_key(180)]) \
        == [None, None, None]
    # scan starting inside empty shard 2: floor (key 39) lives two shards
    # to the left, across an empty shard — exactly the unsharded answer
    ranges = [(int_key(120), int_key(190)), (int_key(55), int_key(80)),
              (int_key(10), int_key(199))]
    assert sh.scan_batch(ranges) == un.scan_batch(ranges)
    assert sh.scan_batch([(int_key(120), int_key(190))])[0] \
        == [(int_key(39), b"v39")]
    # a fully empty keyspace region with nothing to the left
    sh2 = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                                boundaries=B4)
    sh2.export_snapshot()
    assert sh2.scan_batch([(int_key(60), int_key(190))]) == [[]]
    assert sh2.scan(int_key(0), int_key(199)) == []


def test_boundary_keys_route_and_scan_once():
    """A key equal to a shard boundary belongs to the upper shard and shows
    up exactly once in cross-boundary scans."""
    sh = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                               boundaries=B4)
    for b, want_shard in zip(B4, (1, 2, 3)):
        assert sh.shard_for_key(b) == want_shard
    boundary_keys = list(B4)                    # int keys 50, 100, 150
    for k in boundary_keys:
        sh.put(k, b"edge")
    sh.put(int_key(49), b"below")
    sh.export_snapshot()
    assert sh.get_batch(boundary_keys) == [b"edge"] * 3
    items = sh.scan_batch([(int_key(0), int_key(199))])[0]
    assert items == [(int_key(49), b"below")] + [(k, b"edge")
                                                 for k in boundary_keys]
    # per-key ownership: the boundary write dirtied the upper shard
    assert sh.shards[0].sync_stats.log_entries == 1     # only key 49
    assert all(sh.shards[i].sync_stats.log_entries == 1 for i in (1, 2, 3))


def test_per_shard_delta_sync_independence():
    """A write burst confined to one shard delta-syncs ONLY that shard."""
    sh = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                               boundaries=B4)
    for i in range(0, 200, 2):
        sh.put(int_key(i), b"v")
    sh.export_snapshot()                        # every shard resident
    snaps0 = [s.snapshots for s in sh.per_shard_sync_stats]
    bytes0 = [s.bytes_synced for s in sh.per_shard_sync_stats]
    for i in range(100, 148, 2):                # shard 2 only ([100, 150))
        sh.update(int_key(i), b"u")
    sh.export_snapshot()
    snaps = [s.snapshots - a for s, a in zip(sh.per_shard_sync_stats, snaps0)]
    moved = [s.bytes_synced - a for s, a in zip(sh.per_shard_sync_stats,
                                                bytes0)]
    assert snaps == [0, 0, 1, 0]
    assert moved[0] == moved[1] == moved[3] == 0
    assert moved[2] > 0
    assert sh.per_shard_sync_stats[2].delta_syncs == 1
    assert sh.get_batch([int_key(100), int_key(2)]) == [b"u", b"v"]


def test_sharded_scheduler_buckets_and_per_shard_sync():
    """The scheduler buckets by (shard, kind, cost class), applies writes in
    order through the router, syncs once per dirty shard, and delivers
    responses in arrival order."""
    sh = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                               boundaries=B4)
    for i in range(200):
        sh.put(int_key(i), b"v%d" % i)
    sh.export_snapshot()
    sched = OutOfOrderScheduler(batch_size=8, routing=sh.routing())
    rng = np.random.default_rng(2)
    gets = {}
    for _ in range(40):
        k = int(rng.integers(0, 200))
        gets[sched.submit("get", int_key(k))] = k
    scans = {}
    for a in (10, 60, 110, 160, 95):            # last one crosses a boundary
        scans[sched.submit("scan", int_key(a), int_key(a + 8),
                           expected_items=9)] = (a, a + 8)
    writes = [sched.submit("update", int_key(i), value=b"w%d" % i)
              for i in range(48, 52)]           # dirties shards 0 and 1 only
    out = sched.run(sh)
    assert sched.syncs == 2                     # exactly the dirty shards
    assert sched.applied_writes == 4
    for rid, k in gets.items():
        want = b"w%d" % k if 48 <= k < 52 else b"v%d" % k
        assert out[rid] == want
    for rid, (a, b) in scans.items():
        assert out[rid] == sh.scan(int_key(a), int_key(b))
    assert all(out[r] is None for r in writes)
    # buckets are shard-homogeneous: 40 gets over 4 shards + 5 scan buckets
    # can never fit one 8-request batch per shard exactly — just check the
    # dispatch consumed everything ready_batches would have produced
    assert sched.dispatched_requests == 45
    assert list(sched.ready_batches(flush=True)) == []


def test_run_consumes_ready_batches():
    """run() and ready_batches() share one dispatch path: without flush,
    partial batches stay queued in both."""
    sh = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=2,
                               boundaries=uniform_int_boundaries(200, 2))
    for i in range(200):
        sh.put(int_key(i), b"x")
    sh.export_snapshot()
    sched = OutOfOrderScheduler(batch_size=4, routing=sh.routing())
    for i in (0, 1, 2, 3, 120, 121):            # full shard-0, partial shard-1
        sched.submit("get", int_key(i))
    out = sched.run(sh, flush=False)
    assert len(out) == 4                        # only the full bucket went
    assert sched.dispatched_batches == 1
    out2 = sched.run(sh, flush=True)
    assert len(out2) == 2
    assert list(sched.ready_batches(flush=True)) == []


def test_explicit_policy_pins_host_fallback_to_snapshot():
    """Satellite: under sync_policy="explicit", a truncated device SCAN's
    host fallback runs at the RESIDENT SNAPSHOT's read version — never the
    (newer) live tree — and survives GC thanks to the snapshot epoch pin."""
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                          sync_policy="explicit", max_scan_items=4,
                          max_scan_leaves=1)
    st = HoneycombStore(cfg, heap_capacity=256)
    for i in range(60):
        st.put(int_key(i), b"old-%02d" % i)
    st.export_snapshot()
    for i in range(60):                         # live tree moves ahead
        st.update(int_key(i), b"new-%02d" % i)
    # range way over max_scan_items -> device truncates -> host fallback
    items = st.scan_batch([(int_key(0), int_key(50))])[0]
    assert len(items) == 51
    assert all(v.startswith(b"old") for _, v in items)
    # GC while the stale snapshot is resident must not free the old buffers
    st.tree.epochs.cpu_begin(0)
    st.collect_garbage()
    assert st.scan_batch([(int_key(0), int_key(50))])[0] == items
    # the explicit sync rolls the pin forward and fallbacks see the new data
    st.export_snapshot()
    items2 = st.scan_batch([(int_key(0), int_key(50))])[0]
    assert all(v.startswith(b"new") for _, v in items2)


def test_wire_format_metering():
    """Satellite: SyncStats meters the append-only log-entry wire format
    (key+value+op) alongside the dirty-row bytes."""
    st = HoneycombStore(SMALL, heap_capacity=256)
    st.put(b"abcd", b"0123456789")
    st.update(b"abcd", b"xy")
    st.delete(b"abcd")
    s = st.sync_stats
    assert s.log_entries == 3
    assert s.log_wire_bytes == (4 + 10 + WIRE_ENTRY_OVERHEAD) \
        + (4 + 2 + WIRE_ENTRY_OVERHEAD) + (4 + 0 + WIRE_ENTRY_OVERHEAD)
    # router aggregates the meter across shards
    sh = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                               boundaries=B4)
    for i in range(0, 200, 10):
        sh.put(int_key(i), b"v" * 6)
    assert sh.sync_stats.log_entries == 20
    assert sh.sync_stats.log_wire_bytes == 20 * (8 + 6 + WIRE_ENTRY_OVERHEAD)
    assert sum(s.log_entries for s in sh.per_shard_sync_stats) == 20


def test_sharding_config_validation():
    with pytest.raises(AssertionError):
        ShardingConfig(shards=0)
    with pytest.raises(AssertionError):
        ShardingConfig(shards=3, boundaries=(b"a",))       # wrong count
    with pytest.raises(AssertionError):
        ShardingConfig(shards=3, boundaries=(b"b", b"a"))  # not ascending
    ShardingConfig(shards=4, boundaries=B4)                # valid
    # router accepts a prebuilt ShardingConfig verbatim
    sh = ShardedHoneycombStore(
        SMALL, shards=ShardingConfig(shards=4, boundaries=B4))
    assert sh.n_shards == 4 and sh.boundaries == list(B4)


def test_router_load_imbalance_meter():
    sh = ShardedHoneycombStore(SMALL, heap_capacity=256, shards=4,
                               boundaries=B4)
    assert sh.load_imbalance == 0.0
    for i in range(0, 200, 4):                  # balanced writes
        sh.put(int_key(i), b"v")
    assert sh.load_imbalance == pytest.approx(1.0, abs=0.1)
    for i in range(40):                         # skew at shard 0
        sh.get(int_key(5))
    assert sh.load_imbalance > 1.5


def test_replica_ragged_batch_padding_and_load_metering():
    """Satellite: ragged per-replica sub-batches still pad to the shared
    pow2 bucket schedule (one jit compile per bucket, whichever replica's
    image the batch executes against), and the router meters the per-lane
    read spread (replica_load_imbalance) alongside shard imbalance."""
    sh = ShardedHoneycombStore(
        SMALL, heap_capacity=256, shards=1,
        replication=ReplicationConfig(replicas=2, policy="round_robin"))
    assert sh.replica_load_imbalance == 0.0
    for i in range(100):
        sh.put(int_key(i), b"v%d" % i)
    sh.export_snapshot()
    ps0 = sh.pipeline_stats
    lanes0, padded0 = ps0.dispatched_lanes, ps0.padded_lanes
    # two ragged batches, round-robined onto different replicas
    assert sh.get_batch([int_key(i) for i in range(5)]) \
        == [b"v%d" % i for i in range(5)]
    assert sh.get_batch([int_key(i) for i in range(3)]) \
        == [b"v%d" % i for i in range(3)]
    ps = sh.pipeline_stats
    assert ps.dispatched_lanes - lanes0 == 8
    assert ps.padded_lanes - padded0 == bucket_pow2(5) + bucket_pow2(3)
    # one batch per replica lane: 5 on the primary, 3 on the follower
    assert sh.per_shard_replica_ops == [[5, 3]]
    assert sh.replica_load_imbalance == pytest.approx(5 / 4)
    # ragged scans pad on the same schedule and spread the same way
    ranges = [(int_key(a), int_key(a + 4)) for a in (0, 20, 40)]
    sh.scan_batch(ranges)
    ps2 = sh.pipeline_stats
    assert ps2.padded_lanes - ps.padded_lanes == bucket_pow2(3)
    assert sum(sum(ops) for ops in sh.per_shard_replica_ops) == 11
    # replica lanes are invisible to the SHARD imbalance meter (still one
    # shard's traffic) but visible to the replica meter
    assert sh.load_imbalance == pytest.approx(1.0)
