"""Test harness glue.

This environment cannot install ``hypothesis``; the property tests in
test_btree / test_keys / test_read_path only use a small strategy subset, so
when the real package is missing we register a deterministic seeded-PRNG
shim under the same import name.  Each ``@given`` test runs ``max_examples``
times against values drawn from a PRNG seeded by the test name, which keeps
failures reproducible run-to-run while exercising the same invariants.
"""
from __future__ import annotations

import functools
import sys
import types
import zlib

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")


# --------------------------------------------------------------------------
# minimal hypothesis stand-in (only built when hypothesis is absent)
# --------------------------------------------------------------------------

def _build_hypothesis_shim() -> types.ModuleType:
    import numpy as np

    class Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def flatmap(self, f):
            return Strategy(lambda rng: f(self.draw(rng)).draw(rng))

        def map(self, f):
            return Strategy(lambda rng: f(self.draw(rng)))

    def integers(min_value, max_value):
        return Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def just(value):
        return Strategy(lambda rng: value)

    def binary(min_size=0, max_size=64):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return rng.integers(0, 256, n, dtype=np.uint8).tobytes()
        return Strategy(draw)

    def lists(elements, min_size=0, max_size=16):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return Strategy(draw)

    def tuples(*strategies):
        return Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.SearchStrategy = Strategy
    strategies.integers = integers
    strategies.just = just
    strategies.binary = binary
    strategies.lists = lists
    strategies.tuples = tuples

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*gstrategies):
        def deco(fn):
            n_examples = getattr(fn, "_shim_max_examples", 20)

            @functools.wraps(fn)
            def runner(*args, **kwargs):
                # zlib.crc32, not hash(): str hashing is salted per process
                name = (fn.__module__ + "." + fn.__name__).encode()
                seed = zlib.crc32(name)
                rng = np.random.default_rng(seed)
                for _ in range(n_examples):
                    drawn = tuple(s.draw(rng) for s in gstrategies)
                    fn(*args, *drawn, **kwargs)
            # pytest resolves fixtures through __wrapped__'s signature; the
            # drawn parameters must not look like fixtures
            del runner.__wrapped__
            return runner
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__shim__ = True
    return mod, strategies


try:  # pragma: no cover - exercised implicitly by collection
    import hypothesis  # noqa: F401
except ImportError:
    _mod, _strategies = _build_hypothesis_shim()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _strategies
