"""Out-of-order scheduler + interior-node cache / load balancer."""
import numpy as np

from repro.core import HoneycombConfig, HoneycombStore
from repro.core.cache import InteriorCache
from repro.core.keys import int_key
from repro.core.scheduler import OutOfOrderScheduler


def test_scheduler_in_order_delivery():
    store = HoneycombStore(HoneycombConfig(node_cap=16, log_cap=4,
                                           n_shortcuts=4))
    for i in range(100):
        store.put(int_key(i), b"v%d" % i)
    sched = OutOfOrderScheduler(batch_size=8)
    rids = {}
    rng = np.random.default_rng(0)
    for _ in range(20):
        k = int(rng.integers(0, 100))
        rids[sched.submit("get", int_key(k))] = k
    for _ in range(10):
        a = int(rng.integers(0, 90))
        rids[sched.submit("scan", int_key(a), int_key(a + 3),
                          expected_items=4)] = (a, a + 3)
    out = sched.run(store)
    assert set(out) == set(rids)
    for rid, spec in rids.items():
        if isinstance(spec, int):
            assert out[rid] == b"v%d" % spec
        else:
            assert out[rid] == store.tree.scan(int_key(spec[0]),
                                               int_key(spec[1]))
    assert sched.dispatched_requests == 30


def test_scheduler_cost_bucketing():
    sched = OutOfOrderScheduler(batch_size=4, cost_classes=(1, 16))
    for i in range(6):
        sched.submit("scan", b"a", b"b", expected_items=1)
    for i in range(3):
        sched.submit("scan", b"a", b"b", expected_items=10)
    batches = list(sched.ready_batches(flush=True))
    sizes = sorted(len(b) for _, b in batches)
    assert sizes == [2, 3, 4]          # same-cost requests batch together


def test_cache_hit_invalidate():
    cfg = HoneycombConfig(cache_slots=16, cache_ways=4, load_balance=False)
    c = InteriorCache(cfg)
    assert not c.lookup(5, phys=100)     # miss fills
    assert c.lookup(5, phys=100)         # hit
    assert not c.lookup(5, phys=200)     # phys changed (remap) -> NAT miss
    assert c.stats.invalidations == 1
    c.invalidate(5)


def test_load_balancer_routes_to_both_paths():
    cfg = HoneycombConfig(cache_slots=64, load_balance=True,
                          lb_fast_fraction=0.6)
    c = InteriorCache(cfg)
    for lid in range(32):
        c.lookup(lid, lid)               # warm
    for _ in range(200):
        for lid in range(32):
            c.route(lid, lid, nbytes=1024)
    assert c.stats.fast_path_reads > 0
    assert c.stats.slow_path_reads > 0   # hits deliberately sent slow
    frac = c.stats.fast_path_reads / (c.stats.fast_path_reads
                                      + c.stats.slow_path_reads)
    assert 0.4 < frac < 0.8


def test_no_lb_keeps_hits_fast():
    cfg = HoneycombConfig(cache_slots=64, load_balance=False)
    c = InteriorCache(cfg)
    for lid in range(8):
        c.lookup(lid, lid)
    for _ in range(50):
        for lid in range(8):
            c.route(lid, lid, nbytes=512)
    assert c.stats.slow_path_reads == 0


def test_inflight_telemetry_balancing():
    cfg = HoneycombConfig(cache_slots=64, load_balance=True)
    c = InteriorCache(cfg)
    c.lookup(1, 1)
    assert c.route(1, 1, 64, fast_inflight=100, slow_inflight=0) == "slow"
    assert c.route(1, 1, 64, fast_inflight=0, slow_inflight=100) == "fast"
