"""Pallas kernels: interpret-mode vs pure-jnp oracle, shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("B,N,KW", [(8, 16, 4), (128, 64, 8), (50, 8, 2),
                                    (3, 80, 8)])
def test_key_search_sweep(B, N, KW):
    keys = RNG.integers(0, 60, (B, N, KW)).astype(np.uint32)
    klens = RNG.integers(0, KW * 4 + 1, (B, N)).astype(np.int32)
    valid = (RNG.random((B, N)) < 0.8).astype(np.int32)
    q = RNG.integers(0, 60, (B, KW)).astype(np.uint32)
    qlen = RNG.integers(1, KW * 4 + 1, (B,)).astype(np.int32)
    a = ops.key_search(q, qlen, keys, klens, valid, backend="interpret",
                       block_b=16)
    b = ref.key_search_ref(*map(jnp.asarray, (q, qlen, keys, klens, valid)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("B,N,L", [(4, 8, 4), (64, 64, 16), (33, 16, 8)])
def test_leaf_merge_sweep(B, N, L):
    nitems = RNG.integers(0, N + 1, (B,)).astype(np.int32)
    nlog = RNG.integers(0, L + 1, (B,)).astype(np.int32)
    backptr = RNG.integers(0, N + 1, (B, L)).astype(np.int32)
    hints = np.stack([RNG.integers(0, j + 1, (B,)) for j in range(L)],
                     axis=1).astype(np.int32)
    pa, va = ops.leaf_merge(nitems, nlog, backptr, hints, node_cap=N,
                            log_cap=L, backend="interpret", block_b=16)
    pb, vb = ref.leaf_merge_ref(
        *map(jnp.asarray, (nitems, nlog, backptr, hints)),
        node_cap=N, log_cap=L)
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    pa, pb = np.asarray(pa), np.asarray(pb)
    for b in range(B):
        nv = int(nitems[b] + nlog[b])
        np.testing.assert_array_equal(pa[b, :nv], pb[b, :nv])


@pytest.mark.parametrize("B,H,KVH,D,P,PPS,dtype", [
    (2, 4, 2, 16, 8, 3, np.float32),
    (4, 8, 8, 32, 16, 2, np.float32),
    (2, 8, 2, 16, 8, 4, np.float32),
])
def test_paged_attention_sweep(B, H, KVH, D, P, PPS, dtype):
    NP = 16
    q = RNG.normal(size=(B, H, D)).astype(dtype)
    kp = RNG.normal(size=(NP, P, KVH, D)).astype(dtype)
    vp = RNG.normal(size=(NP, P, KVH, D)).astype(dtype)
    bt = RNG.integers(0, NP, (B, PPS)).astype(np.int32)
    sl = RNG.integers(1, P * PPS + 1, (B,)).astype(np.int32)
    # at least one visible position (start < seq_len); an empty window is
    # unreachable from the engine (a decoded token is always visible)
    start = np.minimum(RNG.integers(0, 2, (B,)), sl - 1).astype(np.int32)
    a = ops.paged_attention(q, kp, vp, bt, sl, start, backend="interpret",
                            softcap=30.0)
    b = ref.paged_attention_ref(*map(jnp.asarray, (q, kp, vp, bt, sl,
                                                   start)), softcap=30.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5,
                               atol=3e-5)


def test_paged_attention_bf16():
    B, H, KVH, D, P, PPS, NP = 2, 4, 2, 16, 8, 2, 8
    q = RNG.normal(size=(B, H, D)).astype(jnp.bfloat16)
    kp = RNG.normal(size=(NP, P, KVH, D)).astype(jnp.bfloat16)
    vp = RNG.normal(size=(NP, P, KVH, D)).astype(jnp.bfloat16)
    bt = RNG.integers(0, NP, (B, PPS)).astype(np.int32)
    sl = np.full((B,), P * PPS, np.int32)
    a = ops.paged_attention(q, kp, vp, bt, sl, backend="interpret")
    b = ref.paged_attention_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(sl))
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=3e-2,
                               atol=3e-2)


def test_kernels_match_store_search():
    """The KSU kernel agrees with the live store's segment search."""
    from repro.core import (HoneycombConfig, HoneycombStore,
                            snapshot_fields)
    from repro.core.keys import int_key, pack_keys
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)
    store = HoneycombStore(cfg, heap_capacity=64)
    for i in range(16):
        store.put(int_key(i * 2), b"v")
    # decode per-field views out of the packed node image (core/schema.py)
    snap = snapshot_fields(store.export_snapshot(), cfg)
    phys = int(snap.pagetable[int(snap.root_lid)])
    B = 8
    queries = [int_key(2 * i + 1) for i in range(B)]   # between keys
    lanes, lens = pack_keys(queries, cfg.key_words)
    keys = np.broadcast_to(np.asarray(snap.skeys)[phys][None],
                           (B, cfg.node_cap, cfg.key_words)).copy()
    klens = np.broadcast_to(np.asarray(snap.skeylen)[phys][None],
                            (B, cfg.node_cap)).copy()
    valid = (np.arange(cfg.node_cap)[None]
             < int(snap.nitems[phys])).astype(np.int32)
    valid = np.broadcast_to(valid, (B, cfg.node_cap)).copy()
    idx = ops.key_search(lanes, lens, keys, klens, valid,
                         backend="interpret", block_b=8)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(B))
