"""End-to-end behaviour tests for the whole system: Honeycomb store under a
realistic mixed workload with concurrent-style readers, and the serving +
training integrations built on top of it."""
import numpy as np
import pytest

from repro.core import HoneycombConfig, HoneycombStore
from repro.core.keys import int_key


def test_mixed_workload_end_to_end():
    """YCSB-like mix driven through the full stack: host writes, batched
    accelerator reads, GC, snapshot refresh — everything stays coherent."""
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)
    store = HoneycombStore(cfg, heap_capacity=256)
    oracle: dict[bytes, bytes] = {}
    rng = np.random.default_rng(0)

    for round_ in range(6):
        # write phase (host)
        for _ in range(200):
            k = int_key(int(rng.integers(0, 300)))
            op = rng.random()
            if op < 0.6:
                v = bytes(rng.integers(65, 91, 8))
                store.put(k, v)
                oracle[k] = v
            elif op < 0.8:
                v = bytes(rng.integers(97, 123, 8))
                store.update(k, v)
                oracle[k] = v
            else:
                store.delete(k)
                oracle.pop(k, None)
        # read phase (accelerator): point + range
        keys = [int_key(int(k)) for k in rng.integers(0, 300, 64)]
        got = store.get_batch(keys)
        assert got == [oracle.get(k) for k in keys]
        ranges = []
        for _ in range(16):
            a = int(rng.integers(0, 290))
            ranges.append((int_key(a), int_key(a + 9)))
        for (lo, hi), items in zip(ranges, store.scan_batch(ranges)):
            assert items == store.tree.scan(lo, hi)
        # GC between rounds (epochs closed)
        store.tree.epochs.cpu_begin(0)
        store.collect_garbage()

    store.tree.check_invariants()
    s = store.stats
    assert s.merges > 0 and s.splits > 0 and s.fast_path > 0


def test_snapshot_isolation_under_churn():
    """Readers pinned to old snapshots keep linearizable results while the
    host churns — the paper's core guarantee, system level."""
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)
    store = HoneycombStore(cfg, heap_capacity=256)
    for i in range(150):
        store.put(int_key(i), b"gen0-%d" % i)
    snap = store.export_snapshot()
    frozen = store.scan_batch([(int_key(0), int_key(149))])[0]

    import jax.numpy as jnp
    from repro.core.keys import pack_keys
    from repro.core.read_path import batched_scan
    for gen in range(1, 4):
        for i in range(150):
            store.update(int_key(i), b"gen%d-%d" % (gen, i))
    lo, ln = pack_keys([int_key(0)], cfg.key_words)
    hi, hn = pack_keys([int_key(149)], cfg.key_words)
    res = batched_scan(snap, jnp.asarray(lo), jnp.asarray(ln),
                       jnp.asarray(hi), jnp.asarray(hn), cfg)
    assert int(res.count[0]) >= 1
    first_val = np.asarray(res.vals)[0, 0].astype(">u4").tobytes()[:6]
    assert first_val == b"gen0-0"
    # live store sees the latest generation
    assert store.get_batch([int_key(0)])[0] == b"gen3-0"
    assert frozen[0][1] == b"gen0-0"


def test_honeycomb_vs_cpu_baseline_agree():
    """The accelerated store and the software baseline are observationally
    equivalent (same results; different cost profiles)."""
    from repro.baselines.cpu_store import CpuOrderedStore
    hc = HoneycombStore(HoneycombConfig(node_cap=16, log_cap=4,
                                        n_shortcuts=4))
    cp = CpuOrderedStore(node_cap=16)
    rng = np.random.default_rng(1)
    for _ in range(800):
        k = int_key(int(rng.integers(0, 200)))
        if rng.random() < 0.7:
            v = bytes(rng.integers(65, 91, 8))
            hc.put(k, v)
            cp.put(k, v)
        else:
            hc.delete(k)
            cp.delete(k)
    keys = [int_key(i) for i in range(200)]
    assert hc.get_batch(keys) == cp.get_batch(keys)
    ranges = [(int_key(a), int_key(a + 5)) for a in range(0, 190, 17)]
    assert hc.scan_batch(ranges) == cp.scan_batch(ranges)


def test_variable_length_keys_end_to_end():
    """The paper's headline feature: variable-size keys and values, inline,
    with lexicographic order — through writes, merges, splits and the
    batched device read path."""
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                          key_words=6, val_words=3)
    store = HoneycombStore(cfg, heap_capacity=256)
    rng = np.random.default_rng(4)
    oracle = {}
    keys = []
    for _ in range(400):
        klen = int(rng.integers(1, cfg.max_key_bytes + 1))
        k = rng.integers(97, 123, klen, dtype=np.uint8).tobytes()
        vlen = int(rng.integers(0, 40))        # some overflow the inline cap
        v = rng.integers(65, 91, vlen, dtype=np.uint8).tobytes()
        keys.append(k)
        if rng.random() < 0.85:
            store.put(k, v)
            oracle[k] = v
        else:
            store.delete(k)
            oracle.pop(k, None)
    store.tree.check_invariants()
    # device GETs (mix of present/absent/prefix-sibling keys)
    probes = keys[:64] + [k[:-1] for k in keys[:16] if len(k) > 1] \
        + [(k + b"z")[: cfg.max_key_bytes] for k in keys[:16]]
    got = store.get_batch(probes)
    assert got == [oracle.get(k) for k in probes]
    # device SCANs honor byte-lexicographic order incl. prefix relations
    ks = sorted(oracle)
    if len(ks) > 8:
        ranges = [(ks[1], ks[6]), (ks[0][:1], ks[-1])]
        for (lo, hi), items in zip(ranges, store.scan_batch(ranges)):
            assert items == store.tree.scan(lo, hi)
            assert [k for k, _ in items] == sorted(k for k, _ in items)
