"""Packed node-image layout (core/schema.py): golden word offsets pinning
the image format, pack/unpack/device-view roundtrips, the schema-derived
field lists (no re-enumeration anywhere), the one-image-DMA-per-dirty-node
accounting invariant, randomized packed==legacy op-for-op equivalence
(results AND sync byte counts) across shards x replicas x pipeline modes,
and image-scatter / in-image key-search kernel parity."""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (FIELD_NAMES, NARROWED_FIELDS, NODE_SCHEMA,
                        DEFAULT_CONFIG, HoneycombConfig, HoneycombStore,
                        NodeImageLayout, OutOfOrderScheduler,
                        ReplicationConfig, ShardedHoneycombStore,
                        uniform_int_boundaries)
from repro.core.keys import int_key

SMALL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)


def small(layout):
    return dataclasses.replace(SMALL, layout=layout)


# ------------------------------------------------------------ golden layout
# The packed image format is a wire contract: every field's (word_offset,
# width) inside the default config's image row, in NODE_SCHEMA order.
GOLDEN_DEFAULT_OFFSETS = {
    "ntype": (0, 1), "nitems": (1, 1), "version": (2, 1), "oldptr": (3, 1),
    "left_child": (4, 1), "lsib": (5, 1), "rsib": (6, 1),
    "skeys": (7, 512), "skeylen": (519, 64), "svals": (583, 256),
    "svallen": (839, 64), "n_shortcuts": (903, 1), "sc_keys": (904, 64),
    "sc_keylen": (968, 8), "sc_pos": (976, 8), "nlog": (984, 1),
    "log_keys": (985, 128), "log_keylen": (1113, 16), "log_vals": (1129, 64),
    "log_vallen": (1193, 16), "log_op": (1209, 16), "log_backptr": (1225, 16),
    "log_hint": (1241, 16), "log_vdelta": (1257, 16),
}


def test_golden_offsets_pinned():
    """The default-config image layout is pinned word for word — 1273 words
    (5092 B, the reproduction's analogue of the paper's 8 KB node)."""
    layout = NodeImageLayout.for_config(DEFAULT_CONFIG)
    assert layout.offsets() == GOLDEN_DEFAULT_OFFSETS
    assert layout.image_words == 1273
    assert layout.node_image_bytes == 5092
    # fields tile the row exactly: in schema order, no padding
    assert list(layout.offsets()) == list(FIELD_NAMES)
    off = 0
    for name in FIELD_NAMES:
        o, w = layout.offsets()[name]
        assert o == off, name
        off += w
    assert off == layout.image_words
    # the test geometry used across the suite
    assert NodeImageLayout.for_config(SMALL).image_words == 345


def test_field_lists_derive_from_schema():
    """Heap allocation, snapshot publishing and the device-narrowing table
    all share the ONE schema — no hand-kept field list survives."""
    from repro.core.heap import NodeHeap
    from repro.core.read_path import NODE_FIELDS
    from repro.core.shard import _I32_FIELDS
    assert NodeHeap.ARRAY_FIELDS == FIELD_NAMES
    assert NODE_FIELDS == FIELD_NAMES
    assert _I32_FIELDS is NARROWED_FIELDS
    assert NARROWED_FIELDS == {"version", "log_op", "log_hint", "log_vdelta"}
    assert all(f.device in ("uint32", "int32") for f in NODE_SCHEMA)


def test_pack_unpack_view_roundtrip():
    """pack() -> unpack() is the identity (in device dtypes) on a live
    heap, and the device view() decodes every field identically —
    including NULL = -1 surviving the u32 transit of signed fields."""
    st = HoneycombStore(small("packed"), heap_capacity=256)
    rng = np.random.default_rng(3)
    for i in range(120):
        st.put(int_key(i), bytes(rng.integers(65, 91, 8)))
    for i in range(0, 120, 3):
        st.delete(int_key(i))
    h = st.tree.heap
    layout = NodeImageLayout.for_config(st.cfg)
    img = layout.pack(h)
    fields = layout.unpack(img)
    dimg = jnp.asarray(img)
    for spec in NODE_SCHEMA:
        want = getattr(h, spec.name).astype(spec.device)
        assert np.array_equal(fields[spec.name], want), spec.name
        assert np.array_equal(np.asarray(layout.view(dimg, spec.name)),
                              want), spec.name
    assert (h.rsib == -1).any()                  # NULLs actually exercised
    assert np.array_equal(fields["rsib"] == -1, h.rsib == -1)
    # row subsets pack the same bytes as the full image
    rows = np.array([0, 5, 9], np.int32)
    assert np.array_equal(layout.pack(h, rows), img[rows])


# --------------------------------------------------- the DMA-count invariant
def test_delta_sync_is_one_image_dma_per_dirty_node():
    """THE acceptance invariant: on the packed layout a delta sync issues
    exactly ONE contiguous image-row DMA per dirty node (a full publish is
    one whole-image DMA), metered end to end by SyncStats."""
    st = HoneycombStore(small("packed"), heap_capacity=256)
    layout = NodeImageLayout.for_config(st.cfg)
    for i in range(100):
        st.put(int_key(i), b"v")
    st.export_snapshot()                          # first publish: full
    assert st.sync_stats.full_syncs == 1
    assert st.sync_stats.image_dma_count == 1     # ONE whole-image DMA
    assert st.sync_stats.image_bytes == \
        st.tree.heap.capacity * layout.node_image_bytes
    for rnd in range(3):
        d0, b0 = st.sync_stats.image_dma_count, st.sync_stats.image_bytes
        for i in range(rnd * 7, rnd * 7 + 5):
            st.update(int_key(i), b"u%d" % rnd)
        st.export_snapshot()
        dmas = st.sync_stats.image_dma_count - d0
        dirty = (st.sync_stats.image_bytes - b0) // layout.node_image_bytes
        assert st.sync_stats.delta_syncs == rnd + 1
        assert dmas == dirty > 0, (dmas, dirty)   # one DMA per dirty node
    # legacy on the same traffic: one DMA per FIELD per node
    lg = HoneycombStore(small("legacy"), heap_capacity=256)
    for i in range(100):
        lg.put(int_key(i), b"v")
    lg.export_snapshot()
    assert lg.sync_stats.image_dma_count == len(FIELD_NAMES)
    assert lg.sync_stats.image_bytes == st.sync_stats.image_bytes - \
        (st.sync_stats.image_dma_count - 1) * layout.node_image_bytes


# ------------------------------------------------- packed == legacy, op for op
@pytest.mark.parametrize("shards", [1, 3])
@pytest.mark.parametrize("replicas", [1, 2])
@pytest.mark.parametrize("pipeline", ["serial", "pipelined"])
def test_packed_equals_legacy_randomized(shards, replicas, pipeline):
    """Randomized mixed workloads: the packed layout returns the same
    responses AND the same sync accounting as the legacy per-field layout
    across shards x replicas x pipeline modes.  Every SyncStats counter
    matches except image_dma_count — the counter the refactor collapses
    (one per dirty node instead of one per field per node)."""
    bnd = uniform_int_boundaries(200, shards) if shards > 1 else None
    repl = ReplicationConfig(replicas=replicas,
                             policy="round_robin" if replicas > 1
                             else "primary_only")
    stores, scheds = [], []
    for layout in ("packed", "legacy"):
        s = ShardedHoneycombStore(small(layout), heap_capacity=256,
                                  shards=shards, boundaries=bnd,
                                  replication=repl)
        stores.append(s)
        scheds.append(OutOfOrderScheduler(batch_size=8, routing=s.routing(),
                                          pipeline=pipeline))
    pk, lg = stores
    rng = np.random.default_rng(42)
    from test_pipeline_engine import submit_random_mixed
    for round_ in range(3):
        submit_random_mixed(scheds, rng, 60)
        out_p = scheds[0].run(pk)
        out_l = scheds[1].run(lg)
        assert out_p == out_l, round_
        sp = dataclasses.asdict(pk.sync_stats)
        sl = dataclasses.asdict(lg.sync_stats)
        # the DMA count is the one deliberate difference
        assert sp.pop("image_dma_count") < sl.pop("image_dma_count")
        assert sp == sl, round_
        assert pk.replication_bytes == lg.replication_bytes, round_
    assert pk.sync_stats.delta_syncs > 0          # delta path exercised
    assert pk.sync_stats.image_bytes == lg.sync_stats.image_bytes > 0
    if replicas > 1:
        assert pk.replication_bytes > 0


def test_direct_store_packed_equals_legacy():
    """No scheduler in the way: direct put/get/scan/delete + export on both
    layouts, same results, same bytes_synced."""
    pk = HoneycombStore(small("packed"), heap_capacity=256)
    lg = HoneycombStore(small("legacy"), heap_capacity=256)
    oracle = {}
    rng = np.random.default_rng(11)
    for round_ in range(4):
        for _ in range(50):
            k = int_key(int(rng.integers(0, 150)))
            r = rng.random()
            if r < 0.6:
                v = bytes(rng.integers(65, 91, 8))
                pk.put(k, v), lg.put(k, v)
                oracle[k] = v
            else:
                pk.delete(k), lg.delete(k)
                oracle.pop(k, None)
        keys = [int_key(i) for i in range(0, 150, 7)]
        assert pk.get_batch(keys) == lg.get_batch(keys) \
            == [oracle.get(k) for k in keys]
        ranges = [(int_key(a), int_key(a + 9)) for a in range(0, 140, 23)]
        assert pk.scan_batch(ranges) == lg.scan_batch(ranges)
        pk.export_snapshot()
        lg.export_snapshot()
        assert pk.sync_stats.bytes_synced == lg.sync_stats.bytes_synced


# ------------------------------------------------------------ kernel parity
def test_image_scatter_kernel_matches_ref():
    """snapshot_image_scatter interpret-mode == jnp oracle, duplicate
    (bucket-padded) rows included."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    image = jnp.asarray(rng.integers(0, 2**32, (64, 345), np.int64)
                        .astype(np.uint32))
    rows = jnp.asarray(np.array([2, 31, 63, 63], np.int32))  # padded repeat
    upd = rng.integers(0, 2**32, (3, 345), np.int64).astype(np.uint32)
    upd = jnp.asarray(np.concatenate([upd, upd[-1:]]))
    want = ops.snapshot_image_scatter(image, rows, upd, backend="ref")
    got = ops.snapshot_image_scatter(image, rows, upd, backend="interpret")
    assert bool(jnp.array_equal(want, got))


def test_key_search_image_kernel_matches_ref():
    """In-image floor search (candidate block sliced from packed image rows
    at static layout offsets) interpret-mode == jnp oracle."""
    from repro.core.keys import pack_keys
    from repro.kernels import ops
    cfg = SMALL
    layout = NodeImageLayout.for_config(cfg)
    rng = np.random.default_rng(7)
    B, kw = 8, cfg.key_words
    img = rng.integers(0, 2**32, (B, layout.image_words), np.int64) \
        .astype(np.uint32)
    sk, _ = layout.offsets()["skeys"]
    kl, _ = layout.offsets()["skeylen"]
    ct, _ = layout.offsets()["nitems"]
    # plant sorted candidate keys + sane lengths/counts in each image row
    for b in range(B):
        keys = sorted(rng.integers(65, 91, 6, dtype=np.uint8).tobytes()
                      for _ in range(cfg.node_cap))
        lanes, lens = pack_keys(keys, kw)
        img[b, sk:sk + cfg.node_cap * kw] = lanes.reshape(-1)
        img[b, kl:kl + cfg.node_cap] = lens.astype(np.uint32)
        img[b, ct] = rng.integers(1, cfg.node_cap + 1)
    q, qlen = pack_keys([rng.integers(65, 91, 6, dtype=np.uint8).tobytes()
                         for _ in range(B)], kw)
    kw_args = dict(keys_off=sk, lens_off=kl, count_off=ct,
                   n_keys=cfg.node_cap, key_words=kw)
    want = ops.key_search_image(jnp.asarray(q), jnp.asarray(qlen),
                                jnp.asarray(img), backend="ref", **kw_args)
    got = ops.key_search_image(jnp.asarray(q), jnp.asarray(qlen),
                               jnp.asarray(img), backend="interpret",
                               **kw_args)
    assert bool(jnp.array_equal(want, got))
    assert int(jnp.max(want)) >= 0               # some floors actually found
