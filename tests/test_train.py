"""Training substrate: optimizer math, loop + checkpoint/restart,
straggler detection, data determinism, gradient compression."""
import dataclasses
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataPipeline, FileSource, SyntheticSource
from repro.distributed.compression import (GradCompressor, int8_dequantize,
                                           int8_quantize, topk_sparsify)
from repro.train import optimizer as opt
from repro.train.checkpoint import CheckpointManager
from repro.train.train_loop import LoopConfig, build_smoke_loop


def tiny_cfg():
    return dataclasses.replace(get_smoke_config("qwen2p5_3b"),
                               n_layers=2, d_model=64, d_ff=128, vocab=128)


# ----------------------------------------------------------------- optimizer
def test_adamw_first_step_matches_reference():
    cfg = opt.AdamWConfig(lr=1e-2, warmup_steps=1, weight_decay=0.0,
                          clip_norm=1e9)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    state = opt.init(params)
    new, state, gnorm = opt.update(cfg, grads, state, params)
    # bias-corrected Adam with eps: step ~= lr * sign-ish update
    m = 0.1 * np.array([0.1, -0.2, 0.3])
    v = 0.05 * np.array([0.1, -0.2, 0.3]) ** 2
    mh, vh = m / 0.1, v / 0.05
    want = np.array([1.0, -2.0, 3.0]) - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new["w"]), want, rtol=1e-5)
    assert float(gnorm) == pytest.approx(np.sqrt(0.14), rel=1e-5)


def test_grad_clipping():
    cfg = opt.AdamWConfig(clip_norm=0.1)
    params = {"w": jnp.ones(4)}
    grads = {"w": jnp.full(4, 100.0)}
    state = opt.init(params)
    _, state2, gnorm = opt.update(cfg, grads, state, params)
    assert float(gnorm) > 100
    assert float(jnp.abs(state2.mu["w"]).max()) < 1.0   # clipped before mu


# ------------------------------------------------------------------ training
def test_loss_decreases_and_checkpoints(tmp_path):
    loop = build_smoke_loop(tiny_cfg(), batch=8, seq=32,
                            ckpt_dir=str(tmp_path),
                            loop_cfg=LoopConfig(total_steps=60,
                                                ckpt_every=30, log_every=10))
    summary = loop.run()
    losses = [m["loss"] for m in loop.metrics_log]
    assert losses[-1] < losses[0] - 0.3, losses
    assert loop.ckpt.all_steps() == [30, 60]
    loop.pipeline.close()


def test_restart_resumes_deterministically(tmp_path):
    lc = LoopConfig(total_steps=20, ckpt_every=10, log_every=5)
    a = build_smoke_loop(tiny_cfg(), batch=8, seq=32,
                         ckpt_dir=str(tmp_path / "a"), loop_cfg=lc)
    a.run()
    final_a = jax.tree.leaves(a.params)[0]
    a.pipeline.close()

    # crash after step 10, restart from checkpoint, rerun to 20
    b = build_smoke_loop(tiny_cfg(), batch=8, seq=32,
                         ckpt_dir=str(tmp_path / "b"), loop_cfg=lc)
    b.run(steps=10)
    b.pipeline.close()
    c = build_smoke_loop(tiny_cfg(), batch=8, seq=32,
                         ckpt_dir=str(tmp_path / "b"), loop_cfg=lc)
    assert c.restore_latest()
    assert c.step == 10
    c.run(steps=10)
    final_c = jax.tree.leaves(c.params)[0]
    np.testing.assert_allclose(np.asarray(final_a, np.float32),
                               np.asarray(final_c, np.float32), atol=1e-5)
    c.pipeline.close()


def test_checkpoint_catalog_floor_lookup(tmp_path):
    ck = CheckpointManager(tmp_path, keep=10)
    for s in (10, 20, 40):
        ck.save(s, {"x": jnp.ones(3) * s})
    assert ck.latest_step() == 40
    assert ck.latest_step(at_or_before=35) == 20
    assert ck.latest_step(at_or_before=10) == 10
    (tree, manifest) = ck.restore(20, {"x": jnp.zeros(3)})
    assert float(tree["x"][0]) == 20.0


def test_checkpoint_retention(tmp_path):
    ck = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.zeros(2)})
    assert ck.all_steps() == [3, 4]


def test_straggler_detection(tmp_path):
    import time
    loop = build_smoke_loop(tiny_cfg(), batch=8, seq=32,
                            ckpt_dir=str(tmp_path),
                            loop_cfg=LoopConfig(total_steps=10,
                                                ckpt_every=100,
                                                log_every=100,
                                                straggler_factor=5.0))
    orig = loop.step_fn
    calls = {"n": 0}

    def slow_step(p, o, b):
        calls["n"] += 1
        if calls["n"] == 8:
            time.sleep(1.0)       # injected straggler
        return orig(p, o, b)

    loop.step_fn = slow_step
    loop.run()
    assert len(loop.straggler_events) >= 1
    assert loop.straggler_events[0][0] == 8
    loop.pipeline.close()


# ----------------------------------------------------------------- pipeline
def test_data_determinism_and_sharding():
    src = SyntheticSource(vocab=100, seed=1)
    a = src.batch(5, 0, 4, 8, 16)
    b = src.batch(5, 0, 4, 8, 16)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch(5, 1, 4, 8, 16)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_file_source_roundtrip(tmp_path):
    toks = np.arange(10000, dtype=np.int32)
    path = tmp_path / "toks.bin"
    toks.tofile(path)
    src = FileSource(str(path))
    b = src.batch(0, 0, 2, 2, 8)
    np.testing.assert_array_equal(b["tokens"][0], np.arange(8))
    np.testing.assert_array_equal(b["labels"][0], np.arange(1, 9))


def test_pipeline_prefetch_and_resume():
    pipe = DataPipeline(SyntheticSource(50, seed=2), global_batch=4,
                        seq_len=8, start_step=7)
    b1 = next(pipe)
    state = pipe.state()
    pipe.close()
    pipe2 = DataPipeline(SyntheticSource(50, seed=2), global_batch=4,
                         seq_len=8, start_step=7)
    b2 = next(pipe2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    pipe2.close()


# -------------------------------------------------------------- compression
def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, s = int8_quantize(x)
    err = np.abs(np.asarray(int8_dequantize(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0])
    y, mask = topk_sparsify(x, 0.5)
    np.testing.assert_array_equal(np.asarray(y), [0.0, -5.0, 0.0, 3.0])


def test_error_feedback_is_unbiased_over_time():
    """Sum of compressed grads + final residual == sum of true grads."""
    comp = GradCompressor("int8")
    params = {"w": jnp.zeros(64)}
    state = comp.init(params)
    rng = np.random.default_rng(1)
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for _ in range(20):
        g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
        out, state = comp(g, state)
        total_true += np.asarray(g["w"])
        total_sent += np.asarray(out["w"])
    resid = np.asarray(state.residual["w"])
    np.testing.assert_allclose(total_sent + resid, total_true, atol=1e-3)
