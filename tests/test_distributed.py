"""Distribution: sharding rules, HLO collective parsing, and an 8-device
subprocess check (sharded step + elastic checkpoint reshard).

Device-count-dependent tests run in a subprocess so the main pytest
process keeps its single CPU device (the dry-run owns the 512-device
configuration; see launch/dryrun.py).
"""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.configs import get_config
from repro.distributed.sharding import ShardingPolicy, make_rules
from repro.launch import hlo_analysis as hla
from repro.models.config import shape_by_name


class _FakeMesh:
    def __init__(self, shape, names):
        import numpy as np
        self.axis_names = names
        self.devices = np.zeros(shape)


def test_rules_divisibility():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    cfg = get_config("qwen2.5-3b")
    r = make_rules(cfg, mesh, shape_by_name("train_4k"))
    assert r["heads"] == "model"        # 16*128 divisible
    assert r["vocab"] == "model"        # 151936 divisible
    assert r["embed"] == "data"
    assert r["kv_heads"] is None        # kv=2 not divisible by 16
    assert r["head_dim"] == "model"     # 128 divisible

    cfg2 = get_config("mamba2-1.3b")
    r2 = make_rules(cfg2, mesh, shape_by_name("train_4k"))
    assert r2["heads_act"] == "model"   # 64 ssm heads divisible
    assert r2["mlp"] == "model"         # d_inner divisible


def test_rules_multipod_batch():
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model"))
    cfg = get_config("qwen2.5-3b")
    r = make_rules(cfg, mesh, shape_by_name("train_4k"))
    assert r["batch"] == ("pod", "data")
    r_long = make_rules(cfg, mesh, shape_by_name("long_500k"))
    assert r_long["batch"] is None      # B=1 cannot shard


def test_collective_parser():
    hlo = textwrap.dedent("""\
        %all-reduce.1 = f32[256,4096]{1,0} all-reduce(%x), channel_id=1
        %ag = bf16[64,128]{1,0} all-gather(%y), dimensions={0}
        %rs.3 = (f32[16]{0}, f32[16]{0}) reduce-scatter(%a, %b), dims={0}
        %cp = f32[8,8]{1,0} collective-permute(%z), source_target_pairs={}
        %done = f32[8,8]{1,0} all-gather-done(%cp)
        %other = f32[2,2]{1,0} add(%p, %q)
    """)
    out = hla.collective_bytes(hlo)
    assert out["counts"]["all-reduce"] == 1
    assert out["counts"]["all-gather"] == 1       # -done skipped
    assert out["counts"]["reduce-scatter"] == 1
    assert out["counts"]["collective-permute"] == 1
    assert out["bytes"]["all-reduce"] == 256 * 4096 * 4
    assert out["bytes"]["all-gather"] == 64 * 128 * 2
    assert out["bytes"]["reduce-scatter"] == 2 * 16 * 4
    assert out["total_bytes"] > 0


def test_roofline_terms():
    cost = {"flops": 197e12, "bytes accessed": 819e9}
    coll = {"total_bytes": 50e9}
    rl = hla.roofline(cost, coll, model_flops_per_device=98.5e12)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(1.0)
    assert rl.collective_s == pytest.approx(1.0)
    assert rl.useful_ratio == pytest.approx(0.5)


SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_step
from repro.models.config import ShapeConfig
from repro.models import schema as sc, transformer as tf
from repro.distributed.sharding import make_rules
from repro.train import optimizer as opt
from repro.train.checkpoint import CheckpointManager

cfg = dataclasses.replace(get_smoke_config("qwen2p5_3b"),
                          n_layers=2, d_model=64, d_ff=128, vocab=256)
shape = ShapeConfig("t", "train", seq_len=32, global_batch=8, page_size=16)

# --- sharded train step on a (2,4) mesh --------------------------------
mesh = make_mesh((2, 4), ("data", "model"))
built = build_step(cfg, shape, mesh, grad_accum=2)
with mesh:
    params = sc.init(tf.schema(cfg), jax.random.key(0))
    opt_state = opt.init(params)
    batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
             "labels": jnp.zeros((8, 32), jnp.int32)}
    params = jax.device_put(params, built.in_shardings[0])
    opt_state = jax.device_put(opt_state, built.in_shardings[1])
    batch = jax.device_put(batch, built.in_shardings[2])
    step = jax.jit(built.fn, in_shardings=built.in_shardings,
                   out_shardings=built.out_shardings,
                   donate_argnums=built.donate_argnums)
    params, opt_state, metrics = step(params, opt_state, batch)
    loss1 = float(metrics["loss"])
    assert np.isfinite(loss1)

    # --- elastic checkpoint: save on (2,4), restore on (4,2) ----------
    ck = CheckpointManager("/tmp/repro_elastic_ck", keep=1)
    ck.save(1, params)

mesh2 = make_mesh((4, 2), ("data", "model"))
rules2 = make_rules(cfg, mesh2, shape)
sh2 = sc.shardings(tf.schema(cfg), rules2, mesh2)
restored, _ = ck.restore(1, sc.abstract(tf.schema(cfg)), shardings=sh2)
with mesh2:
    a = jax.tree.leaves(params)[0]
    b = jax.tree.leaves(restored)[0]
    assert np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
print(json.dumps({"ok": True, "loss": loss1,
                  "devices": len(jax.devices())}))
"""


@pytest.mark.slow
def test_multidevice_step_and_elastic_restore():
    r = subprocess.run([sys.executable, "-c", SUBPROC], capture_output=True,
                       text=True, timeout=600,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["devices"] == 8
