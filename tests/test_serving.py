"""Serving: paged generation correctness, Honeycomb page tables, prefix
cache, continuous batching."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import schema as sc
from repro.models import transformer as tf
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedKVCache, page_key, rolling_hashes


def naive_generate(params, cfg, prompt, n_new):
    toks = list(map(int, prompt))
    for _ in range(n_new):
        logits = tf.forward(params, cfg, tokens=jnp.asarray([toks]),
                            remat=False)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


@pytest.mark.parametrize("arch,plen", [("qwen2p5_3b", 13),
                                       ("jamba_v0p1_52b", 16)])
def test_engine_matches_naive_generation(arch, plen):
    cfg = get_smoke_config(arch)
    params = sc.init(tf.schema(cfg), jax.random.key(0))
    eng = ServingEngine(cfg, params, batch_size=2, max_seq=128,
                        page_size=16)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, (plen,)) for _ in range(2)]
    rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    outs = eng.run_until_done()
    for rid, p in zip(rids, prompts):
        assert outs[rid] == naive_generate(params, cfg, p, 5), arch


def test_continuous_batching_oversubscribed():
    """More requests than slots: admission waits for free slots and every
    request still finishes with the right length."""
    cfg = get_smoke_config("qwen2p5_3b")
    eng = ServingEngine(cfg, batch_size=2, max_seq=64, page_size=16)
    rng = np.random.default_rng(1)
    rids = [eng.submit(rng.integers(1, cfg.vocab, (8,)), max_new_tokens=4)
            for _ in range(5)]
    outs = eng.run_until_done()
    assert all(len(outs[r]) == 4 for r in rids)
    # page 0 is the engine's reserved scratch page; everything else freed
    assert eng.kv.pages_in_use == 1


def test_page_table_alloc_free_cycle():
    kv = PagedKVCache(n_pages=16, page_size=8)
    p1 = kv.allocate(7, 0)
    p2 = kv.allocate(7, 1)
    assert p1 != p2
    bt = kv.lookup_block_tables([7], 2)
    assert list(bt[0]) == [p1, p2]
    kv.free_seq(7, 2)
    assert kv.pages_in_use == 0
    assert kv.table.get(page_key(7, 0)) is None


def test_page_table_is_ordered_store():
    """Pages of one sequence are contiguous in key space: a range SCAN
    retrieves a sequence's whole block table — the ordered-store property
    the paper's SCAN exists for."""
    kv = PagedKVCache(n_pages=64, page_size=8)
    for s in (3, 5):
        for b in range(4):
            kv.allocate(s, b)
    items = kv.table.scan(page_key(5, 0), page_key(5, 3))
    assert len(items) == 4
    assert [k[:8] for k, _ in items] == [int(5).to_bytes(8, "big")] * 4


def test_prefix_cache_floor_match():
    kv = PagedKVCache(n_pages=16, page_size=4)
    rng = np.random.default_rng(2)
    toks = rng.integers(1, 100, (16,))
    kv.register_prefix(toks, seq_id=9)
    sid, ln = kv.longest_cached_prefix(np.concatenate([toks[:8], [1, 2, 3, 4]]))
    assert (sid, ln) == (9, 8)
    sid, ln = kv.longest_cached_prefix(toks)
    assert (sid, ln) == (9, 16)
    sid, ln = kv.longest_cached_prefix(rng.integers(100, 200, (8,)))
    assert (sid, ln) == (-1, 0)


def test_rolling_hash_prefix_property():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 50, (12,))
    b = np.concatenate([a[:8], rng.integers(50, 99, (4,))])
    ha = dict((ln, h) for h, ln in rolling_hashes(a, 4))
    hb = dict((ln, h) for h, ln in rolling_hashes(b, 4))
    assert ha[4] == hb[4] and ha[8] == hb[8]
    assert ha[12] != hb[12]
