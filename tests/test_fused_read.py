"""Fused device-resident read path (kernels/fused_read.py + the VMEM
cache tier): interpret-mode kernel parity, fused ≡ reference equivalence
through the whole service stack (results AND serving-version stamps),
cache-frontier edge cases, the stale-cache-after-remap regression, and
the dispatched-launch meter pins."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Get, HoneycombConfig, HoneycombService,
                        HoneycombStore, ReplicationConfig, Scan,
                        ShardedHoneycombStore, Update,
                        uniform_int_boundaries)
from repro.core.keys import int_key, pack_keys
from repro.core.shard import StoreShard
from repro.kernels import ops

SMALL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                        cache_slots=32, max_scan_leaves=2,
                        max_scan_items=16, max_height=6)


def _loaded_shard(cfg, n=120, heap_capacity=256):
    s = StoreShard(cfg, heap_capacity=heap_capacity)
    for i in range(n):
        s.put(int_key(i), b"v%06d" % i)
    for i in range(0, n, 7):
        s.update(int_key(i), b"u%06d" % i)
    for i in range(0, n, 13):
        s.delete(int_key(i))
    return s


def _packed(keys, cfg):
    lanes, lens = pack_keys(keys, cfg.key_words)
    return jnp.asarray(lanes), jnp.asarray(lens)


# ------------------------------------------------- interpret ≡ ref parity
@pytest.mark.parametrize("lb_fraction", [0.0, 0.25])
def test_fused_get_interpret_matches_ref(lb_fraction):
    cfg = SMALL
    snap = _loaded_shard(cfg).export_snapshot()
    keys = [int_key(i) for i in (1, 7, 13, 55, 119, 5000)]
    lanes, lens = _packed(keys, cfg)
    want, wm = ops.batched_get_fused(snap, lanes, lens, cfg=cfg,
                                     lb_fraction=lb_fraction, backend="ref")
    got, gm = ops.batched_get_fused(snap, lanes, lens, cfg=cfg,
                                    lb_fraction=lb_fraction,
                                    backend="interpret")
    for f in want._fields:
        np.testing.assert_array_equal(np.asarray(getattr(want, f)),
                                      np.asarray(getattr(got, f)),
                                      err_msg=f)
    np.testing.assert_array_equal(np.asarray(wm), np.asarray(gm))


@pytest.mark.parametrize("lb_fraction", [0.0, 0.25])
def test_fused_scan_interpret_matches_ref(lb_fraction):
    cfg = SMALL
    snap = _loaded_shard(cfg).export_snapshot()
    los = [int_key(i) for i in (0, 5, 40, 110)]
    his = [int_key(i) for i in (4, 9, 55, 400)]
    a = _packed(los, cfg) + _packed(his, cfg)
    want, wm = ops.batched_scan_fused(snap, *a, cfg=cfg,
                                      lb_fraction=lb_fraction, backend="ref")
    got, gm = ops.batched_scan_fused(snap, *a, cfg=cfg,
                                     lb_fraction=lb_fraction,
                                     backend="interpret")
    for f in want._fields:
        np.testing.assert_array_equal(np.asarray(getattr(want, f)),
                                      np.asarray(getattr(got, f)),
                                      err_msg=f)
    np.testing.assert_array_equal(np.asarray(wm), np.asarray(gm))


# -------------------------------------- fused ≡ reference, full service
@pytest.mark.parametrize("shards", [1, 3])
@pytest.mark.parametrize("replicas", [1, 2])
@pytest.mark.parametrize("pipeline", ["serial", "pipelined"])
def test_fused_matches_reference_end_to_end(shards, replicas, pipeline):
    """Randomized op stream through the typed service on two identically
    loaded stores — fused vs reference backends must agree op-for-op on
    results AND serving-version/replica-visible stamps."""
    n_items = 200
    rng = np.random.default_rng(shards * 10 + replicas + len(pipeline))
    order = rng.permutation(n_items)

    def build(rb):
        st = ShardedHoneycombStore(
            dataclasses.replace(SMALL, read_backend=rb),
            heap_capacity=256, shards=shards,
            boundaries=uniform_int_boundaries(n_items, shards),
            replication=ReplicationConfig(replicas=replicas,
                                          policy="round_robin"))
        for i in order:
            st.put(int_key(int(i)), b"w%06d" % int(i))
        st.export_snapshot()
        return st

    opstream = []
    for k in rng.integers(0, n_items, 60):
        k = int(k)
        draw = rng.random()
        if draw < 0.15:
            opstream.append(Update(int_key(k), b"z%06d" % k))
        elif draw < 0.6:
            opstream.append(Get(int_key(k)))
        else:
            opstream.append(Scan(int_key(k),
                                 int_key(min(k + 5, n_items - 1)),
                                 expected_items=6))
    stamps = {}
    for rb in ("fused", "reference"):
        svc = HoneycombService(build(rb), batch_size=16, pipeline=pipeline)
        tickets = svc.submit_many(opstream)
        svc.drain()
        rs = [t.result() for t in tickets]
        stamps[rb] = [(r.status, r.value, r.items, r.serving_version)
                      for r in rs]
    assert stamps["fused"] == stamps["reference"]


@pytest.mark.parametrize("feed", ["log", "delta"])
def test_followers_serve_fused_from_shipped_cache(feed):
    """Followers inherit the cache tier through BOTH feeds (delta applies
    re-attach it; log replays rebuild it from the replayed image) and
    their fused reads match the cache-less reference fallback."""
    n_items = 160
    st = ShardedHoneycombStore(
        SMALL, heap_capacity=256, shards=1,
        boundaries=uniform_int_boundaries(n_items, 1),
        replication=ReplicationConfig(replicas=2, policy="round_robin",
                                      feed=feed))
    for i in range(n_items):
        st.put(int_key(i), b"v" * 12)
    st.export_snapshot()
    # flush the load epoch's leaf logs so the next epochs are replayable
    for _ in range(5):
        st.update(int_key(3), b"m" * 8)
    st.export_snapshot()
    # several small feed epochs (<= log_cap writes per leaf: the log feed
    # ships+replays them; the delta feed moves dirty image rows)
    for r in range(3):
        for i in (3, 50, 120):
            st.update(int_key(i), b"u%d" % r * 2)
        st.export_snapshot()
    grp = st.shards[0]
    if feed == "log":
        assert sum(f.sync_stats.log_replays for f in grp.followers) > 0
    for f in grp.followers:
        assert f.snapshot is not None
        assert f.snapshot.cache_lids is not None
        assert f.snapshot.cache_image is not None
        probe = [int_key(i) for i in range(0, n_items, 11)]
        v0 = grp.primary.cache.stats.vmem_hits
        got = grp.primary._device_get(f.snapshot, probe)
        assert grp.primary.cache.stats.vmem_hits > v0
        ref = grp.primary._device_get(
            f.snapshot._replace(cache_image=None), probe)
        assert got == ref


# --------------------------------------------- cache-frontier edge cases
def test_root_only_tree_serves_entirely_from_cache():
    """A tree short enough to fit whole inside the cached frontier (here:
    a single root leaf) resolves every descend level from VMEM — zero
    heap gathers."""
    s = StoreShard(SMALL, heap_capacity=64)
    for i in range(5):
        s.put(int_key(i), b"tiny")
    out = s.get_batch([int_key(i) for i in range(5)] + [int_key(99)])
    assert out == [b"tiny"] * 5 + [None]
    st = s.cache.stats
    assert st.vmem_hits > 0
    assert st.heap_gathers == 0


def test_cache_levels_beyond_tree_height():
    """cfg.cache_levels taller than the tree: the frontier walk stops at
    the leaves and fused reads still answer correctly."""
    cfg = dataclasses.replace(SMALL, cache_levels=5)
    s = _loaded_shard(cfg)
    host = {k: s.get(k) for k in (int_key(1), int_key(55), int_key(119))}
    got = s.get_batch(list(host))
    assert got == list(host.values())
    assert s.cache.stats.vmem_hits > 0


def test_partial_level_never_cached():
    """The frontier refuses a level that does not fit whole: cache
    membership stays decidable from the LID vector, and the fused path
    falls through to the heap for the uncached levels."""
    cfg = dataclasses.replace(SMALL, cache_slots=4, cache_ways=2)
    s = _loaded_shard(cfg, n=120)
    snap = s.export_snapshot()
    lids = np.asarray(snap.cache_lids)
    assert (lids != -1).sum() >= 1          # at least the root
    host = {k: s.get(k) for k in (int_key(2), int_key(77))}
    assert s.get_batch(list(host)) == list(host.values())
    assert s.cache.stats.heap_gathers > 0   # below-frontier levels


# ------------------------------------------ stale-cache-after-remap fix
def test_remap_invalidates_interior_cache():
    """Section 5 rule: a page-table command for a LID invalidates that
    LID's cache entry — a remapped LID can never serve a stale cached
    physical address from the metadata table."""
    s = StoreShard(SMALL, heap_capacity=256)
    assert s.tree.pt.on_remap is not None   # wired at construction
    for i in range(40):
        s.put(int_key(i), b"v" * 8)
    lid = s.tree.root_lid
    phys = s.tree.pt.lookup(lid)
    s.cache.lookup(lid, phys)               # warm the metadata entry
    inv0 = s.cache.stats.invalidations
    s.tree.pt.remap(lid, phys)              # the remap command itself
    assert s.cache.stats.invalidations == inv0 + 1
    row = s.cache._set_of(lid)
    assert lid not in s.cache.tag[row]      # entry dropped, not stale
    # free_lid is a page-table command too
    s.cache.lookup(lid, s.tree.pt.lookup(lid))
    inv1 = s.cache.stats.invalidations
    s.tree.pt.free_lid(lid)
    assert s.cache.stats.invalidations == inv1 + 1
    s.tree.pt.remap(lid, phys)              # restore for sanity


def test_reads_stay_correct_across_structural_churn():
    """End-to-end stale-cache regression: splits/merges remap LIDs
    between exports; fused reads after each export must match the host
    tree (the cache frontier re-attaches per staging, the metadata table
    invalidates per remap)."""
    s = StoreShard(SMALL, heap_capacity=512)
    live = {}
    rng = np.random.default_rng(3)
    for round_ in range(4):
        for i in rng.integers(0, 400, 60):
            k = int_key(int(i))
            v = b"r%d_%06d" % (round_, int(i))
            s.put(k, v)
            live[k] = v
        probe = list(live)[:: max(len(live) // 20, 1)]
        got = s.get_batch(probe)
        assert got == [live[k] for k in probe]


# ------------------------------------------------ dispatch-launch meter
def test_read_dispatch_counts():
    cfg = SMALL
    assert ops.read_dispatch_count("get", "fused", cfg) == 1
    assert ops.read_dispatch_count("scan", "fused", cfg) == 1
    ref_scan = cfg.max_height + 2 * cfg.max_scan_leaves
    assert ops.read_dispatch_count("scan", "reference", cfg) == ref_scan
    assert ops.read_dispatch_count("get", "reference", cfg) == ref_scan + 1


def test_shard_meters_fused_dispatches():
    """Acceptance: the fused path issues <= 2 device dispatches per read
    batch, measured by the launch meter at the shard dispatch site."""
    ops.reset_read_dispatches()
    s = _loaded_shard(SMALL)
    s.get_batch([int_key(1), int_key(2)])
    s.scan_batch([(int_key(1), int_key(9))])
    st = ops.read_dispatch_stats()
    assert st["get_fused"]["per_batch"] <= 2
    assert st["scan_fused"]["per_batch"] <= 2
    # the reference path pays per-stage launches
    ops.reset_read_dispatches()
    r = StoreShard(dataclasses.replace(SMALL, read_backend="reference"),
                   heap_capacity=256)
    for i in range(40):
        r.put(int_key(i), b"v" * 8)
    r.get_batch([int_key(1)])
    st = ops.read_dispatch_stats()
    assert st["get_reference"]["per_batch"] > 2
    ops.reset_read_dispatches()


def test_legacy_layout_falls_back_to_reference():
    """cfg.layout="legacy" snapshots carry no cache tier: the shard must
    dispatch reads through the reference path (and still answer right)."""
    cfg = dataclasses.replace(SMALL, layout="legacy")
    ops.reset_read_dispatches()
    s = _loaded_shard(cfg)
    out = s.get_batch([int_key(1), int_key(118)])
    assert out == [s.get(int_key(1)), s.get(int_key(118))]
    st = ops.read_dispatch_stats()
    assert "get_reference" in st and "get_fused" not in st
    ops.reset_read_dispatches()
