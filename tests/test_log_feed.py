"""Log-shipped replication feed (core/replica.py, core/shard.py,
kernels/delta_scatter.py): the primary encodes each epoch's writes ONCE
with the wire codec and ships that payload to followers, which replay it
on device with the ``log_replay_scatter`` kernel — falling back per-epoch
to the image-row delta when the tree shape changed.

Covered here: kernel interpret==ref parity on random geometry, randomized
log-fed == delta-fed follower equivalence (read results AND
serving-version stamps) over {shards 1,3} x {relay depth 0,2}, the
no-image-DMA invariant plus exact wire-byte accounting on log epochs,
every fallback trigger (log-overflow merge, overflow-length value, GC),
the relay tree's primary-egress split and lagging-relay catch-up, and the
replicas=1 zero-overhead guarantee."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FeedTopology, Get, HoneycombConfig, HoneycombService,
                        Put, ReplicationConfig, ShardedHoneycombStore,
                        Update, uniform_int_boundaries, wire_entry_nbytes)
from repro.core.keys import int_key
from repro.core.schema import NodeImageLayout
from repro.kernels import ops as kops

SMALL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)
EXPL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                       sync_policy="explicit")
KEYSPACE = 200


def replicated(cfg=EXPL, shards=1, replicas=3, feed="log", fanout=2,
               depth=0, keyspace=KEYSPACE):
    return ShardedHoneycombStore(
        cfg, heap_capacity=256, shards=shards,
        boundaries=(uniform_int_boundaries(keyspace, shards)
                    if shards > 1 else None),
        replication=ReplicationConfig(
            replicas=replicas, policy="round_robin", feed=feed,
            topology=FeedTopology(fanout=fanout, depth=depth)))


def follower_images_match_primary(st) -> bool:
    for g in st.shards:
        prim = np.asarray(g.primary._snapshot.image)
        for f in g.followers:
            if f.snapshot is None or \
                    not np.array_equal(prim, np.asarray(f.snapshot.image)):
                return False
    return True


# ------------------------------------------------------ kernel parity
@pytest.mark.parametrize("seed,n_entries", [(0, 7), (1, 16), (2, 48)])
def test_log_replay_scatter_interpret_matches_ref(seed, n_entries):
    """The Pallas kernel body (interpret mode) and the jnp oracle agree
    bit-for-bit on random images/entries, including duplicate padded
    entries and nonzero per-row slot bases (an epoch's appends continue
    wherever the previous epoch left the leaf log)."""
    cfg = HoneycombConfig(node_cap=16, log_cap=16, n_shortcuts=4)
    layout = NodeImageLayout.for_config(cfg)
    offs = layout.log_replay_offsets()
    rng = np.random.default_rng(seed)
    S = 32
    image = jnp.asarray(rng.integers(0, 2 ** 32, (S, layout.image_words),
                                     dtype=np.uint32))
    pool = rng.choice(S, 8, replace=False)
    rows = rng.choice(pool, n_entries).astype(np.int32)
    base = {int(r): int(rng.integers(0, 3)) for r in pool}
    count = dict.fromkeys(base, 0)
    slots = np.empty(n_entries, np.int32)
    for i, r in enumerate(rows.tolist()):
        slots[i] = base[r] + count[r]
        count[r] += 1
    assert max(base[r] + count[r] for r in count) <= cfg.log_cap
    entries = rng.integers(0, 2 ** 32, (n_entries, layout.log_entry_words),
                           dtype=np.uint32)
    # pad with duplicates of the last record — the store's pow2 bucketing
    rows_p = np.concatenate([rows, np.repeat(rows[-1:], 3)])
    slots_p = np.concatenate([slots, np.repeat(slots[-1:], 3)])
    ent_p = np.concatenate([entries, np.repeat(entries[-1:], 3, axis=0)])
    args = (image, jnp.asarray(rows_p), jnp.asarray(slots_p),
            jnp.asarray(ent_p))
    ref = kops.log_replay_scatter(*args, offs=offs, backend="ref")
    itp = kops.log_replay_scatter(*args, offs=offs, backend="interpret")
    assert np.array_equal(np.asarray(ref), np.asarray(itp))
    # every touched row's nlog is its highest slot + 1
    nlog = np.asarray(ref)[:, offs.nlog]
    for r in pool:
        if count[int(r)]:
            assert nlog[int(r)] == base[int(r)] + count[int(r)]


# ------------------------------------------- log-fed == delta-fed grid
@pytest.mark.parametrize("shards,depth", [(1, 0), (1, 2), (3, 0), (3, 2)])
def test_log_fed_equals_delta_fed_followers(shards, depth):
    """Identical randomized workloads against a log-fed and a delta-fed
    replicated store produce identical read results AND identical
    serving-version stamps from every replica lane — the feed is an
    implementation detail of the follower image, never of what's served."""
    def drive(feed):
        st = replicated(cfg=SMALL, shards=shards, replicas=3, feed=feed,
                        depth=depth)
        svc = HoneycombService(st, batch_size=16, pipeline="serial")
        rng = np.random.default_rng(11)
        stamps = []
        for _ in range(6):
            tickets = []
            for _ in range(48):
                k = int_key(int(rng.integers(0, KEYSPACE)))
                roll = rng.random()
                if roll < 0.35:
                    svc.submit(Put(k, rng.bytes(int(rng.integers(0, 13)))))
                elif roll < 0.5:
                    svc.submit(Update(k, rng.bytes(8)))
                else:
                    tickets.append(svc.submit(Get(k)))
            svc.drain()
            stamps += [(t.result().value, t.result().serving_version,
                        t.result().replica) for t in tickets]
        # deterministic tail: overflow one leaf's log (merge -> fallback
        # epoch), then lone appends into the freshly merged leaf so the
        # log feed provably engages regardless of the random phase
        for _ in range(5):
            svc.submit(Put(int_key(0), b"t" * 8))
        svc.drain()
        for v in (b"u" * 8, b"w" * 8):
            svc.submit(Put(int_key(0), v))
            svc.drain()
        return st, stamps

    log_st, log_stamps = drive("log")
    delta_st, delta_stamps = drive("delta")
    assert log_stamps == delta_stamps
    # the log path actually engaged, and both feeds converged on the
    # primary's bit-identical follower images
    assert log_st.feed_stats.log_feed_epochs > 0
    assert delta_st.feed_stats.log_feed_epochs == 0
    log_st.export_snapshot()
    delta_st.export_snapshot()
    assert follower_images_match_primary(log_st)
    assert follower_images_match_primary(delta_st)
    # spread reads off every lane agree feed-to-feed
    keys = [int_key(i) for i in range(0, KEYSPACE, 7)]
    for ga, gb in zip(log_st.shards, delta_st.shards):
        for lane in range(4):
            assert ga.get_batch(keys, replica=lane) == \
                gb.get_batch(keys, replica=lane)


# ------------------------------------------- byte accounting invariants
def test_log_epoch_ships_no_image_rows_and_meters_exact_wire_bytes():
    """A log-fed epoch moves ZERO image rows to followers (the delta
    path's ~5 KB/dirty-node collapses to the wire entries) and the feed's
    wire meter equals the exact encoder accounting byte-for-byte."""
    st = replicated(replicas=2)
    g = st.shards[0]
    for i in range(30):
        st.put(int_key(i), b"v" * 8)
    st.export_snapshot()
    # force a merge so the measured epoch starts from an empty leaf log
    for _ in range(5):
        st.update(int_key(3), b"m" * 8)
    st.export_snapshot()
    f = g.followers[0]
    dmas0, img0 = f.sync_stats.image_dma_count, f.sync_stats.image_bytes
    replays0, wire0 = f.sync_stats.log_replays, g.feed_stats.wire_bytes
    writes = [(int_key(3), b"a" * 6), (int_key(3), b"b" * 3),
              (int_key(3), b"")]
    for k, v in writes:
        st.update(k, v)
    st.export_snapshot()
    assert f.sync_stats.image_dma_count == dmas0      # no image rows moved
    assert f.sync_stats.image_bytes == img0
    assert f.sync_stats.log_replays == replays0 + 1
    assert g.feed_stats.wire_bytes - wire0 == \
        sum(wire_entry_nbytes(k, v) for k, v in writes)
    assert follower_images_match_primary(st)
    assert g.get_batch([int_key(3)], replica=1) == [b""]


def test_fallback_triggers_merge_overflow_value_and_gc():
    """Epochs the wire stream cannot replay fall back to the image delta,
    each metered: a log-overflow merge (tree shape changed), a value past
    the inline limit (its heap placement is not derivable from the wire),
    and a GC pass (freed slots change rows no wire entry describes).
    Followers stay correct through every fallback."""
    st = replicated(replicas=2)
    g = st.shards[0]
    for i in range(30):
        st.put(int_key(i), b"v" * 8)
    st.export_snapshot()
    fb0 = g.feed_stats.log_fallback_epochs
    for _ in range(5):                       # log_cap=4 -> merge mid-epoch
        st.update(int_key(5), b"m" * 8)
    st.export_snapshot()
    assert g.feed_stats.log_fallback_epochs == fb0 + 1
    assert follower_images_match_primary(st)

    big = b"x" * (EXPL.max_inline_val_bytes + 8)     # overflow-length value
    st.update(int_key(6), big)
    st.export_snapshot()
    assert g.feed_stats.log_fallback_epochs == fb0 + 2
    assert g.get_batch([int_key(6)], replica=1) == [big]

    st.update(int_key(7), b"g" * 8)          # a replayable write...
    freed = st.collect_garbage()             # ...then GC poisons the epoch
    assert freed > 0                         # merges above deferred slots
    st.export_snapshot()
    assert g.feed_stats.log_fallback_epochs == fb0 + 3
    assert follower_images_match_primary(st)
    assert g.get_batch([int_key(7)], replica=1) == [b"g" * 8]


# --------------------------------------------------------- relay tree
def test_feed_topology_parents_shapes():
    flat = FeedTopology(fanout=2, depth=0)
    assert flat.parents(4) == {1: 0, 2: 0, 3: 0, 4: 0}
    tree = FeedTopology(fanout=2, depth=2)
    assert tree.parents(4) == {1: 0, 2: 0, 3: 1, 4: 1}
    # the leaf level spreads round-robin over the relay level
    assert tree.parents(7) == {1: 0, 2: 0, 3: 1, 4: 2, 5: 1, 6: 2, 7: 1}
    assert FeedTopology(fanout=3, depth=2).parents(2) == {1: 0, 2: 0}
    # parents always precede children so one staging pass delivers in order
    for n in (1, 3, 6, 9):
        par = FeedTopology(fanout=2, depth=3).parents(n)
        assert all(par[f] < f for f in par)


def test_relay_tree_bounds_primary_egress_to_fanout_edges():
    """With fanout=2 and 4 followers the primary pays for exactly its 2
    direct edges; the other half of the feed bytes ride relay hops.  The
    flat topology charges everything to the primary."""
    deep = replicated(replicas=5, fanout=2, depth=2)
    flat = replicated(replicas=5, fanout=2, depth=0)
    for st in (deep, flat):
        rng = np.random.default_rng(5)
        for i in rng.permutation(60):
            st.put(int_key(int(i)), b"v" * 8)
        st.export_snapshot()
        for _ in range(3):
            for i in range(8):
                st.update(int_key(int(rng.integers(0, 60))), b"u" * 8)
            st.export_snapshot()
    fsd, fsf = deep.feed_stats, flat.feed_stats
    assert deep.shards[0]._parents == {1: 0, 2: 0, 3: 1, 4: 1}
    assert fsd.primary_egress_bytes * 2 == fsd.feed_bytes
    assert fsd.relay_hop_bytes * 2 == fsd.feed_bytes
    assert fsf.primary_egress_bytes == fsf.feed_bytes
    assert fsf.relay_hop_bytes == 0
    # topology only reshapes WHO pays, never the total or the content
    assert fsd.feed_bytes == fsf.feed_bytes
    assert follower_images_match_primary(deep)


def test_lagging_relay_stales_subtree_then_catches_up():
    """Pausing a relay cuts off its subtree: the downstream follower goes
    stale WITH it (routed around, served from the primary, skip metered),
    and on resume the next staging full-copies both back into the feed."""
    st = replicated(replicas=4, fanout=2, depth=2)
    g = st.shards[0]
    assert g._parents == {1: 0, 2: 0, 3: 1}
    for i in range(40):
        st.put(int_key(i), b"v" * 8)
    st.export_snapshot()
    g.pause_follower(1)                       # relay for follower 3
    for i in range(6):
        st.update(int_key(i), b"w" * 8)
    st.export_snapshot()
    lag = g.replica_lag_epochs
    assert lag[0] >= 1 and lag[2] >= 1        # relay AND its child lag
    assert lag[1] == 0                        # primary-fed sibling is fresh
    keys = [int_key(i) for i in range(6)]
    skips0 = g.lagging_skips
    assert g.get_batch(keys, replica=1) == [b"w" * 8] * 6   # via primary
    assert g.get_batch(keys, replica=3) == [b"w" * 8] * 6
    assert g.lagging_skips == skips0 + 2
    g.resume_follower(1)
    catch0 = g.feed_stats.full_catchups
    for i in range(6):
        st.update(int_key(i), b"x" * 8)
    st.export_snapshot()
    assert g.feed_stats.full_catchups >= catch0 + 2
    assert g.replica_lag_epochs == [0, 0, 0]
    assert follower_images_match_primary(st)
    for lane in (1, 2, 3):
        assert g.get_batch(keys, replica=lane) == [b"x" * 8] * 6
        assert g.last_dispatch[0] == lane     # served by the lane itself


# ------------------------------------------------- replicas=1 overhead
def test_unreplicated_group_never_captures_the_log():
    """replicas=1 stays op-for-op the unreplicated store: no followers, no
    wire capture on the write path, no feed bytes."""
    st = replicated(replicas=1)
    g = st.shards[0]
    assert not g.followers and not g.primary.log_capture
    for i in range(20):
        st.put(int_key(i), b"v" * 8)
    st.export_snapshot()
    assert g.primary._epoch_log == []
    fs = st.feed_stats
    assert fs.feed_bytes == 0 and fs.log_feed_epochs == 0
