"""§Perf optimization variants must be bit-honest: the shard_map-local
paged attention and the EP ragged MoE agree with their baseline
implementations on a real multi-device mesh (8 CPU devices, subprocess)."""
import json
import subprocess
import sys

import pytest

SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed.paged_attention import paged_attention_local
from repro.kernels import ref as kref

rng = np.random.default_rng(0)
mesh = make_mesh((4, 2), ("data", "model"))
B, H, KVH, D, P_, PPS = 8, 4, 2, 16, 8, 4
NP = B * PPS
q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
kp = jnp.asarray(rng.normal(size=(NP, P_, KVH, D)), jnp.float32)
vp = jnp.asarray(rng.normal(size=(NP, P_, KVH, D)), jnp.float32)
# shard-contiguous identity block tables (sequence i owns rows i*PPS..)
bt = jnp.arange(NP, dtype=jnp.int32).reshape(B, PPS)
lens = jnp.asarray(rng.integers(1, P_ * PPS - 1, (B,)), jnp.int32)
start = jnp.zeros((B,), jnp.int32)
kn = jnp.asarray(rng.normal(size=(B, KVH, D)), jnp.float32)
vn = jnp.asarray(rng.normal(size=(B, KVH, D)), jnp.float32)

with mesh:
    out, kp2, vp2 = jax.jit(lambda *a: paged_attention_local(
        *a, mesh=mesh, batch_axes=("data",), kv_head_axis="model",
        head_dim_axis=None, page_size=P_, scale=D ** -0.5))(
        q, kp, vp, bt, lens, start, kn, vn)

# reference: scatter then ref paged attention
rows = np.arange(B)
page = np.asarray(bt)[rows, np.asarray(lens) // P_]
slot = np.asarray(lens) % P_
kp_ref = np.array(kp); vp_ref = np.array(vp)
kp_ref[page, slot] = np.asarray(kn); vp_ref[page, slot] = np.asarray(vn)
want = kref.paged_attention_ref(q, jnp.asarray(kp_ref), jnp.asarray(vp_ref),
                                bt, lens + 1, start, scale=D ** -0.5)
np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                           rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(np.asarray(kp2), kp_ref, atol=1e-6)

# --- EP ragged MoE vs dense on the same mesh ---------------------------
import dataclasses
from repro.configs import get_smoke_config
from repro.models import moe as me, schema as sc
cfg = dataclasses.replace(get_smoke_config("olmoe_1b_7b"),
                          n_experts=8, top_k=2, capacity_factor=8.0)
p = sc.init(me.moe_schema(cfg), jax.random.key(1))
x = jnp.asarray(rng.normal(size=(8, 16, cfg.d_model)) * 0.1, jnp.float32)
with mesh:
    pd = jax.device_put(p, jax.tree.map(
        lambda _: NamedSharding(mesh, P()), p))
    y_ep = jax.jit(lambda p, x: me.moe_ep_ragged(
        p, x, cfg, mesh=mesh, dp_axes=("data",)))(pd, x)
y_dense = me.moe_dense(p, x, cfg)
# capacity_factor is generous so no tokens are dropped -> exact match
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                           rtol=3e-4, atol=3e-4)

# --- f-sliced ragged MoE (any E; exact, no drops) ----------------------
cfg2 = dataclasses.replace(get_smoke_config("mixtral_8x22b"),
                           n_experts=4, top_k=2)
p2 = sc.init(me.moe_schema(cfg2), jax.random.key(2))
x2 = jnp.asarray(rng.normal(size=(8, 16, cfg2.d_model)) * 0.1, jnp.float32)
with mesh:
    pd2 = jax.device_put(p2, jax.tree.map(
        lambda _: NamedSharding(mesh, P()), p2))
    y_fs = jax.jit(lambda p, x: me.moe_fsliced_ragged(
        p, x, cfg2, mesh=mesh, dp_axes=("data",)))(pd2, x2)
np.testing.assert_allclose(np.asarray(y_fs),
                           np.asarray(me.moe_dense(p2, x2, cfg2)),
                           rtol=3e-4, atol=3e-4)
print(json.dumps({"ok": True}))
"""


@pytest.mark.slow
def test_local_paged_attention_and_ep_moe_multidevice():
    r = subprocess.run([sys.executable, "-c", SUBPROC], capture_output=True,
                       text=True, timeout=900,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert json.loads(r.stdout.strip().splitlines()[-1])["ok"]
