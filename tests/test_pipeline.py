"""Pipeline parallelism: the staged schedule equals sequential layer
application (8 CPU devices, subprocess)."""
import json
import subprocess
import sys

import pytest

SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed.pipeline import pipeline_apply, bubble_fraction

rng = np.random.default_rng(0)
S, M, mb, d = 4, 6, 2, 16          # 4 stages, 6 microbatches
mesh = make_mesh((4, 2), ("stage", "model"))
w = jnp.asarray(rng.normal(size=(S, d, d)) * 0.3, jnp.float32)
x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)

def stage_fn(p, x):
    return jnp.tanh(x @ p)

with mesh:
    wd = jax.device_put(w, NamedSharding(mesh, P("stage")))
    y = jax.jit(lambda w, x: pipeline_apply(
        stage_fn, w, x, mesh=mesh, stage_axis="stage"))(wd, x)

# sequential reference
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ w[s])
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
assert abs(bubble_fraction(6, 4) - 3/9) < 1e-9
print(json.dumps({"ok": True}))
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SUBPROC], capture_output=True,
                       text=True, timeout=600,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert json.loads(r.stdout.strip().splitlines()[-1])["ok"]
