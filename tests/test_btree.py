"""Host-side B+Tree: property tests against a dict oracle + protocol
invariants (MVCC snapshots, GC epochs, lock words, underflow merges)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.btree import HoneycombTree
from repro.core.config import HoneycombConfig
from repro.core.keys import int_key

SMALL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)


def apply_ops(tree, oracle, ops):
    for op, k, i in ops:
        key = int_key(k)
        if op == 0:
            v = f"v{i}".encode()
            tree.put(key, v)
            oracle[key] = v
        elif op == 1:
            v = f"u{i}".encode()
            tree.update(key, v)
            oracle[key] = v
        else:
            tree.delete(key)
            oracle.pop(key, None)


ops_strategy = st.lists(
    st.tuples(st.integers(0, 2), st.integers(0, 120),
              st.integers(0, 10 ** 6)),
    min_size=1, max_size=300)


@given(ops_strategy)
@settings(max_examples=25, deadline=None)
def test_tree_matches_dict_oracle(ops):
    tree = HoneycombTree(SMALL, heap_capacity=64)
    oracle = {}
    apply_ops(tree, oracle, ops)
    tree.check_invariants()
    for k in range(121):
        assert tree.get(int_key(k)) == oracle.get(int_key(k))
    items = tree.scan(int_key(0), int_key(121))
    assert items == sorted(oracle.items())


@given(ops_strategy, st.integers(0, 120), st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_scan_floor_semantics(ops, lo, width):
    tree = HoneycombTree(SMALL, heap_capacity=64)
    oracle = {}
    apply_ops(tree, oracle, ops)
    lo_k, hi_k = int_key(lo), int_key(min(lo + width, 121))
    got = tree.scan(lo_k, hi_k)
    floor = max((k for k in oracle if k <= lo_k), default=None)
    want = [(k, oracle[k]) for k in sorted(oracle)
            if (k == floor or k > lo_k) and k <= hi_k]
    assert got == want


def test_mvcc_snapshot_stability():
    tree = HoneycombTree(SMALL, heap_capacity=64)
    for i in range(60):
        tree.put(int_key(i), b"a%d" % i)
    rv = tree.versions.read_version()
    before = tree.scan(int_key(0), int_key(59), read_version=rv)
    for i in range(60):
        tree.update(int_key(i), b"b%d" % i)
    for i in range(0, 60, 2):
        tree.delete(int_key(i))
    assert tree.scan(int_key(0), int_key(59), read_version=rv) == before
    now = dict(tree.scan(int_key(0), int_key(59)))
    assert all(int.from_bytes(k, "big") % 2 == 1 for k in now)


def test_release_in_version_order():
    """Writers release to readers in write-version order (Section 3.2)."""
    from repro.core.mvcc import VersionManager
    vm = VersionManager(True)
    a, b, c = (vm.acquire_write_version() for _ in range(3))
    vm.release(b)
    assert vm.global_read_version == 0          # a still outstanding
    vm.release(a)
    assert vm.global_read_version == b          # cascades a then b
    vm.release(c)
    assert vm.global_read_version == c
    assert vm.device_read_version == c


def test_gc_waits_for_accelerator_epoch():
    tree = HoneycombTree(SMALL, heap_capacity=64)
    for i in range(200):
        tree.put(int_key(i), b"x")
    tree.epochs.cpu_begin(0)
    tree.gc.collect()                           # drain pre-epoch garbage
    lo, hi = tree.epochs.accel_begin_batch(8)   # inflight batch
    for i in range(40):
        tree.update(int_key(i), b"y" * 8)
    pending = len(tree.gc.list)
    assert pending > 0
    assert tree.gc.collect() == 0               # pinned by the open epoch
    tree.epochs.accel_complete_batch(lo, hi)
    tree.epochs.cpu_begin(0)                    # host thread moves on
    assert tree.gc.collect() == pending


def test_heap_slot_reuse_after_gc():
    tree = HoneycombTree(SMALL, heap_capacity=64)
    for i in range(300):
        tree.put(int_key(i % 50), b"v" * 8)
        if i % 64 == 0:
            tree.epochs.cpu_begin(0)
            tree.gc.collect()
    tree.epochs.cpu_begin(0)
    tree.gc.collect()
    assert tree.heap.live_slots < 40            # slots recycled, not leaked


def test_underflow_merges_and_empties():
    tree = HoneycombTree(SMALL, heap_capacity=128)
    for i in range(200):
        tree.put(int_key(i), b"x")
    h_before = tree.tree_height if hasattr(tree, "tree_height") else tree.height
    for i in range(199, 3, -1):
        tree.delete(int_key(i))
    tree.check_invariants()
    assert tree.stats.node_merges > 0
    assert [int.from_bytes(k, "big") for k, _ in
            tree.scan(int_key(0), int_key(300))] == [0, 1, 2, 3]


def test_overflow_values_roundtrip():
    cfg = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4, val_words=2)
    tree = HoneycombTree(cfg, heap_capacity=64)
    big = bytes(range(200))
    tree.put(int_key(1), big)
    tree.put(int_key(2), b"small")
    assert tree.get(int_key(1)) == big
    assert tree.get(int_key(2)) == b"small"
    tree.update(int_key(1), big * 2)
    assert tree.get(int_key(1)) == big * 2


def test_lock_word_protocol():
    tree = HoneycombTree(SMALL)
    phys = tree.pt.lookup(tree.root_lid)
    seq = tree.heap.seqno(phys)
    assert tree.heap.try_lock(phys, seq)
    assert not tree.heap.try_lock(phys, seq)        # already locked
    tree.heap.unlock_bump(phys)
    assert tree.heap.seqno(phys) == seq + 1
    assert not tree.heap.try_lock(phys, seq)        # stale seqno -> restart
    assert tree.heap.try_lock(phys, seq + 1)
    tree.heap.unlock_bump(phys)


def test_pagetable_sync_amortization():
    """Log blocks amortize accelerator page-table updates: syncs per write
    ~ 1/log_cap, the paper's core PCIe argument."""
    tree = HoneycombTree(HoneycombConfig(node_cap=32, log_cap=8,
                                         n_shortcuts=4))
    n = 400
    base = tree.pt.sync_commands
    rng = np.random.default_rng(0)
    for i in rng.integers(0, 200, n):
        tree.put(int_key(int(i)), b"v")
    per_write = (tree.pt.sync_commands - base) / n
    assert per_write < 0.5, per_write
