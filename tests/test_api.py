"""Typed request/response service API (core/api.py): wire-format
roundtrips and the exact encoder-vs-meter agreement, the legacy
stringly-submit shim's op-for-op equivalence (results AND sync byte
counts), the service-vs-direct-facade differential grid over
{shards} x {replicas} x {pipeline}, and end-to-end linearizability of the
serving-version stamps (monotone per key, follower answers cover the
primary's serving version, lagging followers exercised via the freshness
backstop)."""
import numpy as np
import pytest

from repro.core import (Delete, Get, HoneycombConfig, HoneycombService,
                        HoneycombStore, OutOfOrderScheduler, Put,
                        ReplicaGroup, ReplicationConfig, Scan, ServiceConfig,
                        ShardedHoneycombStore, StoreShard, Update,
                        WIRE_ENTRY_OVERHEAD, WireDecodeError, decode_wire,
                        decode_wire_stream, uniform_int_boundaries,
                        wire_entry_nbytes)
from repro.core.keys import int_key

SMALL = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4)
KEYSPACE = 200


def make_store(shards: int, replicas: int):
    if shards == 1 and replicas == 1:
        return HoneycombStore(SMALL, heap_capacity=256)
    return ShardedHoneycombStore(
        SMALL, heap_capacity=256, shards=shards,
        boundaries=(uniform_int_boundaries(KEYSPACE, shards)
                    if shards > 1 else None),
        replication=ReplicationConfig(
            replicas, "round_robin" if replicas > 1 else "primary_only"))


def random_ops(rng, n, key_space=KEYSPACE):
    """One randomized GET/SCAN/PUT/UPDATE/DELETE mix as typed ops."""
    ops = []
    for _ in range(n):
        k = int(rng.integers(0, key_space))
        p = rng.random()
        if p < 0.25:
            ops.append(Put(int_key(k), b"v%03d" % k))
        elif p < 0.35:
            ops.append(Update(int_key(k), b"u%03d" % k))
        elif p < 0.45:
            ops.append(Delete(int_key(k)))
        elif p < 0.8:
            ops.append(Get(int_key(k)))
        else:
            ops.append(Scan(int_key(k), int_key(min(k + 7, key_space - 1)),
                            expected_items=8))
    return ops


# ------------------------------------------------------------- wire format
def test_wire_roundtrip_every_op_type():
    """encode_wire/decode_wire are exact inverses for all five op types,
    and write-op encodings are exactly the metered log-entry size."""
    ops = [Get(b"k" * 31), Scan(b"", b"\xff" * 8, 17), Scan(b"a", b"a"),
           Put(b"key", b"value" * 3), Put(b"k", b""), Update(b"u", b"w"),
           Delete(b"gone"), Get(b"")]
    for op in ops:
        enc = op.encode_wire()
        dec, off = decode_wire(enc)
        assert dec == op
        assert off == len(enc)
        if op.IS_WRITE:
            assert len(enc) == wire_entry_nbytes(
                op.key, getattr(op, "value", b""))
    with pytest.raises(WireDecodeError):
        decode_wire(b"\x99\x00\x01\x00\x00X")     # unknown op code
    with pytest.raises(WireDecodeError):
        decode_wire(Put(b"key", b"value").encode_wire()[:-2])  # truncated
    with pytest.raises(AssertionError):
        Put(b"k", b"x" * 70000).encode_wire()     # over the u16 field limit
    with pytest.raises(AssertionError):
        Scan(b"a", b"z", expected_items=70000).encode_wire()


def test_wire_decode_rejects_malformed_buffers_cleanly():
    """Truncated or garbage buffers fail with ``WireDecodeError`` (a
    ``ValueError``), never ``struct.error``/``IndexError`` — the replica
    feed treats decode as all-or-nothing."""
    good = Put(b"key", b"value").encode_wire()
    assert decode_wire_stream(b"") == []         # empty stream is valid
    for bad in (b"\x00", good[:3],               # inside the fixed header
                good[:-1],                       # inside the payload
                good + good[: WIRE_ENTRY_OVERHEAD - 1],   # truncated tail
                b"\x7f" + good[1:],              # unknown op code
                bytes([good[0]]) + b"\xff\xff" + good[3:]):  # huge keylen
        with pytest.raises(WireDecodeError):
            decode_wire_stream(bad)
    assert issubclass(WireDecodeError, ValueError)
    # SCAN's trailing u16 count is covered by the same contract
    with pytest.raises(WireDecodeError):
        decode_wire(Scan(b"a", b"z", expected_items=7).encode_wire()[:-1])


def test_wire_roundtrip_zero_length_and_max_u16_fields():
    """Edge geometry survives the codec: zero-length values (a PUT of the
    empty string is one header + key, the meter's minimum) and keys/values
    at the u16 field limit."""
    edge = [Put(b"k", b""), Update(b"u" * 65535, b""),
            Put(b"p", b"v" * 65535), Delete(b"d" * 65535), Get(b"")]
    stream = b"".join(op.encode_wire() for op in edge)
    assert decode_wire_stream(stream) == edge
    assert len(Put(b"k", b"").encode_wire()) == wire_entry_nbytes(b"k", b"")
    assert len(Put(b"p", b"v" * 65535).encode_wire()) == \
        wire_entry_nbytes(b"p", b"v" * 65535)


def test_wire_stream_roundtrip():
    """A concatenated entry stream (the replica log-replay feed shape)
    decodes back op-for-op, offsets chaining exactly."""
    rng = np.random.default_rng(3)
    ops = random_ops(rng, 60)
    stream = b"".join(op.encode_wire() for op in ops)
    assert decode_wire_stream(stream) == ops


def test_wire_encoder_agrees_with_log_wire_meter_on_log_block_traffic():
    """The exact encoder reproduces the store's ``log_wire_bytes`` meter on
    benchmarks/log_block.py's sync-traffic workload: the former estimate is
    now the same shared accounting (``wire_entry_nbytes``)."""
    from benchmarks.log_block import WRITE_BATCHES, sync_traffic_curve
    n_items = 256
    st = HoneycombStore(HoneycombConfig(log_cap=8), heap_capacity=2048)
    load_rng = np.random.default_rng(0)
    load_ops = [Put(int_key(int(i)), b"v" * 16)
                for i in load_rng.permutation(n_items)]
    for op in load_ops:
        op.apply(st)
    assert st.sync_stats.log_wire_bytes == sum(
        len(op.encode_wire()) for op in load_ops)
    w0 = st.sync_stats.log_wire_bytes
    curve = sync_traffic_curve(st, n_items)
    # replay the exact op stream sync_traffic_curve generates (seed 23)
    rng = np.random.default_rng(23)
    total = 0
    for w in WRITE_BATCHES:
        batch_bytes = sum(
            len(Update(int_key(int(k)), b"u" * 16).encode_wire())
            for k in rng.integers(0, n_items, w))
        assert curve[w]["wire_bytes"] == batch_bytes  # per-batch agreement
        total += batch_bytes
    assert st.sync_stats.log_wire_bytes - w0 == total
    # the historical constant still matches the codec header
    assert WIRE_ENTRY_OVERHEAD == 5
    assert wire_entry_nbytes(b"12345678", b"x" * 16) == 5 + 8 + 16


# ------------------------------------------------- legacy submit() shim
def test_legacy_submit_shim_identical_to_op_path():
    """The stringly ``submit(kind, ...)`` facade delegates to the op path:
    op-for-op identical results AND sync byte counts versus the native
    typed submission — extending the shards=1 / serial / replicas=1
    invariant family to the API boundary."""
    mk = lambda: ShardedHoneycombStore(
        SMALL, heap_capacity=256, shards=2,
        boundaries=uniform_int_boundaries(KEYSPACE, 2),
        replication=ReplicationConfig(2, "round_robin"))
    a, b = mk(), mk()
    legacy = OutOfOrderScheduler(batch_size=8, routing=a.routing())
    typed = OutOfOrderScheduler(batch_size=8, routing=b.routing())
    rng = np.random.default_rng(11)
    for round_ in range(3):
        for op in random_ops(rng, 60):
            if isinstance(op, Scan):
                legacy.submit("scan", op.lo, op.hi,
                              expected_items=op.expected_items)
            elif op.IS_WRITE:
                legacy.submit(op.KIND, op.key, value=getattr(op, "value",
                                                             b""))
            else:
                legacy.submit("get", op.key)
            typed.submit_op(op)
        out_l = legacy.run(a)
        out_t = typed.run(b)
        assert out_l == out_t, round_
        assert a.sync_stats == b.sync_stats, round_   # bytes included
    assert a.sync_stats.delta_syncs > 0
    assert legacy.dispatched_batches == typed.dispatched_batches
    with pytest.raises(AssertionError):
        legacy.submit("upsert", b"k")


# ------------------------------------------------------- differential grid
@pytest.mark.parametrize("shards,replicas,pipeline",
                         [(s, r, p) for s in (1, 3) for r in (1, 2)
                          for p in ("serial", "pipelined")])
def test_service_equals_direct_facade(shards, replicas, pipeline):
    """Randomized mixed workload through ``HoneycombService`` returns
    exactly what direct facade calls on a twin store produce, across the
    {shards} x {replicas} x {pipeline} grid."""
    svc_store = make_store(shards, replicas)
    ref = make_store(shards, replicas)
    svc = HoneycombService(svc_store, batch_size=8, pipeline=pipeline)
    rng = np.random.default_rng(1000 + shards * 10 + replicas)
    for round_ in range(3):
        ops = random_ops(rng, 40)
        tickets = svc.submit_many(ops)
        svc.drain()
        # the direct-facade oracle replays the epoch the way the pipeline
        # semantics define it: writes in submission order, one sync, reads
        want = []
        for op in ops:
            if op.IS_WRITE:
                op.apply(ref)
        ref.export_snapshot()
        for op in ops:
            if isinstance(op, Get):
                want.append(ref.get_batch([op.key])[0])
            elif isinstance(op, Scan):
                want.append(ref.scan_batch([(op.lo, op.hi)])[0])
            else:
                want.append(None)
        for op, t, w in zip(ops, tickets, want):
            r = t.result()
            assert r.unwrap() == w, (round_, op)
            if isinstance(op, Get):
                assert r.ok == (w is not None)
                assert 0 <= r.replica < replicas
                assert r.shard == svc.routing.shard_of(op.key)
    assert svc_store.sync_stats == ref.sync_stats  # same sync byte counts


# -------------------------------------------------------- linearizability
def assert_monotone_serving_versions(records):
    """Linearizability helper: per key, the serving-version stamps never
    regress in submission (rid) order — a later read can never observe an
    older snapshot of that key than an earlier one did."""
    last: dict = {}
    for rid, key, resp in sorted(records, key=lambda t: t[0]):
        prev = last.get(key)
        assert prev is None or resp.serving_version >= prev, (
            f"rid {rid}: key {key!r} served at {resp.serving_version} "
            f"after {prev}")
        last[key] = resp.serving_version
    return last


def test_serving_version_monotone_and_covers_primary():
    """End-to-end linearizability of the stamps on a replicated store:
    per-key serving versions are monotone across epochs, every follower
    answer covers the primary's serving version, and a follower that lags
    after its pin was assigned is redirected by the freshness backstop
    (``lagging_skips``) with a FRESH stamp, never a stale one."""
    st = ShardedHoneycombStore(
        SMALL, heap_capacity=256, shards=1,
        replication=ReplicationConfig(3, "round_robin"))
    svc = HoneycombService(st, batch_size=4)
    group = st.shards[0]
    records = []
    rng = np.random.default_rng(7)
    follower_answers = 0
    for round_ in range(4):
        keys = [int(k) for k in rng.integers(0, 100, 12)]
        svc.submit_many([Put(int_key(k), b"r%d-%03d" % (round_, k))
                         for k in keys])
        tickets = [(svc.submit(Get(int_key(k))), int_key(k))
                   for k in keys]
        svc.drain()
        prim_v = group.primary.serving_version
        for t, key in tickets:
            r = t.result()
            records.append((t.rid, key, r))
            assert r.value == b"r%d-%03d" % (round_, int.from_bytes(
                key, "big")), "reads observe this epoch's writes"
            # every answer serves AT the primary's published version —
            # follower answers COVER it (freshness rule), primary answers
            # are it by definition
            assert r.serving_version >= prim_v, (round_, r)
            if r.replica > 0:
                follower_answers += 1
    assert follower_answers > 0            # spreading actually happened
    assert_monotone_serving_versions(records)

    # inject lag AFTER pins are assigned: submit reads (round-robin pins
    # cover the followers), then pause a follower and advance the primary
    # an epoch behind its back — the pinned batches must redirect
    tickets = [(svc.submit(Get(int_key(k))), int_key(k))
               for k in range(0, 100, 9)]
    group.pause_follower(1)
    group.pause_follower(2)
    for k in range(0, 100, 9):
        st.put(int_key(k), b"fresh%03d" % k)
    st.export_snapshot()                   # followers miss this epoch
    skips0 = st.lagging_skips
    svc.drain()
    assert st.lagging_skips > skips0
    prim_v = group.primary.serving_version
    for t, key in tickets:
        r = t.result()
        records.append((t.rid, key, r))
        assert r.replica == 0              # redirected to the primary
        assert r.serving_version >= prim_v
        assert r.value == b"fresh%03d" % int.from_bytes(key, "big")
    assert_monotone_serving_versions(records)


def test_write_responses_stamped_with_visibility_version():
    """Write responses carry the host-tree version at which the write
    became visible; a later read's serving version covers it."""
    st = HoneycombStore(SMALL, heap_capacity=256)
    svc = HoneycombService(st, batch_size=8)
    wt = svc.submit(Put(int_key(1), b"a"))
    rt = svc.submit(Get(int_key(1)))
    svc.drain()
    assert wt.result().ok and wt.result().serving_version > 0
    assert rt.result().serving_version >= wt.result().serving_version


# ------------------------------------------------------- service mechanics
def test_service_wraps_every_facade_layer():
    """routing() is provided by all three layers — plain store, bare
    replica group, sharded router — and the service self-wires each."""
    # plain shard facade
    plain = HoneycombStore(SMALL, heap_capacity=256)
    s1 = HoneycombService(plain, batch_size=4)
    s1.submit_many([Put(int_key(i), b"p%d" % i) for i in range(20)])
    s1.drain()
    t = s1.submit(Get(int_key(7)))
    assert t.result().value == b"p7"
    assert t.result().shard == 0 and t.result().replica == 0
    # bare replica group (no router in front)
    group = ReplicaGroup(StoreShard(SMALL, heap_capacity=256),
                         ReplicationConfig(2, "round_robin"))
    s2 = HoneycombService(group, batch_size=4)
    s2.submit_many([Put(int_key(i), b"g%d" % i) for i in range(40)])
    s2.drain()
    tickets = s2.submit_many([Get(int_key(i)) for i in range(0, 40, 2)])
    s2.drain()
    assert [t.result().value for t in tickets] \
        == [b"g%d" % i for i in range(0, 40, 2)]
    assert {t.result().replica for t in tickets} == {0, 1}  # spread happened
    # sharded router
    sh = make_store(3, 1)
    s3 = HoneycombService(sh, batch_size=4)
    s3.submit_many([Put(int_key(i), b"s%d" % i) for i in range(0, 200, 5)])
    s3.drain()
    span = s3.submit(Scan(int_key(1), int_key(198), expected_items=32))
    got = span.result()                    # result() drains on demand
    assert got.ok and len(got.items) > 0
    assert got.items == sh.scan_batch([(int_key(1), int_key(198))])[0]


def test_ticket_result_drains_on_demand_and_pending_counts():
    st = HoneycombStore(SMALL, heap_capacity=256)
    svc = HoneycombService(st)
    svc.submit(Put(int_key(5), b"v"))
    t = svc.submit(Get(int_key(5)))
    assert not t.done and svc.pending == 2
    assert t.result().value == b"v"        # implicit drain
    assert t.done and svc.pending == 0
    assert t.result() is t.result()        # resolved once, cached


def test_service_config_validation():
    with pytest.raises(AssertionError):
        ServiceConfig(pipeline="warp")
    with pytest.raises(AssertionError):
        ServiceConfig(batch_size=0)
    st = HoneycombStore(SMALL, heap_capacity=256)
    svc = HoneycombService(st, cfg=ServiceConfig(batch_size=16),
                           pipeline="pipelined")
    assert svc.cfg.batch_size == 16 and svc.cfg.pipeline == "pipelined"
