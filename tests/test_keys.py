"""Key packing/comparison: host numpy vs jax vs python bytes semantics."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.keys import (int_key, jax_key_cmp, key_cmp, pack_key,
                             pack_keys, unpack_key)

KW = 4


def ref_cmp(a: bytes, b: bytes) -> int:
    return (a > b) - (a < b)


@given(st.binary(max_size=KW * 4), st.binary(max_size=KW * 4))
@settings(max_examples=200, deadline=None)
def test_key_cmp_matches_bytes(a, b):
    la, lb = pack_key(a, KW), pack_key(b, KW)
    assert key_cmp(la, len(a), lb, len(b)) == ref_cmp(a, b)


@given(st.binary(max_size=KW * 4), st.binary(max_size=KW * 4))
@settings(max_examples=100, deadline=None)
def test_jax_cmp_matches_host(a, b):
    la, lb = pack_key(a, KW), pack_key(b, KW)
    j = int(jax_key_cmp(jnp.asarray(la), jnp.int32(len(a)),
                        jnp.asarray(lb), jnp.int32(len(b))))
    assert j == key_cmp(la, len(a), lb, len(b))


@given(st.binary(max_size=KW * 4))
@settings(max_examples=50, deadline=None)
def test_pack_roundtrip(key):
    lanes = pack_key(key, KW)
    assert unpack_key(lanes, len(key)) == key


def test_int_key_orders_numerically():
    ks = [int_key(i) for i in (0, 1, 255, 256, 65535, 2**31)]
    assert ks == sorted(ks)


def test_pack_keys_batch():
    lanes, lens = pack_keys([b"a", b"bc", b""], KW)
    assert lanes.shape == (3, KW)
    assert list(lens) == [1, 2, 0]


def test_oversize_key_raises():
    with pytest.raises(ValueError):
        pack_key(b"x" * (KW * 4 + 1), KW)
