"""Accelerator read path (pure-JAX) vs the host implementation."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import HoneycombConfig, HoneycombStore
from repro.core.keys import int_key
from repro.core.read_path import log_sort_positions

import jax.numpy as jnp

CFG = HoneycombConfig(node_cap=16, log_cap=4, n_shortcuts=4,
                      max_scan_items=16, max_scan_leaves=4)


def build_store(ops):
    st_ = HoneycombStore(CFG, heap_capacity=128)
    oracle = {}
    for op, k, i in ops:
        key = int_key(k)
        if op == 0:
            v = f"v{i}".encode()
            st_.put(key, v)
            oracle[key] = v
        elif op == 1:
            v = f"u{i}".encode()
            st_.update(key, v)
            oracle[key] = v
        else:
            st_.delete(key)
            oracle.pop(key, None)
    return st_, oracle


ops_strategy = st.lists(
    st.tuples(st.integers(0, 2), st.integers(0, 100),
              st.integers(0, 10 ** 6)),
    min_size=5, max_size=200)


@given(ops_strategy)
@settings(max_examples=10, deadline=None)
def test_batched_get_matches_host(ops):
    store, oracle = build_store(ops)
    keys = [int_key(k) for k in range(0, 101, 3)]
    got = store.get_batch(keys)
    for k, g in zip(keys, got):
        assert g == oracle.get(k)


@given(ops_strategy, st.lists(st.tuples(st.integers(0, 100),
                                        st.integers(1, 6)),
                              min_size=1, max_size=16))
@settings(max_examples=10, deadline=None)
def test_batched_scan_matches_host(ops, ranges):
    store, _ = build_store(ops)
    rs = [(int_key(a), int_key(min(a + w, 100))) for a, w in ranges]
    dev = store.scan_batch(rs)
    for (lo, hi), d in zip(rs, dev):
        assert d == store.tree.scan(lo, hi)


def test_scan_across_leaves_and_truncation():
    store = HoneycombStore(CFG, heap_capacity=128)
    for i in range(120):
        store.put(int_key(i), b"v%03d" % i)
    # a wide scan: device truncates at max_scan_items/leaves, falls back to
    # host -> result must still be exact
    [items] = store.scan_batch([(int_key(0), int_key(119))])
    assert len(items) == 120
    assert items == store.tree.scan(int_key(0), int_key(119))


def test_reads_are_wait_free_snapshots():
    """A device snapshot keeps answering at its read version while the host
    writes — wait-free MVCC (no retry, no lock, stable results)."""
    store = HoneycombStore(CFG, heap_capacity=128)
    for i in range(50):
        store.put(int_key(i), b"old")
    snap_before = store.export_snapshot()
    rv = int(snap_before.read_version)
    for i in range(50):
        store.update(int_key(i), b"new")
    # re-reading through the OLD snapshot sees the old values
    from repro.core.read_path import batched_get
    from repro.core.keys import pack_keys
    lanes, lens = pack_keys([int_key(i) for i in range(50)], CFG.key_words)
    res = batched_get(snap_before, jnp.asarray(lanes), jnp.asarray(lens),
                      CFG)
    assert bool(res.found.all())
    vals = np.asarray(res.vals)
    for i in range(50):
        assert vals[i].astype(">u4").tobytes()[:3] == b"old"
    # and the refreshed snapshot sees the new ones
    assert store.get_batch([int_key(0)])[0] == b"new"


def shift_register_ref(hints):
    """Literal simulation of the paper's Fig. 8 shift register."""
    out = []
    for h in hints:
        out.insert(h, None)
        idx = out.index(None)
        out[idx] = h
    # positions of each insertion in final order
    pos = [0] * len(hints)
    arr = []
    for j, h in enumerate(hints):
        arr.insert(h, j)
    for p, j in enumerate(arr):
        pos[j] = p
    return pos


@given(st.lists(st.integers(0, 0), min_size=0, max_size=0))
def _noop(_):
    pass


@given(st.integers(1, 8).flatmap(
    lambda n: st.tuples(st.just(n),
                        st.lists(st.integers(0, n), min_size=n, max_size=n))))
@settings(max_examples=50, deadline=None)
def test_log_sort_positions_match_shift_register(args):
    n, raw = args
    hints = [min(h, j) for j, h in enumerate(raw)]   # hint[j] <= j
    want = shift_register_ref(hints)
    L = 8
    padded = hints + [0] * (L - n)
    got = log_sort_positions(jnp.asarray([padded], jnp.int32),
                             jnp.asarray([n]), L)
    assert list(np.asarray(got)[0][:n]) == want


def test_order_hints_give_sorted_log():
    """End to end: hint-based ordering equals key order within a leaf."""
    store = HoneycombStore(HoneycombConfig(node_cap=64, log_cap=8,
                                           n_shortcuts=8), heap_capacity=64)
    for k in (90, 60, 30, 45):                      # the paper's Fig. 7
        store.put(int_key(k), b"v")
    h = store.tree.heap
    phys = store.tree.pt.lookup(store.tree.root_lid)
    hints = list(h.log_hint[phys][: int(h.nlog[phys])])
    assert hints == [0, 0, 0, 1]
    [items] = store.scan_batch([(int_key(0), int_key(100))])
    assert [int.from_bytes(k, "big") for k, _ in items] == [30, 45, 60, 90]
