"""Software-only ordered key-value store — the eRPC-Masstree stand-in.

The paper's baseline (Section 6) is Masstree behind eRPC: a cache-crafted
in-memory trie/B+tree executed entirely on CPU cores.  For the benchmark
comparison we provide a well-implemented software store with the same
interface as HoneycombStore: a classic sorted-node B+tree (no shortcuts, no
log blocks, no MVCC, no accelerator path — every operation is a host
operation touching whole nodes).

The benchmarks meter *bytes touched* and operations/second so the
Honeycomb-vs-CPU comparison reproduces the paper's shape: Honeycomb wins on
read/scan throughput per (modeled) byte of interconnect, the CPU baseline
wins on pure write paths.
"""
from __future__ import annotations

import bisect
import dataclasses


@dataclasses.dataclass
class CpuStoreStats:
    gets: int = 0
    puts: int = 0
    deletes: int = 0
    scans: int = 0
    bytes_touched: int = 0
    node_visits: int = 0

    def collect(self):
        """Registry samples (core/telemetry.py collect protocol):
        ``cpu_store_*`` counters for the host-baseline op mix."""
        from repro.core.telemetry import samples_from
        return samples_from(self, "cpu_store", "baseline")


class _Leaf:
    __slots__ = ("keys", "vals", "next")

    def __init__(self):
        self.keys: list[bytes] = []
        self.vals: list[bytes] = []
        self.next: _Leaf | None = None


class CpuOrderedStore:
    """B+tree with in-leaf sorted arrays; interior levels as sorted lists of
    (separator, child).  Node capacity mirrors honeycomb's node_cap."""

    def __init__(self, node_cap: int = 64):
        self.node_cap = node_cap
        root = _Leaf()
        self.levels: list[list[bytes]] = []   # separators per interior level
        self.children: list[list] = []        # children per interior level
        self.leaves: list[_Leaf] = [root]
        self.stats = CpuStoreStats()

    # simple two-level structure: a sorted list of leaf minimums
    # (fanout-free "interior"), which is what Masstree's upper trie
    # amortizes to for random keys; adequate as a throughput baseline.
    def _find_leaf(self, key: bytes) -> _Leaf:
        self.stats.node_visits += 1
        idx = bisect.bisect_right(self._mins, key) - 1
        return self.leaves[max(idx, 0)]

    @property
    def _mins(self) -> list[bytes]:
        return [lf.keys[0] if lf.keys else b"" for lf in self.leaves]

    def put(self, key: bytes, val: bytes):
        self.stats.puts += 1
        lf = self._find_leaf(key)
        i = bisect.bisect_left(lf.keys, key)
        self.stats.bytes_touched += sum(map(len, lf.keys)) \
            + sum(map(len, lf.vals))
        if i < len(lf.keys) and lf.keys[i] == key:
            lf.vals[i] = val
        else:
            lf.keys.insert(i, key)
            lf.vals.insert(i, val)
            if len(lf.keys) > self.node_cap:
                self._split(lf)

    update = put

    def _split(self, lf: _Leaf):
        mid = len(lf.keys) // 2
        right = _Leaf()
        right.keys, right.vals = lf.keys[mid:], lf.vals[mid:]
        lf.keys, lf.vals = lf.keys[:mid], lf.vals[:mid]
        right.next, lf.next = lf.next, right
        pos = self.leaves.index(lf)
        self.leaves.insert(pos + 1, right)

    def delete(self, key: bytes):
        self.stats.deletes += 1
        lf = self._find_leaf(key)
        i = bisect.bisect_left(lf.keys, key)
        self.stats.bytes_touched += sum(map(len, lf.keys))
        if i < len(lf.keys) and lf.keys[i] == key:
            del lf.keys[i], lf.vals[i]
            if not lf.keys and len(self.leaves) > 1:
                pos = self.leaves.index(lf)
                if pos > 0:
                    self.leaves[pos - 1].next = lf.next
                del self.leaves[pos]

    def get(self, key: bytes) -> bytes | None:
        self.stats.gets += 1
        lf = self._find_leaf(key)
        self.stats.bytes_touched += sum(map(len, lf.keys))
        i = bisect.bisect_left(lf.keys, key)
        if i < len(lf.keys) and lf.keys[i] == key:
            self.stats.bytes_touched += len(lf.vals[i])
            return lf.vals[i]
        return None

    def scan(self, lo: bytes, hi: bytes,
             max_items: int | None = None) -> list[tuple[bytes, bytes]]:
        """Floor-start scan with Honeycomb-compatible semantics."""
        self.stats.scans += 1
        out: list[tuple[bytes, bytes]] = []
        lf = self._find_leaf(lo)
        # floor: the largest key <= lo (may sit in an earlier leaf)
        floor = None
        pos = self.leaves.index(lf)
        for j in range(pos, -1, -1):
            cand = [k for k in self.leaves[j].keys if k <= lo]
            self.stats.bytes_touched += sum(
                map(len, self.leaves[j].keys))
            if cand:
                floor = cand[-1]
                v = self.leaves[j].vals[self.leaves[j].keys.index(floor)]
                out.append((floor, v))
                break
        node: _Leaf | None = lf
        while node is not None:
            self.stats.node_visits += 1
            self.stats.bytes_touched += sum(map(len, node.keys)) \
                + sum(map(len, node.vals))
            for k, v in zip(node.keys, node.vals):
                if k <= lo:
                    continue
                if k > hi:
                    return out
                out.append((k, v))
                if max_items and len(out) >= max_items:
                    return out
            node = node.next
        return out

    # batch facades for benchmark parity with HoneycombStore
    def get_batch(self, keys):
        return [self.get(k) for k in keys]

    def scan_batch(self, ranges):
        return [self.scan(lo, hi) for lo, hi in ranges]
