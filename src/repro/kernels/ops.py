"""Jit'd dispatch wrappers for the Pallas kernels.

``backend`` selects the implementation:
  "ref"       pure-jnp oracle — what XLA:CPU lowers (dry-run / CI default)
  "interpret" Pallas kernel body executed in Python on CPU (correctness)
  "pallas"    compiled Pallas kernel — real TPUs

The default follows the runtime: TPU -> pallas, else ref.
"""
from __future__ import annotations

import functools

import jax

from . import delta_scatter as _ds
from . import key_search as _ks
from . import leaf_merge as _lm
from . import paged_attention as _pa
from . import ref as _ref


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def key_search(q, qlen, keys, klens, valid, backend: str | None = None,
               **kw):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.key_search_ref(q, qlen, keys, klens, valid)
    return _ks.key_search(q, qlen, keys, klens, valid,
                          interpret=(backend == "interpret"), **kw)


def key_search_image(q, qlen, node_img, *, keys_off, lens_off, count_off,
                     n_keys, key_words, backend: str | None = None, **kw):
    """Floor search addressed INSIDE packed node images (cfg.layout=
    "packed"): the candidate block is sliced from each request's image row
    at static layout offsets (core/schema.py) instead of arriving as
    separate key/length/valid operands."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.key_search_image_ref(
            q, qlen, node_img, keys_off=keys_off, lens_off=lens_off,
            count_off=count_off, n_keys=n_keys, key_words=key_words)
    return _ks.key_search_image(
        q, qlen, node_img, keys_off=keys_off, lens_off=lens_off,
        count_off=count_off, n_keys=n_keys, key_words=key_words,
        interpret=(backend == "interpret"), **kw)


def leaf_merge(nitems, nlog, backptr, hints, *, node_cap, log_cap,
               backend: str | None = None, **kw):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.leaf_merge_ref(nitems, nlog, backptr, hints,
                                   node_cap=node_cap, log_cap=log_cap)
    return _lm.leaf_merge(nitems, nlog, backptr, hints, node_cap=node_cap,
                          log_cap=log_cap,
                          interpret=(backend == "interpret"), **kw)


def snapshot_delta_scatter(dst, rows, upd, backend: str | None = None, **kw):
    """Apply one delta sync's dirty rows to a resident device array
    (host->device snapshot patch).  ``dst``/``upd`` are [S, W]/[D, W] with
    trailing dims flattened; see ``repro.core.read_path.apply_snapshot_delta``
    for the whole-snapshot jnp path the store uses off-TPU."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.snapshot_delta_scatter_ref(dst, rows, upd)
    return _ds.snapshot_delta_scatter(dst, rows, upd,
                                      interpret=(backend == "interpret"),
                                      **kw)


def snapshot_image_scatter(image, rows, upd, backend: str | None = None,
                           **kw):
    """Apply one delta sync to the PACKED snapshot image: one contiguous
    [image_words] row DMA per dirty node (the paper's whole-node transfer,
    cfg.layout="packed").  ``image``/``upd`` are [S, IW]/[D, IW] u32; see
    ``repro.core.read_path.apply_snapshot_delta`` for the store wiring and
    the jnp oracle kept as the parity reference."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.snapshot_image_scatter_ref(image, rows, upd)
    return _ds.snapshot_image_scatter(image, rows, upd,
                                      interpret=(backend == "interpret"),
                                      **kw)


def log_replay_scatter(image, rows, slots, entries, *, offs,
                       backend: str | None = None, **kw):
    """Replay marshalled log entries into the resident packed snapshot
    image (the log-shipped replication feed): entry ``i`` writes its
    ~(key_words + val_words + 6) words into row ``rows[i]`` at the static
    layout offsets in ``offs`` (``core/schema.LogReplayOffsets``), instead
    of a whole ``image_words`` row DMA per dirty node.  ``slots`` are the
    per-entry log indices (monotone per row within an epoch; padding
    repeats the last record)."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.log_replay_scatter_ref(image, rows, slots, entries,
                                           offs=offs)
    return _ds.log_replay_scatter(image, rows, slots, entries, offs=offs,
                                  interpret=(backend == "interpret"), **kw)


def snapshot_multi_scatter(dsts, rows, upd, backend: str | None = None,
                           **kw):
    """Apply one delta sync's dirty rows to EVERY per-node field of the
    resident snapshot in a single fused kernel invocation (the paper's
    whole-node DMA).  ``dsts``/``upd`` are matching sequences of
    [S, W_f]/[D, W_f] arrays with trailing dims flattened; see
    ``repro.core.read_path.apply_snapshot_delta`` for the store wiring and
    the jnp oracle kept as the parity reference."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.snapshot_multi_scatter_ref(dsts, rows, upd)
    return _ds.snapshot_multi_scatter(dsts, rows, upd,
                                      interpret=(backend == "interpret"),
                                      **kw)


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                    start_pos=None, backend: str | None = None, **kw):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.paged_attention_ref(q, k_pages, v_pages, block_tables,
                                        seq_lens, start_pos, **kw)
    return _pa.paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                               start_pos,
                               interpret=(backend == "interpret"), **kw)
