"""Jit'd dispatch wrappers for the Pallas kernels.

``backend`` selects the implementation:
  "ref"       pure-jnp oracle — what XLA:CPU lowers (dry-run / CI default)
  "interpret" Pallas kernel body executed in Python on CPU (correctness)
  "pallas"    compiled Pallas kernel — real TPUs

The default follows the runtime: TPU -> pallas, else ref.
"""
from __future__ import annotations

import collections
import functools

import jax

from . import delta_scatter as _ds
from . import fused_read as _fr
from . import key_search as _ks
from . import leaf_merge as _lm
from . import paged_attention as _pa
from . import ref as _ref


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# ---------------------------------------------------------------- dispatch
# counter: device launches per read batch, recorded at the NON-jitted shard
# dispatch site (core/shard.py) so trace-caching can't hide repeats.  The
# counts are the analytic launch model the latency benchmark pins (like
# PR 6's dma/node 24 -> 1): the fused megakernels execute the whole
# traversal in ONE pallas_call, where the reference path issues one
# gather/merge stage per descend level plus one per scan-leaf visit (floor
# pre-pass + forward pass) and GET adds its equality post-pass.
READ_DISPATCHES: collections.Counter = collections.Counter()


def read_dispatch_count(op: str, read_backend: str, cfg) -> int:
    """Device dispatches one ``op`` ("get"/"scan") batch costs under
    ``read_backend`` ("fused"/"reference") at this config's static
    traversal bounds."""
    if read_backend == "fused":
        return 1
    n = cfg.max_height + 2 * cfg.max_scan_leaves
    return n + 1 if op == "get" else n


def record_read_dispatch(op: str, read_backend: str, cfg, batches: int = 1):
    """Meter ``batches`` read-batch dispatches (called per device call by
    the shard layer)."""
    READ_DISPATCHES[(op, read_backend)] += \
        batches * read_dispatch_count(op, read_backend, cfg)
    READ_DISPATCHES[("batches", op, read_backend)] += batches


def reset_read_dispatches():
    READ_DISPATCHES.clear()


def read_dispatch_stats() -> dict:
    """Per-(op, backend) dispatched-launch totals and per-batch averages."""
    out = {}
    for op in ("get", "scan"):
        for rb in ("fused", "reference"):
            b = READ_DISPATCHES.get(("batches", op, rb), 0)
            d = READ_DISPATCHES.get((op, rb), 0)
            if b:
                out[f"{op}_{rb}"] = {"batches": b, "dispatches": d,
                                     "per_batch": d / b}
    return out


def collect() -> list:
    """Telemetry source for the launch meter (core/telemetry.py collect
    protocol).  Returns plain ``(name, kind, value, labels)`` tuples —
    kernels must not import repro.core (core imports kernels), so the
    registry normalizes the dependency-free form."""
    out = []
    for op in ("get", "scan"):
        for rb in ("fused", "reference"):
            b = READ_DISPATCHES.get(("batches", op, rb), 0)
            d = READ_DISPATCHES.get((op, rb), 0)
            if b or d:
                labels = {"layer": "kernel", "op": op, "backend": rb}
                out.append(("read_dispatches", "counter", d, labels))
                out.append(("read_batches", "counter", b, labels))
    return out


def key_search(q, qlen, keys, klens, valid, backend: str | None = None,
               **kw):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.key_search_ref(q, qlen, keys, klens, valid)
    return _ks.key_search(q, qlen, keys, klens, valid,
                          interpret=(backend == "interpret"), **kw)


def key_search_image(q, qlen, node_img, *, keys_off, lens_off, count_off,
                     n_keys, key_words, backend: str | None = None, **kw):
    """Floor search addressed INSIDE packed node images (cfg.layout=
    "packed"): the candidate block is sliced from each request's image row
    at static layout offsets (core/schema.py) instead of arriving as
    separate key/length/valid operands."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.key_search_image_ref(
            q, qlen, node_img, keys_off=keys_off, lens_off=lens_off,
            count_off=count_off, n_keys=n_keys, key_words=key_words)
    return _ks.key_search_image(
        q, qlen, node_img, keys_off=keys_off, lens_off=lens_off,
        count_off=count_off, n_keys=n_keys, key_words=key_words,
        interpret=(backend == "interpret"), **kw)


def leaf_merge(nitems, nlog, backptr, hints, *, node_cap, log_cap,
               backend: str | None = None, **kw):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.leaf_merge_ref(nitems, nlog, backptr, hints,
                                   node_cap=node_cap, log_cap=log_cap)
    return _lm.leaf_merge(nitems, nlog, backptr, hints, node_cap=node_cap,
                          log_cap=log_cap,
                          interpret=(backend == "interpret"), **kw)


def snapshot_delta_scatter(dst, rows, upd, backend: str | None = None, **kw):
    """Apply one delta sync's dirty rows to a resident device array
    (host->device snapshot patch).  ``dst``/``upd`` are [S, W]/[D, W] with
    trailing dims flattened; see ``repro.core.read_path.apply_snapshot_delta``
    for the whole-snapshot jnp path the store uses off-TPU."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.snapshot_delta_scatter_ref(dst, rows, upd)
    return _ds.snapshot_delta_scatter(dst, rows, upd,
                                      interpret=(backend == "interpret"),
                                      **kw)


def snapshot_image_scatter(image, rows, upd, backend: str | None = None,
                           **kw):
    """Apply one delta sync to the PACKED snapshot image: one contiguous
    [image_words] row DMA per dirty node (the paper's whole-node transfer,
    cfg.layout="packed").  ``image``/``upd`` are [S, IW]/[D, IW] u32; see
    ``repro.core.read_path.apply_snapshot_delta`` for the store wiring and
    the jnp oracle kept as the parity reference."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.snapshot_image_scatter_ref(image, rows, upd)
    return _ds.snapshot_image_scatter(image, rows, upd,
                                      interpret=(backend == "interpret"),
                                      **kw)


def log_replay_scatter(image, rows, slots, entries, *, offs,
                       backend: str | None = None, **kw):
    """Replay marshalled log entries into the resident packed snapshot
    image (the log-shipped replication feed): entry ``i`` writes its
    ~(key_words + val_words + 6) words into row ``rows[i]`` at the static
    layout offsets in ``offs`` (``core/schema.LogReplayOffsets``), instead
    of a whole ``image_words`` row DMA per dirty node.  ``slots`` are the
    per-entry log indices (monotone per row within an epoch; padding
    repeats the last record)."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.log_replay_scatter_ref(image, rows, slots, entries,
                                           offs=offs)
    return _ds.log_replay_scatter(image, rows, slots, entries, offs=offs,
                                  interpret=(backend == "interpret"), **kw)


def snapshot_multi_scatter(dsts, rows, upd, backend: str | None = None,
                           **kw):
    """Apply one delta sync's dirty rows to EVERY per-node field of the
    resident snapshot in a single fused kernel invocation (the paper's
    whole-node DMA).  ``dsts``/``upd`` are matching sequences of
    [S, W_f]/[D, W_f] arrays with trailing dims flattened; see
    ``repro.core.read_path.apply_snapshot_delta`` for the store wiring and
    the jnp oracle kept as the parity reference."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.snapshot_multi_scatter_ref(dsts, rows, upd)
    return _ds.snapshot_multi_scatter(dsts, rows, upd,
                                      interpret=(backend == "interpret"),
                                      **kw)


def batched_get_fused(snap, key, klen, *, cfg, lb_fraction: float = 0.0,
                      backend: str | None = None):
    """Fused device-resident GET: the whole batch traversal (descend +
    leaf resolve + log merge + version resolution) in ONE dispatch, the
    first ``cfg.cache_levels`` levels served from the snapshot's
    VMEM-pinned cache tier.  ``snap`` is a packed ``TreeSnapshot`` with
    cache fields attached.  Returns (GetResult, meters i32[3] =
    [vmem_hits, heap_gathers, lb_routed])."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.batched_get_fused_ref(snap, key, klen, cfg=cfg,
                                          lb_fraction=lb_fraction)
    return _fr.batched_get_fused(
        snap.image, snap.pagetable, snap.root_lid, snap.read_version,
        snap.cache_lids, snap.cache_image, key, klen, cfg=cfg,
        lb_fraction=lb_fraction, interpret=(backend == "interpret"))


def batched_scan_fused(snap, lo, lolen, hi, hilen, *, cfg,
                       lb_fraction: float = 0.0,
                       backend: str | None = None):
    """Fused device-resident SCAN — see ``batched_get_fused``.  Returns
    (ScanResult, meters i32[3])."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.batched_scan_fused_ref(snap, lo, lolen, hi, hilen,
                                           cfg=cfg, lb_fraction=lb_fraction)
    return _fr.batched_scan_fused(
        snap.image, snap.pagetable, snap.root_lid, snap.read_version,
        snap.cache_lids, snap.cache_image, lo, lolen, hi, hilen, cfg=cfg,
        lb_fraction=lb_fraction, interpret=(backend == "interpret"))


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                    start_pos=None, backend: str | None = None, **kw):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.paged_attention_ref(q, k_pages, v_pages, block_tables,
                                        seq_lens, start_pos, **kw)
    return _pa.paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                               start_pos,
                               interpret=(backend == "interpret"), **kw)
