"""Fused device-resident GET/SCAN megakernels (paper Sections 4-5).

One ``pallas_call`` executes the WHOLE per-request traversal — multi-level
descend over the packed node image, leaf resolve, order-hint log merge,
MVCC version resolution — where the reference path (core/read_path.py)
issues one gather storm per level.  The grid iterates over the request
batch (one program per request, ``PrefetchScalarGridSpec`` carrying the
root LID + read version as scalars), so a read batch costs ONE device
dispatch regardless of tree height or scan budget.

The paper's cache tiers run for real here: the snapshot's contiguous
``[cache_slots, image_words]`` cache array (root + top interior levels,
packed at export — core/cache.py / ``attach_cache_image``) arrives through
a plain VMEM BlockSpec, pinning it on-core for every program; a descend
level whose LID is in the cache resolves from that block under a
``lax.cond`` — the heap-image load, pagetable lookup and MVCC walk are
genuinely not executed — while levels below the cached frontier fall
through to dynamic row loads against the heap image (``pltpu.ANY`` +
``pl.ds``, the ``log_replay_scatter`` addressing idiom).  The compile-time
``lb_fraction`` knob deterministically routes a slice of cache-HIT
programs down the heap pipe anyway (Section 5's dual-pipe load balancer);
per-program ``[vmem_hits, heap_gathers, lb_routed]`` meters come back as
an output block.

Field decoding inside the body reuses ``NodeImageLayout.field_views`` on
single ``[1, image_words]`` rows and the search/merge helpers from
core/read_path.py (``_shortcut_floor``/``_segment_floor``/
``_resolve_leaf``) on the resulting one-row views — the kernel and the
jnp oracle (``kernels/ref.py`` ``batched_*_fused_ref``) share the actual
search arithmetic, so interpret-mode parity pins only the traversal
plumbing.  The oracle is what XLA:CPU lowers; ``interpret=True`` is the
CPU-testable kernel path, compiled Mosaic the TPU one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import read_path as _rp
from repro.core.heap import LEAF, NULL
from repro.core.keys import jax_key_cmp
from repro.core.schema import NodeImageLayout


def _row_view(layout, row, rv):
    """One-row SnapshotFields over a [1, image_words] image row: the same
    static-offset decode the reference path applies to the whole image."""
    return _rp.SnapshotFields(read_version=rv, **layout.field_views(row))


def _fused_kernel(cfg, routed_k: int, mode: str):
    """Build the megakernel body.  ``mode`` is "get" or "scan"; both share
    the descend + floor + forward-scan spine (GET is SCAN(K, K) plus the
    equality post-pass, exactly as in the reference path)."""
    layout = NodeImageLayout.for_config(cfg)
    IW = layout.image_words
    M = cfg.max_scan_items
    T = cfg.node_cap + cfg.log_cap
    KW, VW = cfg.key_words, cfg.val_words

    def kernel(scal_ref, lo_ref, lolen_ref, hi_ref, hilen_ref, clids_ref,
               cimg_ref, pt_ref, img_ref, *out_refs):
        rv = scal_ref[1]
        lo = lo_ref[...]                       # [1, KW]
        lolen = lolen_ref[...]                 # [1]
        hi = hi_ref[...]
        hilen = hilen_ref[...]
        clids = clids_ref[...]                 # [C]
        cimg = cimg_ref[...]                   # [C, IW] — the VMEM pin
        lane = pl.program_id(0)
        routed = (lane % 16) < routed_k
        z = jnp.zeros((1,), jnp.int32)
        rows1 = jnp.arange(1)

        def load_row(phys):                    # dynamic heap-image row load
            return pl.load(img_ref, (pl.ds(jnp.maximum(phys, 0), 1),
                                     slice(None)))

        def view1(row):
            return _row_view(layout, row, rv)

        def fetch_heap(lid):
            """pagetable lookup + MVCC old-version walk + row load — the
            slow pipe (only executed below the cached frontier or for
            lb-routed lanes, via lax.cond)."""
            p0 = pl.load(pt_ref, (pl.ds(jnp.maximum(lid, 0), 1),))[0]

            def step(_, p):
                v = view1(load_row(p))
                too_new = (v.version[0] > rv) & (v.oldptr[0] != NULL)
                return jnp.where(too_new, v.oldptr[0], p)

            p = jax.lax.fori_loop(0, cfg.max_version_chain, step,
                                  jnp.maximum(p0, 0))
            return load_row(p)

        # ---- descend: cache tier first, heap fall-through ----------------
        def level(_, state):
            lid, row, done, vh, hg, lr = state
            eq = clids == lid
            hit = eq.any() & (lid != NULL)
            slot = jnp.argmax(eq).astype(jnp.int32)
            use_cache = hit & ~routed

            def from_cache():
                return jax.lax.dynamic_slice(cimg, (slot, 0), (1, IW))

            new_row = jax.lax.cond(
                done, lambda: row,
                lambda: jax.lax.cond(use_cache, from_cache,
                                     lambda: fetch_heap(lid)))
            live = ~done
            vh = vh + (use_cache & live).astype(jnp.int32)
            hg = hg + (~use_cache & live).astype(jnp.int32)
            lr = lr + (hit & routed & live).astype(jnp.int32)
            v = view1(new_row)
            is_leaf = v.ntype[0] == LEAF
            seg = _rp._shortcut_floor(v, z, lo, lolen)
            idx = _rp._segment_floor(v, z, seg, lo, lolen, cfg)
            child = jnp.where(
                idx[0] >= 0,
                v.svals[0, jnp.maximum(idx[0], 0), 0].astype(jnp.int32),
                v.left_child[0])
            new_done = done | is_leaf
            new_lid = jnp.where(new_done, lid, child)
            return new_lid, new_row, new_done, vh, hg, lr

        zi = jnp.zeros((), jnp.int32)
        root = scal_ref[0]
        _, leaf_row, _, vh, hg, lr = jax.lax.fori_loop(
            0, cfg.max_height, level,
            (root, load_row(jnp.zeros((), jnp.int32)),
             jnp.zeros((), bool), zi, zi, zi))

        # ---- floor pre-pass: walk left until a visible key <= lo ---------
        def floor_step(_, state):
            row, fkeys, fklens, fvals, fvlens, have = state
            keys, klens, vals, vlens, live = _rp._resolve_leaf(
                view1(row), z, cfg)
            leq = live & (jax_key_cmp(keys, klens, lo[:, None, :],
                                      lolen[:, None]) <= 0)
            idx = jnp.where(leq, jnp.arange(T)[None, :], -1).max(axis=1)
            found = idx >= 0
            sel = jnp.maximum(idx, 0)
            upd = found & ~have
            fkeys = jnp.where(upd[:, None], keys[rows1, sel], fkeys)
            fklens = jnp.where(upd, klens[rows1, sel], fklens)
            fvals = jnp.where(upd[:, None], vals[rows1, sel], fvals)
            fvlens = jnp.where(upd, vlens[rows1, sel], fvlens)
            have = have | found
            nxt = view1(row).lsib[0]
            can_move = (~have[0]) & (nxt != NULL)
            new_row = jax.lax.cond(can_move, lambda: fetch_heap(nxt),
                                   lambda: row)
            return new_row, fkeys, fklens, fvals, fvlens, have

        _, fkeys, fklens, fvals, fvlens, have_floor = jax.lax.fori_loop(
            0, cfg.max_scan_leaves, floor_step,
            (leaf_row, jnp.zeros((1, KW), jnp.uint32), z,
             jnp.zeros((1, VW), jnp.uint32), z, jnp.zeros((1,), bool)))

        emit_floor = have_floor & (jax_key_cmp(fkeys, fklens, hi,
                                               hilen) <= 0)
        out_keys = jnp.zeros((1, M, KW), jnp.uint32) \
            .at[:, 0].set(jnp.where(emit_floor[:, None], fkeys, 0))
        out_klens = jnp.zeros((1, M), jnp.int32) \
            .at[:, 0].set(jnp.where(emit_floor, fklens, 0))
        out_vals = jnp.zeros((1, M, VW), jnp.uint32) \
            .at[:, 0].set(jnp.where(emit_floor[:, None], fvals, 0))
        out_vlens = jnp.zeros((1, M), jnp.int32) \
            .at[:, 0].set(jnp.where(emit_floor, fvlens, 0))
        count = emit_floor.astype(jnp.int32)

        # ---- forward scan across sibling leaves --------------------------
        def leaf_step(_, state):
            (row, out_keys, out_klens, out_vals, out_vlens, count, trunc,
             done) = state
            keys, klens, vals, vlens, live = _rp._resolve_leaf(
                view1(row), z, cfg)
            gt_lo = jax_key_cmp(keys, klens, lo[:, None, :],
                                lolen[:, None]) > 0
            leq_hi = jax_key_cmp(keys, klens, hi[:, None, :],
                                 hilen[:, None]) <= 0
            emit = live & gt_lo & leq_hi & ~done[:, None]
            local = jnp.cumsum(emit, axis=1) - 1
            slot = count[:, None] + local
            ok = emit & (slot < M)
            slot_c = jnp.where(ok, jnp.clip(slot, 0, M - 1), M)
            br = rows1[:, None]
            out_keys = out_keys.at[br, slot_c].set(keys, mode="drop")
            out_klens = out_klens.at[br, slot_c].set(klens, mode="drop")
            out_vals = out_vals.at[br, slot_c].set(vals, mode="drop")
            out_vlens = out_vlens.at[br, slot_c].set(vlens, mode="drop")
            count = count + ok.sum(axis=1)
            trunc = trunc | (emit & ~ok).any(axis=1)
            past_hi = (live & ~leq_hi).any(axis=1)
            nxt = view1(row).rsib[0]
            done = done | past_hi | (nxt == NULL) | trunc
            new_row = jax.lax.cond(done[0], lambda: row,
                                   lambda: fetch_heap(nxt))
            return (new_row, out_keys, out_klens, out_vals, out_vlens,
                    count, trunc, done)

        state = (leaf_row, out_keys, out_klens, out_vals, out_vlens, count,
                 jnp.zeros((1,), bool), jnp.zeros((1,), bool))
        (_, out_keys, out_klens, out_vals, out_vlens, count, trunc,
         done) = jax.lax.fori_loop(0, cfg.max_scan_leaves, leaf_step, state)
        trunc = trunc | ~done

        if mode == "scan":
            (count_ref, keys_ref, klens_ref, vals_ref, vlens_ref, trunc_ref,
             meters_ref) = out_refs
            count_ref[...] = count[:, None]
            keys_ref[...] = out_keys
            klens_ref[...] = out_klens
            vals_ref[...] = out_vals
            vlens_ref[...] = out_vlens
            trunc_ref[...] = trunc.astype(jnp.int32)[:, None]
        else:
            eq = (jax_key_cmp(out_keys, out_klens, lo[:, None, :],
                              lolen[:, None]) == 0) \
                & (jnp.arange(M)[None, :] < count[:, None])
            found = eq.any(axis=1)
            idx = jnp.argmax(eq, axis=1)
            found_ref, vals_ref, vlens_ref, meters_ref = out_refs
            found_ref[...] = found.astype(jnp.int32)[:, None]
            vals_ref[...] = out_vals[rows1, idx]
            vlens_ref[...] = out_vlens[rows1, idx][:, None]
        meters_ref[...] = jnp.stack([vh, hg, lr])[None, :]

    return kernel


def _common_specs(KW, C, IW):
    """in_specs shared by both megakernels: per-request key blocks, the
    cache tier resident in VMEM, page table + heap image in ANY (addressed
    dynamically by the body)."""
    return [
        pl.BlockSpec((1, KW), lambda i, s: (i, 0)),      # lo key
        pl.BlockSpec((1,), lambda i, s: (i,)),           # lo len
        pl.BlockSpec((1, KW), lambda i, s: (i, 0)),      # hi key
        pl.BlockSpec((1,), lambda i, s: (i,)),           # hi len
        pl.BlockSpec((C,), lambda i, s: (0,)),           # cache lids (VMEM)
        pl.BlockSpec((C, IW), lambda i, s: (0, 0)),      # cache image (VMEM)
        pl.BlockSpec(memory_space=pltpu.ANY),            # page table
        pl.BlockSpec(memory_space=pltpu.ANY),            # heap image
    ]


@functools.partial(jax.jit, static_argnames=("cfg", "lb_fraction",
                                             "interpret"))
def batched_scan_fused(image, pagetable, root_lid, read_version, cache_lids,
                       cache_image, lo, lolen, hi, hilen, *, cfg,
                       lb_fraction: float = 0.0, interpret: bool = False):
    """Fused SCAN(K_l, K_u): ONE dispatch for the whole batch.  Returns
    (ScanResult, meters i32[3]) matching ``ref.batched_scan_fused_ref``."""
    B = lo.shape[0]
    S, IW = image.shape
    C = cache_lids.shape[0]
    M, KW, VW = cfg.max_scan_items, cfg.key_words, cfg.val_words
    scal = jnp.stack([root_lid.astype(jnp.int32),
                      read_version.astype(jnp.int32)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=_common_specs(KW, C, IW),
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),       # count
            pl.BlockSpec((1, M, KW), lambda i, s: (i, 0, 0)),
            pl.BlockSpec((1, M), lambda i, s: (i, 0)),
            pl.BlockSpec((1, M, VW), lambda i, s: (i, 0, 0)),
            pl.BlockSpec((1, M), lambda i, s: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),       # truncated
            pl.BlockSpec((1, 3), lambda i, s: (i, 0)),       # meters
        ],
    )
    count, keys, klens, vals, vlens, trunc, meters = pl.pallas_call(
        _fused_kernel(cfg, int(round(lb_fraction * 16)), "scan"),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, M, KW), jnp.uint32),
            jax.ShapeDtypeStruct((B, M), jnp.int32),
            jax.ShapeDtypeStruct((B, M, VW), jnp.uint32),
            jax.ShapeDtypeStruct((B, M), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 3), jnp.int32),
        ],
        interpret=interpret,
    )(scal, lo, lolen, hi, hilen, cache_lids, cache_image, pagetable, image)
    res = _rp.ScanResult(count[:, 0], keys, klens, vals, vlens,
                         trunc[:, 0] != 0)
    return res, meters.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("cfg", "lb_fraction",
                                             "interpret"))
def batched_get_fused(image, pagetable, root_lid, read_version, cache_lids,
                      cache_image, key, klen, *, cfg,
                      lb_fraction: float = 0.0, interpret: bool = False):
    """Fused GET(K): ONE dispatch for the whole batch.  Returns
    (GetResult, meters i32[3]) matching ``ref.batched_get_fused_ref``."""
    B = key.shape[0]
    S, IW = image.shape
    C = cache_lids.shape[0]
    KW, VW = cfg.key_words, cfg.val_words
    scal = jnp.stack([root_lid.astype(jnp.int32),
                      read_version.astype(jnp.int32)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=_common_specs(KW, C, IW),
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),       # found
            pl.BlockSpec((1, VW), lambda i, s: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),       # vallen
            pl.BlockSpec((1, 3), lambda i, s: (i, 0)),       # meters
        ],
    )
    found, vals, vlens, meters = pl.pallas_call(
        _fused_kernel(cfg, int(round(lb_fraction * 16)), "get"),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, VW), jnp.uint32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 3), jnp.int32),
        ],
        interpret=interpret,
    )(scal, key, klen, key, klen, cache_lids, cache_image, pagetable, image)
    res = _rp.GetResult(found[:, 0] != 0, vals, vlens[:, 0])
    return res, meters.sum(axis=0)
