"""Pure-jnp oracles for every Pallas kernel (the reference the shape/dtype
sweeps assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import read_path as _rp
from repro.core.keys import jax_key_cmp
from repro.core.read_path import log_sort_positions


def key_search_ref(q, qlen, keys, klens, valid):
    """Floor search oracle: largest valid index with key <= query, else -1."""
    c = jax_key_cmp(keys, klens, q[:, None, :], qlen[:, None])
    leq = (c <= 0) & (valid != 0)
    n = keys.shape[1]
    return jnp.where(leq, jnp.arange(n)[None, :], -1).max(axis=1) \
        .astype(jnp.int32)


def key_search_image_ref(q, qlen, img, *, keys_off: int, lens_off: int,
                         count_off: int, n_keys: int, key_words: int):
    """Floor search over packed node images: decode the candidate block
    from each request's image row at the static layout offsets, then the
    plain floor-search oracle."""
    B = img.shape[0]
    keys = img[:, keys_off:keys_off + n_keys * key_words] \
        .reshape(B, n_keys, key_words)
    klens = img[:, lens_off:lens_off + n_keys].astype(jnp.int32)
    count = img[:, count_off].astype(jnp.int32)
    valid = (jnp.arange(n_keys)[None, :] < count[:, None]).astype(jnp.int32)
    return key_search_ref(q, qlen, keys, klens, valid)


def leaf_merge_ref(nitems, nlog, backptr, hints, *, node_cap: int,
                   log_cap: int):
    """Merged-emission permutation oracle (rank sort via argsort)."""
    B = nitems.shape[0]
    N, L = node_cap, log_cap
    T = N + L
    logpos = log_sort_positions(hints.astype(jnp.int32), nlog, L)
    rank_log = backptr * (L + 1) + logpos
    rank_sorted = jnp.arange(N)[None, :] * (L + 1) + L
    svalid = jnp.arange(N)[None, :] < nitems[:, None]
    lvalid = jnp.arange(L)[None, :] < nlog[:, None]
    imax = jnp.iinfo(jnp.int32).max
    rank = jnp.concatenate([
        jnp.where(svalid, rank_sorted, imax),
        jnp.where(lvalid, rank_log, imax)], axis=1)
    perm = jnp.argsort(rank, axis=1, stable=True).astype(jnp.int32)
    valid = jnp.concatenate([svalid, lvalid], axis=1).astype(jnp.int32)
    return perm, valid


def snapshot_delta_scatter_ref(dst, rows, upd):
    """Delta-sync row scatter oracle: dst[rows[i]] = upd[i].

    Duplicate rows must carry identical data (the store pads deltas with
    repeats), so application order is immaterial."""
    return dst.at[rows].set(upd)


def snapshot_image_scatter_ref(image, rows, upd):
    """Packed node-image row scatter oracle: image[rows[i]] = upd[i] — one
    whole node image per dirty row (same idempotent-duplicates contract)."""
    return image.at[rows].set(upd)


def snapshot_multi_scatter_ref(dsts, rows, upd):
    """Fused multi-field scatter oracle: one row-scatter per field, same
    contract as ``delta_scatter.snapshot_multi_scatter`` (the parity
    reference for the one-invocation-per-sync fused kernel)."""
    return tuple(d.at[rows].set(u) for d, u in zip(dsts, upd))


def log_replay_scatter_ref(image, rows, slots, entries, *, offs):
    """Log-replay scatter oracle: apply one epoch's marshalled log entries
    to a packed node image (the log-shipped replication feed).

    Entry ``i`` writes its key/value lanes, lengths, op code, backptr,
    hint and vdelta words into image row ``rows[i]`` at the static layout
    offsets in ``offs`` (a ``schema.LogReplayOffsets``), each per-slot
    field advanced by ``slots[i] * width``; ``nlog`` becomes each touched
    row's highest ``slots + 1`` (log appends are monotone per row within
    an epoch — the kernel's last in-order write — and padded duplicate
    entries repeat the same record, so order is immaterial)."""
    kw, vw = offs.key_words, offs.val_words
    S, IW = image.shape
    rows = rows.astype(jnp.int32)
    j = slots.astype(jnp.int32)
    flat = image.reshape(-1)
    base = rows * IW

    def col(off):                     # flat index of a width-1 slot field
        return base + off + j

    flat = flat.at[(base[:, None] + offs.log_keys + j[:, None] * kw
                    + jnp.arange(kw)[None, :]).reshape(-1)] \
        .set(entries[:, 0:kw].reshape(-1))
    flat = flat.at[col(offs.log_keylen)].set(entries[:, kw])
    flat = flat.at[(base[:, None] + offs.log_vals + j[:, None] * vw
                    + jnp.arange(vw)[None, :]).reshape(-1)] \
        .set(entries[:, kw + 1:kw + 1 + vw].reshape(-1))
    flat = flat.at[col(offs.log_vallen)].set(entries[:, kw + 1 + vw])
    flat = flat.at[col(offs.log_op)].set(entries[:, kw + vw + 2])
    flat = flat.at[col(offs.log_backptr)].set(entries[:, kw + vw + 3])
    flat = flat.at[col(offs.log_hint)].set(entries[:, kw + vw + 4])
    flat = flat.at[col(offs.log_vdelta)].set(entries[:, kw + vw + 5])
    img = flat.reshape(S, IW)
    # per-row final count: entries sharing a row all carry that row's max
    # slots+1, so the duplicate-index set below is order-free
    same_row = rows[:, None] == rows[None, :]
    final_nlog = jnp.where(same_row, (j + 1)[None, :], 0).max(axis=1)
    return img.at[rows, offs.nlog].set(final_nlog.astype(image.dtype))


def batched_scan_fused_ref(snap, lo, lolen, hi, hilen, *, cfg,
                           lb_fraction: float = 0.0):
    """Fused SCAN oracle: the whole traversal — cache-tiered descend, leaf
    resolve, log merge, version resolution — as ONE jnp expression over the
    snapshot's combined cache+heap image view.  Returns
    (ScanResult, meters i32[3] = [vmem_hits, heap_gathers, lb_routed]).

    Descend levels whose LID sits in the snapshot's cache tier resolve from
    the cache rows (no pagetable/MVCC walk); the scan engine itself is the
    reference implementation running on the combined view, so results are
    bit-identical to ``read_path.batched_scan`` by construction."""
    view = _rp.fused_view(snap, cfg)
    leaf0, meters = _rp.descend_fused(snap, view, lo, lolen, cfg,
                                      lb_fraction=lb_fraction)
    res = _rp.scan_from_leaf(view, leaf0, lo, lolen, hi, hilen, cfg)
    return res, meters


def batched_get_fused_ref(snap, key, klen, *, cfg,
                          lb_fraction: float = 0.0):
    """Fused GET oracle: fused SCAN(K, K) + the shared equality post-pass.
    Returns (GetResult, meters i32[3])."""
    res, meters = batched_scan_fused_ref(snap, key, klen, key, klen,
                                         cfg=cfg, lb_fraction=lb_fraction)
    return _rp.get_from_scan(res, key, klen), meters


def paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens,
                        start_pos=None, *, scale: float | None = None,
                        softcap: float = 0.0):
    """Gather-then-dense-attention oracle."""
    B, H, D = q.shape
    _, P, KVH, _ = k_pages.shape
    G = H // KVH
    PPS = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if start_pos is None:
        start_pos = jnp.zeros_like(seq_lens)
    k = k_pages[block_tables].reshape(B, PPS * P, KVH, D)
    v = v_pages[block_tables].reshape(B, PPS * P, KVH, D)
    qg = q.reshape(B, KVH, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(PPS * P)[None, :]
    mask = (pos < seq_lens[:, None]) & (pos >= start_pos[:, None])
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
