"""KSU: key search unit as a Pallas TPU kernel (paper Section 4.2, Fig. 6).

Floor search — largest key <= query — over a block of candidate keys per
request.  This one primitive implements both stages of the paper's interior
search: the shortcut-block search and the sorted-segment search (and the
leaf floor probe), exactly as the hardware KSU is reused across block types.

Hardware adaptation: the FPGA KSU streams variable-size keys through a
16-byte compare pipeline fed by barrel shifters.  The TPU-native equivalent
packs keys big-endian in uint32 lanes; a whole [block, n_keys] tile of
comparisons is one VPU op: compare all lanes, select the first differing
lane, tie-break on length.  The reduction to the floor index is a masked
max over key positions.

VMEM budget per grid step (defaults B_BLK=128, N=64, KW=8):
  queries 128*8*4 B = 4 KiB, keys 128*64*8*4 B = 1 MiB, lens 32 KiB
  => comfortably inside the ~16 MiB VMEM of a TPU core; B_BLK and the key
  block are the tunable BlockSpec knobs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_B = 128


def _cmp_leq(keys, klens, q, qlen):
    """sign(memcmp(keys, q)) <= 0 elementwise over [B, N] candidates."""
    neq = keys != q[:, None, :]
    any_neq = neq.any(axis=-1)
    first = jnp.argmax(neq, axis=-1)
    kv = jnp.take_along_axis(keys, first[..., None], axis=-1)[..., 0]
    qv = jnp.take_along_axis(
        jnp.broadcast_to(q[:, None, :], keys.shape), first[..., None],
        axis=-1)[..., 0]
    lane_lt = kv < qv
    len_leq = klens <= qlen[:, None]
    return jnp.where(any_neq, lane_lt, len_leq)


def _key_search_kernel(q_ref, qlen_ref, keys_ref, klens_ref, valid_ref,
                       out_ref):
    """One grid step: floor index for a block of requests."""
    q = q_ref[...]                 # [B_blk, KW] uint32
    qlen = qlen_ref[...]           # [B_blk]
    keys = keys_ref[...]           # [B_blk, N, KW] uint32
    klens = klens_ref[...]         # [B_blk, N]
    valid = valid_ref[...] != 0    # [B_blk, N]

    leq = _cmp_leq(keys, klens, q, qlen) & valid
    n = keys.shape[1]
    idx = jnp.where(leq, jax.lax.broadcasted_iota(jnp.int32, leq.shape, 1),
                    -1).max(axis=1)
    out_ref[...] = idx.astype(jnp.int32)


def _key_search_image_kernel(q_ref, qlen_ref, img_ref, out_ref, *,
                             keys_off: int, lens_off: int, count_off: int,
                             n_keys: int, key_words: int):
    """Floor search straight off PACKED node images: the candidate block
    (keys, lengths, live count) is sliced out of each request's
    [image_words] u32 row at STATIC layout offsets (core/schema.py) — the
    kernel walks the image, no host-side per-field gather feeds it."""
    q = q_ref[...]                 # [B_blk, KW] uint32
    qlen = qlen_ref[...]           # [B_blk]
    img = img_ref[...]             # [B_blk, IW] uint32 packed node images
    B = img.shape[0]
    keys = img[:, keys_off:keys_off + n_keys * key_words] \
        .reshape(B, n_keys, key_words)
    klens = img[:, lens_off:lens_off + n_keys].astype(jnp.int32)
    count = img[:, count_off].astype(jnp.int32)
    valid = jax.lax.broadcasted_iota(jnp.int32, (B, n_keys), 1) \
        < count[:, None]
    leq = _cmp_leq(keys, klens, q, qlen) & valid
    idx = jnp.where(leq, jax.lax.broadcasted_iota(jnp.int32, leq.shape, 1),
                    -1).max(axis=1)
    out_ref[...] = idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "keys_off", "lens_off", "count_off", "n_keys", "key_words", "block_b",
    "interpret"))
def key_search_image(q, qlen, node_img, *, keys_off: int, lens_off: int,
                     count_off: int, n_keys: int, key_words: int,
                     block_b: int = DEFAULT_BLOCK_B,
                     interpret: bool = False):
    """Floor search over a candidate block addressed INSIDE packed node
    images (cfg.layout="packed"; e.g. the shortcut block at the layout's
    sc_keys/sc_keylen/n_shortcuts offsets).

    q:        [B, KW] uint32 packed big-endian query keys
    qlen:     [B]     int32 byte lengths
    node_img: [B, IW] uint32 — one packed image row per request (the node
              each request is searching, gathered by physical slot)
    keys_off/lens_off/count_off: word offsets of the candidate keys, key
              lengths and live-candidate count within the image row
    n_keys/key_words: candidate block geometry (static)
    returns [B] int32 floor indices, -1 when no candidate <= query.
    """
    B, IW = node_img.shape
    if B % block_b != 0:
        pad = -B % block_b
        q = jnp.pad(q, ((0, pad), (0, 0)))
        qlen = jnp.pad(qlen, (0, pad))
        node_img = jnp.pad(node_img, ((0, pad), (0, 0)))
    Bp = q.shape[0]
    kern = functools.partial(
        _key_search_image_kernel, keys_off=keys_off, lens_off=lens_off,
        count_off=count_off, n_keys=n_keys, key_words=key_words)
    out = pl.pallas_call(
        kern,
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, q.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, IW), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.int32),
        interpret=interpret,
    )(q, qlen, node_img)
    return out[:B]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def key_search(q, qlen, keys, klens, valid, *, block_b: int = DEFAULT_BLOCK_B,
               interpret: bool = False):
    """Floor search: largest index i with valid[b,i] and keys[b,i] <= q[b].

    q:     [B, KW] uint32 packed big-endian query keys
    qlen:  [B]     int32 byte lengths
    keys:  [B, N, KW] uint32 candidate keys (shortcut block or segment)
    klens: [B, N]  int32
    valid: [B, N]  int32 (0/1)
    returns [B] int32 floor indices, -1 when no candidate <= query.
    """
    B, N, KW = keys.shape
    if B % block_b != 0:
        pad = -B % block_b
        q = jnp.pad(q, ((0, pad), (0, 0)))
        qlen = jnp.pad(qlen, (0, pad))
        keys = jnp.pad(keys, ((0, pad), (0, 0), (0, 0)))
        klens = jnp.pad(klens, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
    Bp = q.shape[0]
    grid = (Bp // block_b,)
    out = pl.pallas_call(
        _key_search_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, KW), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, N, KW), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, N), lambda i: (i, 0)),
            pl.BlockSpec((block_b, N), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.int32),
        interpret=interpret,
    )(q, qlen, keys, klens, valid)
    return out[:B]
