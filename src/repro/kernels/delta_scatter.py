"""Delta-sync row scatter as a Pallas TPU kernel (host->device snapshot
delta application, paper Sections 3-4).

One sync's dirty node rows arrive as a dense [D, W] update block plus a
prefetched [D] row-index vector; the kernel DMAs each update row over the
matching row of the resident [S, W] device array in place.  This is the
device half of the PCIe analogue: the host transfers O(dirty) bytes and the
on-device image is patched, never rebuilt.

The grid iterates over update rows; the row indices are scalar-prefetched so
the output BlockSpec can address row ``rows[i]`` before the body runs.  The
destination is aliased to the output (``input_output_aliases``), so
untouched rows keep their contents without any copy.

Three kernels, tracking the sync path's evolution toward the paper's
one-contiguous-DMA-per-node transfer:
  * ``snapshot_delta_scatter`` — one flattened field per call (the original
    correctness stub; scalar fields flatten to W=1 blocks, far below the
    128-lane tile).
  * ``snapshot_multi_scatter`` — ALL fields of a dirty row in ONE
    ``pallas_call``: each field is its own aliased operand/output pair and
    the grid body DMAs every field's row in the same iteration.  One kernel
    launch per sync, but still ~24 distinct row DMAs per dirty node (one
    per field operand).  This is what ``cfg.layout="legacy"`` dispatches.
  * ``snapshot_image_scatter`` — the packed-layout endgame
    (``cfg.layout="packed"``, the default): the snapshot is ONE
    ``[S, image_words]`` u32 image (core/schema.py), a dirty node's entire
    contents are one contiguous ``[image_words]`` row, and the scatter is
    a single row DMA per dirty node — bit-for-bit the paper's whole-node
    8 KB buffer transfer, with no per-field addressing anywhere on the
    device side.  The grid iterates over dirty rows with the row indices
    scalar-prefetched, so the output BlockSpec lands each update at
    ``rows[i]`` in the aliased resident image.

Shared caveat: duplicate rows must carry identical data (the store pads
deltas with repeats), which keeps the scatters order-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_row_kernel(rows_ref, upd_ref, dst_ref, out_ref):
    del rows_ref, dst_ref   # rows drive the out index map; dst is aliased
    out_ref[...] = upd_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def snapshot_delta_scatter(dst, rows, upd, *, interpret: bool = False):
    """dst[rows[i], :] = upd[i, :] for i in range(D), in place.

    dst:  [S, W] resident device array (flattened trailing dims)
    rows: [D] int32 target rows (repeats allowed with identical data)
    upd:  [D, W] replacement rows
    """
    D, W = upd.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(D,),
        in_specs=[
            pl.BlockSpec((1, W), lambda i, rows: (i, 0)),       # upd row
            pl.BlockSpec(memory_space=pltpu.ANY),               # dst (alias)
        ],
        out_specs=pl.BlockSpec((1, W), lambda i, rows: (rows[i], 0)),
    )
    return pl.pallas_call(
        _scatter_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={2: 0},   # dst (arg 2, after rows & upd) -> out
        interpret=interpret,
    )(rows, upd, dst)


def snapshot_image_scatter(image, rows, upd, *, interpret: bool = False):
    """image[rows[i], :] = upd[i, :] — ONE contiguous image-row DMA per
    dirty node (the packed layout's whole sync).

    image: [S, image_words] resident packed node images (u32)
    rows:  [D] int32 dirty physical slots (repeats carry identical data)
    upd:   [D, image_words] replacement node images

    The node image IS the transfer unit: every field of the node rides in
    this one row (static offsets, core/schema.py), so the sync needs no
    per-field operands — same aliased row-scatter machinery as
    ``snapshot_delta_scatter``, applied to whole node images.
    """
    return snapshot_delta_scatter(image, rows, upd, interpret=interpret)


def _log_replay_kernel(offs):
    """Kernel body for one log-replay step: entry ``i`` (a marshalled
    [1, EW] u32 record, see ``schema.pack_log_entries``) is written into
    image row ``rows[i]`` at the static layout offsets in ``offs``, each
    per-slot log field advanced by ``slots[i] * width``.  The image is
    aliased in ANY memory space and addressed with dynamic stores — only
    the entry's own words move, never a node row.  ``nlog`` is stored as
    ``slots[i] + 1``: the grid runs in order and log appends are monotone
    per row within an epoch, so the last write holds the row's final
    count (padded duplicate entries repeat the same record)."""
    kw, vw = offs.key_words, offs.val_words

    def kernel(rows_ref, slots_ref, entry_ref, img_ref, out_ref):
        del img_ref                      # aliased to out_ref
        i = pl.program_id(0)
        r = rows_ref[i]
        j = slots_ref[i]
        e = entry_ref[0, :]
        out_ref[r, pl.ds(offs.log_keys + j * kw, kw)] = e[0:kw]
        out_ref[r, offs.log_keylen + j] = e[kw]
        out_ref[r, pl.ds(offs.log_vals + j * vw, vw)] = e[kw + 1:kw + 1 + vw]
        out_ref[r, offs.log_vallen + j] = e[kw + 1 + vw]
        out_ref[r, offs.log_op + j] = e[kw + vw + 2]
        out_ref[r, offs.log_backptr + j] = e[kw + vw + 3]
        out_ref[r, offs.log_hint + j] = e[kw + vw + 4]
        out_ref[r, offs.log_vdelta + j] = e[kw + vw + 5]
        out_ref[r, offs.nlog] = (j + 1).astype(out_ref.dtype)
    return kernel


@functools.partial(jax.jit, static_argnames=("offs", "interpret"))
def log_replay_scatter(image, rows, slots, entries, *, offs,
                       interpret: bool = False):
    """Replay one epoch's marshalled log entries into a resident packed
    node image, in place (the log-shipped replication feed's device half).

    image:   [S, image_words] resident follower node images (u32)
    rows:    [D] int32 target physical slots (leaves that took appends)
    slots:   [D] int32 log slot index per entry (monotone per row;
             padded entries repeat the last record)
    entries: [D, log_entry_words] u32 marshalled records
    offs:    ``schema.LogReplayOffsets`` static layout constants

    Where the image-delta feed DMAs a whole ``image_words`` row per dirty
    node, this kernel moves only each entry's ~(key_words + val_words + 6)
    words — the device-side analogue of shipping the op wire stream
    instead of node buffers over the slow bus.
    """
    D = entries.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(D,),
        in_specs=[
            pl.BlockSpec((1, entries.shape[1]),
                         lambda i, rows, slots: (i, 0)),     # entry record
            pl.BlockSpec(memory_space=pltpu.ANY),            # image (alias)
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
    )
    return pl.pallas_call(
        _log_replay_kernel(offs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(image.shape, image.dtype),
        input_output_aliases={3: 0},   # image (after rows, slots, entries)
        interpret=interpret,
    )(rows, slots, entries, image)


def _multi_scatter_kernel(nf: int):
    """Kernel body for ``nf`` fused fields: refs arrive as
    (rows, upd_0..upd_{nf-1}, dst_0..dst_{nf-1}, out_0..out_{nf-1});
    every field's update row DMAs over its aliased output row."""
    def kernel(rows_ref, *refs):
        del rows_ref  # drives the out index maps; dsts are aliased
        upd = refs[:nf]
        out = refs[2 * nf:]
        for f in range(nf):
            out[f][...] = upd[f][...]
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def snapshot_multi_scatter(dsts, rows, upd, *, interpret: bool = False):
    """Fused dirty-row scatter: dsts[f][rows[i], :] = upd[f][i, :] for every
    field f, in ONE kernel invocation (the paper's whole-node DMA).

    dsts: sequence of [S, W_f] resident device arrays (trailing dims
          flattened by the caller; dtypes may differ per field)
    rows: [D] int32 target rows (repeats allowed with identical data)
    upd:  matching sequence of [D, W_f] replacement rows

    Returns the new field arrays in input order.  The grid iterates over
    update rows with ``rows`` scalar-prefetched; each destination is
    aliased to its output, so untouched rows keep their contents without
    any copy and the whole sync costs one kernel launch.
    """
    dsts, upd = tuple(dsts), tuple(upd)
    nf = len(dsts)
    D = upd[0].shape[0]

    def upd_spec(w):
        return pl.BlockSpec((1, w), lambda i, rows: (i, 0))

    def out_spec(w):
        return pl.BlockSpec((1, w), lambda i, rows: (rows[i], 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(D,),
        in_specs=[upd_spec(u.shape[1]) for u in upd]
        + [pl.BlockSpec(memory_space=pltpu.ANY)] * nf,
        out_specs=[out_spec(d.shape[1]) for d in dsts],
    )
    return pl.pallas_call(
        _multi_scatter_kernel(nf),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(d.shape, d.dtype) for d in dsts],
        # dst f is argument 1 + nf + f (after rows and the nf update blocks)
        input_output_aliases={1 + nf + f: f for f in range(nf)},
        interpret=interpret,
    )(rows, *upd, *dsts)
