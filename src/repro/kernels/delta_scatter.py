"""Delta-sync row scatter as a Pallas TPU kernel (host->device snapshot
delta application, paper Sections 3-4).

One sync's dirty node rows arrive as a dense [D, W] update block plus a
prefetched [D] row-index vector; the kernel DMAs each update row over the
matching row of the resident [S, W] device array in place.  This is the
device half of the PCIe analogue: the host transfers O(dirty) bytes and the
on-device image is patched, never rebuilt.

The grid iterates over update rows; the row indices are scalar-prefetched so
the output BlockSpec can address row ``rows[i]`` before the body runs.  The
destination is aliased to the output (``input_output_aliases``), so
untouched rows keep their contents without any copy.

Caveats (why ``ops.snapshot_delta_scatter`` defaults to the jnp ref off-TPU):
  * scalar per-row fields flatten to W=1 blocks, far below the 128-lane
    tile — fine for a correctness stub, wasteful on real hardware (a
    production kernel would fuse all fields of a row into one 8 KB DMA,
    exactly the paper's node-buffer transfer unit);
  * duplicate rows must carry identical data (the store pads deltas with
    repeats), which keeps the scatter order-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_row_kernel(rows_ref, upd_ref, dst_ref, out_ref):
    del rows_ref, dst_ref   # rows drive the out index map; dst is aliased
    out_ref[...] = upd_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def snapshot_delta_scatter(dst, rows, upd, *, interpret: bool = False):
    """dst[rows[i], :] = upd[i, :] for i in range(D), in place.

    dst:  [S, W] resident device array (flattened trailing dims)
    rows: [D] int32 target rows (repeats allowed with identical data)
    upd:  [D, W] replacement rows
    """
    D, W = upd.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(D,),
        in_specs=[
            pl.BlockSpec((1, W), lambda i, rows: (i, 0)),       # upd row
            pl.BlockSpec(memory_space=pltpu.ANY),               # dst (alias)
        ],
        out_specs=pl.BlockSpec((1, W), lambda i, rows: (rows[i], 0)),
    )
    return pl.pallas_call(
        _scatter_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={2: 0},   # dst (arg 2, after rows & upd) -> out
        interpret=interpret,
    )(rows, upd, dst)
