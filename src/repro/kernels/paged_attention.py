"""Paged decode attention over Honeycomb-indexed KV pages (Pallas TPU).

The serving integration point of the paper's technique (DESIGN.md Section 4):
the KV cache is paged; the ordered store maps (sequence, block) -> physical
page, decode gathers pages through that mapping.  This kernel consumes the
page indices exactly as the FPGA consumes LID->physical translations: the
block table is a *scalar-prefetch* operand, so the page gather is expressed
in the BlockSpec index_map and the DMA engine streams pages HBM->VMEM while
the MXU works on the previous page — the TPU equivalent of the paper's MSI
adapters overlapping memory reads with compute.

Grid: (batch, pages_per_seq); online-softmax accumulation in VMEM scratch
across the page dimension (initialized at page 0, emitted at the last page).
``start_pos`` masks positions below a per-sequence lower bound (sliding-
window layers); ``softcap`` applies gemma2-style logit capping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(block_tables_ref, seq_lens_ref, start_pos_ref,
                       q_ref, k_ref, v_ref, out_ref,
                       m_ref, l_ref, acc_ref,
                       *, page_size: int, n_pages: int, scale: float,
                       softcap: float):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # [KVH, G, D]
    k = k_ref[0]                       # [P, KVH, D]
    v = v_ref[0]                       # [P, KVH, D]

    seq_len = seq_lens_ref[b]
    start = start_pos_ref[b]
    pos = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (page_size,), 0)
    mask = (pos < seq_len) & (pos >= start)

    s = jnp.einsum("kgd,pkd->kgp", q.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask[None, None, :], s, NEG_INF)

    m_prev = m_ref[...]                # [KVH, G]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    probs = jnp.exp(s - m_new[..., None])
    probs = jnp.where(mask[None, None, :], probs, 0.0)
    l_new = l_prev * alpha + probs.sum(axis=-1)
    acc = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "kgp,pkd->kgd", probs, v.astype(jnp.float32),
        preferred_element_type=jnp.float32)

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(p == n_pages - 1)
    def _emit():
        out_ref[0] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)[..., None]
                      ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "softcap", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                    start_pos=None, *, scale: float | None = None,
                    softcap: float = 0.0, interpret: bool = False):
    """Decode attention over paged KV.

    q:            [B, H, D]            (one new token per sequence)
    k_pages:      [N_PAGES, P, KVH, D]
    v_pages:      [N_PAGES, P, KVH, D]
    block_tables: [B, PAGES_PER_SEQ] int32 — physical page per logical block
                  (produced by Honeycomb GETs on the page-table store)
    seq_lens:     [B] int32 — visible tokens (exclusive upper bound)
    start_pos:    [B] int32 — first visible position (sliding window)
    returns       [B, H, D]
    """
    B, H, D = q.shape
    _, P, KVH, _ = k_pages.shape
    G = H // KVH
    PPS = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if start_pos is None:
        start_pos = jnp.zeros_like(seq_lens)
    qg = q.reshape(B, KVH, G, D)

    grid = (B, PPS)
    out = pl.pallas_call(
        functools.partial(_paged_attn_kernel, page_size=P, n_pages=PPS,
                          scale=scale, softcap=softcap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, KVH, G, D),
                             lambda b, p, bt, sl, sp: (b, 0, 0, 0)),
                pl.BlockSpec((1, P, KVH, D),
                             lambda b, p, bt, sl, sp: (bt[b, p], 0, 0, 0)),
                pl.BlockSpec((1, P, KVH, D),
                             lambda b, p, bt, sl, sp: (bt[b, p], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, KVH, G, D),
                                   lambda b, p, bt, sl, sp: (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((KVH, G), jnp.float32),
                pltpu.VMEM((KVH, G), jnp.float32),
                pltpu.VMEM((KVH, G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), seq_lens.astype(jnp.int32),
      start_pos.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(B, H, D)
