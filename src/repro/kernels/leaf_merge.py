"""RSU: leaf-node scan unit as a Pallas TPU kernel (paper Section 4.3).

Computes the merged emission order of a leaf's sorted + log blocks without
key comparisons:

  1. order-hint shift-register sort of the log block (Figs. 7-8): one vector
     step per log entry, exactly the hardware's one-cycle-per-item insertion
     into a shift register, evaluated for a whole request block at once;
  2. merged ranks: log entries slot in right before the sorted item named by
     their back pointer, hint order breaking ties (Section 3.1);
  3. rank -> permutation via pairwise counting (out_pos[i] = #{j: rank[j] <
     rank[i]}), a [T, T] triangular compare — the TPU-native replacement for
     the FPGA's indirection shift register.

The kernel returns the permutation (source index per output position) and
its validity mask; value movement happens outside (XLA gathers — the MSI
adapters' job in the paper's architecture).

VMEM per grid step (B_BLK=128, N=64, L=16, T=80): ranks 128*80*4 = 40 KiB,
pairwise tile 128*80*80 bool ~ 800 KiB — within budget; B_BLK is the knob.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128
_I32_MAX = jnp.iinfo(jnp.int32).max


def _shift_register_sort(hints, nlog, L):
    """positions[b, j] = final slot of log entry j in ascending key order."""
    def insert(j, pos):
        placed = jax.lax.broadcasted_iota(jnp.int32, pos.shape, 1) < j
        active = placed & (j < nlog)[:, None]
        shift = active & (pos >= hints[:, j][:, None])
        pos = pos + shift.astype(pos.dtype)
        return pos.at[:, j].set(jnp.where(j < nlog, hints[:, j], pos[:, j]))
    return jax.lax.fori_loop(0, L, insert,
                             jnp.zeros(hints.shape, jnp.int32))


def _leaf_merge_kernel(nitems_ref, nlog_ref, backptr_ref, hint_ref,
                       perm_ref, valid_ref, *, N: int, L: int):
    nitems = nitems_ref[...]       # [B]
    nlog = nlog_ref[...]           # [B]
    backptr = backptr_ref[...]     # [B, L]
    hints = hint_ref[...]          # [B, L]
    B = nitems.shape[0]
    T = N + L

    logpos = _shift_register_sort(hints, nlog, L)          # [B, L]
    rank_log = backptr * (L + 1) + logpos                  # [B, L]
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (B, N), 1)
    rank_sorted = iota_n * (L + 1) + L
    svalid = iota_n < nitems[:, None]
    lvalid = jax.lax.broadcasted_iota(jnp.int32, (B, L), 1) < nlog[:, None]
    rank = jnp.concatenate([
        jnp.where(svalid, rank_sorted, _I32_MAX),
        jnp.where(lvalid, rank_log, _I32_MAX)], axis=1)    # [B, T]

    # permutation via pairwise counting: unique ranks for valid slots;
    # invalid slots share I32_MAX and are tie-broken by slot index
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (B, T), 1)
    lt = (rank[:, :, None] > rank[:, None, :]) | (
        (rank[:, :, None] == rank[:, None, :])
        & (iota_t[:, :, None] > iota_t[:, None, :]))
    out_pos = lt.sum(axis=2).astype(jnp.int32)             # [B, T]

    # invert: perm[b, p] = source index emitted at position p
    onehot = (out_pos[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (B, T, T), 2))
    perm = (onehot * iota_t[:, :, None]).sum(axis=1)
    perm_ref[...] = perm.astype(jnp.int32)
    valid_ref[...] = (jnp.concatenate([svalid, lvalid], axis=1)
                      .astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("node_cap", "log_cap", "block_b",
                                    "interpret"))
def leaf_merge(nitems, nlog, backptr, hints, *, node_cap: int, log_cap: int,
               block_b: int = DEFAULT_BLOCK_B, interpret: bool = False):
    """Merged emission permutation for a batch of leaves.

    nitems, nlog: [B] int32; backptr, hints: [B, L] int32.
    Returns (perm [B, T] int32, valid [B, T] int32) where T = N + L and
    perm[b, p] is the concatenated-slot index (sorted block then log block)
    emitted at merged position p; positions of invalid slots point at the
    padding tail.
    """
    B = nitems.shape[0]
    N, L = node_cap, log_cap
    if B % block_b != 0:
        pad = -B % block_b
        nitems = jnp.pad(nitems, (0, pad))
        nlog = jnp.pad(nlog, (0, pad))
        backptr = jnp.pad(backptr, ((0, pad), (0, 0)))
        hints = jnp.pad(hints, ((0, pad), (0, 0)))
    Bp = nitems.shape[0]
    T = N + L
    kernel = functools.partial(_leaf_merge_kernel, N=N, L=L)
    perm, valid = pl.pallas_call(
        kernel,
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, L), lambda i: (i, 0)),
            pl.BlockSpec((block_b, L), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, T), lambda i: (i, 0)),
            pl.BlockSpec((block_b, T), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, T), jnp.int32),
            jax.ShapeDtypeStruct((Bp, T), jnp.int32),
        ],
        interpret=interpret,
    )(nitems, nlog, backptr, hints)
    return perm[:B], valid[:B]
