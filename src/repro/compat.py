"""Version compatibility shims for JAX API drift.

The repo targets current JAX but must run on older installs (this container
ships 0.4.x): ``jax.shard_map``/``check_vma`` moved out of
``jax.experimental.shard_map``/``check_rep`` only in later releases, and
``jax.sharding.AxisType`` does not exist before the explicit-sharding work.
Each shim prefers the new API and degrades to the equivalent old one.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the old experimental fallback (where the
    replication-check kwarg is spelled ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
