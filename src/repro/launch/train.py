"""End-to-end training driver.

Smoke scale (this container):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50

Production scale: the same builder the dry-run compiles, on the real mesh
(remove --smoke on a TPU slice).  Checkpoint/restart and straggler handling
live in repro.train.train_loop.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from repro.configs import get_config, get_smoke_config
from repro.train.train_loop import LoopConfig, build_smoke_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, single device")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    loop = build_smoke_loop(
        cfg, batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
        loop_cfg=LoopConfig(total_steps=args.steps,
                            ckpt_every=max(args.steps // 2, 1),
                            log_every=max(args.steps // 10, 1)))
    if args.resume and loop.restore_latest():
        print(f"resumed from step {loop.step}")
    summary = loop.run()
    for m in loop.metrics_log:
        print(json.dumps(m))
    print("summary:", json.dumps(summary))
    loop.pipeline.close()


if __name__ == "__main__":
    main()
