"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import,
smoke tests must keep seeing one device).
"""
from __future__ import annotations

import jax

# jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist in
# newer JAX; on older installs a plain Mesh has the same Auto semantics.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    if _AXIS_TYPE is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2, 16, 16) = 512 chips across 2 pods.

    Axes: ``data`` carries DP/FSDP + sequence-parallel KV pages, ``model``
    carries TP/EP, ``pod`` is pure cross-pod data parallelism (gradient
    reduction hierarchy: reduce-scatter in-pod over ICI, all-reduce of the
    scattered shards across pods over DCN).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests use small CPU meshes, e.g. (2, 4))."""
    return _mesh(shape, axes)
