"""Roofline accounting from compiled SPMD artifacts.

``cost_analysis()`` (flops / bytes) is per-device after partitioning
(verified empirically: a 512-way sharded matmul reports total/512 flops).
Collective traffic is not in cost_analysis, so we parse the compiled HLO and
sum *operand* bytes of every collective op — shapes in the partitioned
module are already per-device.

Hardware model (TPU v5e, per brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  collective term = per-device collective bytes / link
bandwidth (each chip drives its links at the payload rate; ring all-reduce
moves 2x the shard but overlaps both directions — we report raw
payload/bandwidth and call out the model in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# post-optimization HLO prints operands without types, so we meter the
# RESULT type: `%x = f32[256,4096]{1,0} all-reduce(%y), ...` or a tuple for
# variadic/-start forms.  Result bytes == payload for all-reduce/permute,
# == received bytes for all-gather; reduce-scatter's wire bytes are ~result
# x group size (we report result bytes — a lower bound, stated in
# EXPERIMENTS.md).  `-done` ops are skipped (their start was counted).
_OP_RE = re.compile(
    r"=\s+(?P<type>\([^=]*?\)|\S+)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind result bytes (per device) + op counts."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue
        kind = m.group("kind")
        nb = sum(_shape_bytes(d, s)
                 for d, s in _SHAPE_RE.findall(m.group("type")))
        out[kind] += nb
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class Roofline:
    flops: float                # per device
    hbm_bytes: float            # per device
    coll_bytes: float           # per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float          # per device ("useful" flops)
    useful_ratio: float

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline(cost: dict, coll: dict, model_flops_per_device: float
             ) -> Roofline:
    if isinstance(cost, (list, tuple)):   # older jax: [{...}] per computation
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    cb = float(coll["total_bytes"])
    terms = {"compute": flops / PEAK_FLOPS,
             "memory": hbm / HBM_BW,
             "collective": cb / ICI_BW}
    dominant = max(terms, key=terms.get)
    return Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=cb,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], dominant=dominant,
        model_flops=model_flops_per_device,
        useful_ratio=(model_flops_per_device / flops) if flops else 0.0)


def model_flops_per_step(cfg, shape) -> float:
    """6*N*D train / 2*N*D forward, N = active params (global, whole step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch      # decode: one token/seq
