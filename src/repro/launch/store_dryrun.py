import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the paper's own workload at production scale: the Honeycomb
batched read path (GET/SCAN) compiled for the 16x16 mesh as a range-sharded
store service.

Deployment model — the LIVE ``ShardedHoneycombStore`` (core/router.py), at
mesh scale: the keyspace is range-sharded across all 256 chips — each chip
owns a complete Honeycomb tree for its range (~128M/256 = 500k items for the
paper's store) and serves its slice of the request batch; the router
(serving layer) pre-partitions requests by range, so the read path itself is
collective-free.  Expressed as a shard_map over (data, model) with per-shard
snapshots.

Two halves keep the abstract model honest:
  * the compile analysis sizes ONE shard's snapshot/delta with the same
    per-shard item count the router's uniform boundaries produce, and
    lowers the read path + delta application for the full mesh;
    ``pipeline_occupancy_model()`` lowers the two pipeline stages (standby
    delta scatter, batched read) separately and models the epoch pipeline
    of core/pipeline.py — serial epoch = export + dispatch, pipelined
    epoch = max(stage), with per-stage occupancy;
  * ``live_sharded_smoke()`` drives a small live ShardedHoneycombStore
    through the identical shape (range partition, per-shard delta sync
    plus one pipelined service epoch — typed op messages through
    ``HoneycombService``, core/api.py — with independent per-shard flips,
    cross-shard scan stitching) and reports per-shard sync traffic and
    router load imbalance — the measured twin of the modeled numbers;
    ``live_replicated_smoke()`` adds the replication axis (follower
    replicas fed by the log-shipped wire stream replayed on device —
    falling back to image-row deltas when the tree shape changed —
    round-robin read spreading, lag/amplification/feed meters,
    per-response replica/serving-version stamps — core/replica.py,
    core/api.py).

Usage: PYTHONPATH=src python -m repro.launch.store_dryrun
"""
import json
import math
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import (Get, HoneycombConfig, HoneycombService, Put,
                        ReplicationConfig, ShardedHoneycombStore,
                        TelemetryConfig, Update, uniform_int_boundaries)
from repro.core.keys import int_key
from repro.core.read_path import (SnapshotDelta, TreeSnapshot,
                                  apply_snapshot_delta, batched_get,
                                  batched_scan)
from repro.core.schema import NodeImageLayout
from repro.launch import hlo_analysis as hla
from repro.launch.mesh import make_production_mesh


def abstract_snapshot(cfg: HoneycombConfig, n_items: int, shards: int):
    """ShapeDtypeStructs for one shard's tree (paper store: 128M items,
    55% leaf occupancy, 8KB-equivalent nodes).  Shard sizing matches the
    live router's uniform range partition (n_items // shards items each);
    the snapshot is the PACKED node image (core/schema.py — one
    [S, image_words] u32 array, every field at a static word offset)."""
    items_per_shard = n_items // shards
    leaves = math.ceil(items_per_shard / (cfg.node_cap * 0.55))
    interior = math.ceil(leaves / (cfg.node_cap * 0.55)) + 8
    S = leaves + interior + 64          # physical slots incl. old versions
    layout = NodeImageLayout.for_config(cfg)
    sds = jax.ShapeDtypeStruct
    i32 = jnp.int32
    return TreeSnapshot(
        image=sds((S, layout.image_words), jnp.uint32),
        pagetable=sds((S,), i32),
        root_lid=sds((), i32),
        read_version=sds((), i32),
        cache_lids=sds((cfg.cache_slots,), i32),
        cache_image=sds((cfg.cache_slots, layout.image_words), jnp.uint32),
    ), S


def abstract_delta(cfg: HoneycombConfig, snap: TreeSnapshot, dirty_rows: int,
                   pt_commands: int) -> SnapshotDelta:
    """ShapeDtypeStructs for one shard's delta sync: D whole node-image
    rows (ONE contiguous DMA per dirty node) + P batched page-table
    commands + the two scalars."""
    sds = jax.ShapeDtypeStruct
    i32 = jnp.int32
    return SnapshotDelta(
        rows=sds((dirty_rows,), i32),
        image=sds((dirty_rows, snap.image.shape[1]), jnp.uint32),
        pt_lids=sds((pt_commands,), i32), pt_phys=sds((pt_commands,), i32),
        root_lid=sds((), i32), read_version=sds((), i32),
        cache_lids=(None if snap.cache_lids is None
                    else sds(snap.cache_lids.shape, i32)))


def delta_sync_analysis(cfg: HoneycombConfig, snap_abs: TreeSnapshot,
                        dirty_rows: int = 256,
                        pt_commands: int = 64) -> dict:
    """Compile the per-shard delta application and report the PCIe-analogue
    traffic: delta argument bytes vs the wholesale snapshot size."""
    delta_abs = abstract_delta(cfg, snap_abs, dirty_rows, pt_commands)
    lowered = jax.jit(apply_snapshot_delta).lower(snap_abs, delta_abs)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    size = lambda tree: sum(
        int(np.prod(a.shape)) * a.dtype.itemsize
        for a in jax.tree.leaves(tree))
    full_bytes = size(snap_abs)
    delta_bytes = size(delta_abs)
    return {
        "dirty_rows": dirty_rows, "pagetable_commands": pt_commands,
        "delta_bytes_per_sync": delta_bytes,
        "full_snapshot_bytes": full_bytes,
        "traffic_ratio": delta_bytes / full_bytes,
        "compiled_temp_gb": mem.temp_size_in_bytes / 2 ** 30,
    }


def pipeline_occupancy_model(cfg: HoneycombConfig, snap_abs: TreeSnapshot,
                             batch_per_shard: int = 512,
                             dirty_rows: int = 256,
                             pt_commands: int = 64) -> dict:
    """Compile model of the epoch pipeline (core/pipeline.py): lower ONE
    shard's two device stages — the standby delta scatter (export) and the
    batched GET (dispatch) — and derive what double-buffering buys.

    A serial epoch pays export + dispatch back-to-back (the sync barrier);
    a pipelined epoch pays max(export, dispatch) once the pipe fills,
    because shard A's reads execute while shard B's scatter drains.  Stage
    occupancy is each stage's share of the bottleneck stage."""
    delta_abs = abstract_delta(cfg, snap_abs, dirty_rows, pt_commands)
    no_coll = {"total_bytes": 0}
    c_exp = jax.jit(apply_snapshot_delta) \
        .lower(snap_abs, delta_abs).compile()
    export_rl = hla.roofline(c_exp.cost_analysis(), no_coll, 0.0)
    sds = jax.ShapeDtypeStruct
    keys = sds((batch_per_shard, cfg.key_words), jnp.uint32)
    lens = sds((batch_per_shard,), jnp.int32)
    c_get = jax.jit(batched_get, static_argnames="cfg") \
        .lower(snap_abs, keys, lens, cfg=cfg).compile()
    read_rl = hla.roofline(c_get.cost_analysis(), no_coll, 0.0)
    export_s = max(export_rl.compute_s, export_rl.memory_s)
    read_s = max(read_rl.compute_s, read_rl.memory_s)
    serial_s = export_s + read_s
    pipelined_s = max(export_s, read_s)
    bottleneck = pipelined_s or 1e-30
    return {
        "dirty_rows": dirty_rows, "batch_per_shard": batch_per_shard,
        "export_stage_s": export_s, "read_stage_s": read_s,
        "serial_epoch_s": serial_s, "pipelined_epoch_s": pipelined_s,
        "pipeline_speedup": serial_s / bottleneck,
        "stage_occupancy": {"export": export_s / bottleneck,
                            "read": read_s / bottleneck},
        "bottleneck_stage": "export" if export_s >= read_s else "read",
    }


def _telemetry_report(svc: HoneycombService) -> dict:
    """The smoke's observability artifact (core/telemetry.py): the full
    registry snapshot, the Prometheus exposition (verify.sh parses it and
    asserts key meters), the Chrome trace-event JSON (written next to the
    results by ``main`` — Perfetto-loadable), and the last sampled
    trace's span chain + stamps for the lifecycle assertions."""
    traces = svc.traces()
    last = traces[-1] if traces else None
    return {
        "snapshot": svc.metrics_snapshot(),
        "prometheus": svc.prometheus(),
        "chrome_trace": svc.chrome_trace(),
        "sampled_traces": len(traces),
        "last_trace": ({"kind": last.kind, "spans": last.span_names(),
                        "tags": last.tags} if last else None),
    }


def live_sharded_smoke(shards: int = 4, n_items: int = 1024,
                       batch: int = 64) -> dict:
    """Drive a small LIVE ShardedHoneycombStore through the dry-run's
    deployment shape: uniform range partition, per-shard resident snapshots
    and delta syncs, router-split GET batches, cross-shard SCAN stitching.
    Returns the measured per-shard sync traffic and load imbalance that the
    mesh-scale compile analysis only models."""
    cfg = HoneycombConfig()
    st = ShardedHoneycombStore(
        cfg, heap_capacity=1024, shards=shards,
        boundaries=uniform_int_boundaries(n_items, shards))
    rng = np.random.default_rng(11)
    for i in rng.permutation(n_items):
        st.put(int_key(int(i)), b"v" * 12)
    st.export_snapshot()                     # resident snapshot per shard
    # router-split GET batch + one scan spanning every shard
    keys = [int_key(int(k)) for k in rng.integers(0, n_items, batch)]
    st.get_batch(keys)
    span = st.scan_batch([(int_key(1), int_key(n_items - 2))])[0]
    # write burst confined to one shard -> exactly one delta sync
    snaps0 = [s.snapshots for s in st.per_shard_sync_stats]
    lo_shard = n_items // shards
    for k in range(batch):
        st.update(int_key(k % lo_shard), b"u" * 12)
    st.export_snapshot()
    dirty = [s.snapshots - b for s, b in zip(st.per_shard_sync_stats, snaps0)]
    # one pipelined service epoch (typed op messages, routing self-wired
    # from the store — core/api.py): staged standby scatters + independent
    # per-shard flips + immediate read dispatch (measured twin of
    # pipeline_occupancy_model)
    svc = HoneycombService(
        st, batch_size=batch, pipeline="pipelined",
        telemetry=TelemetryConfig(trace_sample_rate=0.25))
    svc.submit_many(
        op for k in range(batch)
        for op in (Update(int_key(int(rng.integers(0, n_items))), b"p" * 12),
                   Get(int_key(int(rng.integers(0, n_items))))))
    svc.drain()
    # fused read-path invariants: the default backend served through the
    # megakernels with the cache tier resolving levels from VMEM, and is
    # result-identical to the reference path (a cache-less snapshot is the
    # documented reference fallback — same shard, same dispatch machinery)
    span_per_shard = n_items // shards
    for i, sh in enumerate(st.shards):
        pk = [int_key(int(k)) for k in
              rng.integers(i * span_per_shard, (i + 1) * span_per_shard, 16)]
        snap = sh._snapshot_for_read()
        assert sh._device_get(snap, pk) == \
            sh._device_get(snap._replace(cache_image=None), pk), \
            f"fused GET diverged from reference on shard {i}"
        pr = [(pk[0], pk[1])]
        assert sh._device_scan(snap, pr, None) == \
            sh._device_scan(snap._replace(cache_image=None), pr, None), \
            f"fused SCAN diverged from reference on shard {i}"
    vmem_hits = sum(sh.cache.stats.vmem_hits for sh in st.shards)
    heap_gathers = sum(sh.cache.stats.heap_gathers for sh in st.shards)
    assert vmem_hits > 0, "cache tier never served a descend level"
    agg = st.sync_stats
    ps = st.pipeline_stats
    return {
        "shards": shards, "items": n_items, "layout": cfg.layout,
        "cross_shard_scan_items": len(span),
        "image_dma_count": agg.image_dma_count,
        "image_bytes": agg.image_bytes,
        "per_shard_bytes_synced": [s.bytes_synced
                                   for s in st.per_shard_sync_stats],
        "per_shard_delta_syncs": [s.delta_syncs
                                  for s in st.per_shard_sync_stats],
        "dirty_shard_syncs_after_confined_burst": dirty,
        "log_wire_bytes": agg.log_wire_bytes,
        "load_imbalance": st.load_imbalance,
        "read_path": {
            "backend": cfg.read_backend,
            "vmem_hits": vmem_hits,
            "heap_gathers": heap_gathers,
            "fused_matches_reference": True,     # asserted above
        },
        "pipelined_epoch": {
            "per_shard_epochs": st.per_shard_epochs,
            "staged_exports": ps.staged_exports, "flips": ps.flips,
            "sync_stall_s": svc.stats.sync_stall_s,
            "lane_occupancy": svc.stats.lane_occupancy,
        },
        "telemetry": _telemetry_report(svc),
    }


def live_replicated_smoke(shards: int = 2, replicas: int = 2,
                          n_items: int = 512, batch: int = 64) -> dict:
    """The replication twin of ``live_sharded_smoke``: each shard serves
    from a primary plus follower replicas fed by the primary's log-shipped
    op wire stream, replayed on device by the log_replay_scatter kernel
    (core/replica.py; tree-shape-changing epochs fall back to the image
    delta), with round-robin read spreading through the scheduler's
    (shard, replica, kind, cost) buckets.  Reports per-replica served
    lanes, the feed amplification bytes (with the primary-egress /
    relay-hop split and fallback-epoch count) and the epoch-lag freshness
    meters the mesh-scale model treats as free."""
    cfg = HoneycombConfig()
    st = ShardedHoneycombStore(
        cfg, heap_capacity=1024, shards=shards,
        boundaries=uniform_int_boundaries(n_items, shards),
        replication=ReplicationConfig(replicas=replicas,
                                      policy="round_robin"))
    rng = np.random.default_rng(13)
    for i in rng.permutation(n_items):
        st.put(int_key(int(i)), b"v" * 12)
    st.export_snapshot()                 # primaries + followers resident
    svc = HoneycombService(
        st, batch_size=batch // 2, pipeline="pipelined",
        telemetry=TelemetryConfig(trace_sample_rate=0.25))
    tickets = svc.submit_many(
        op for k in range(batch)
        for op in (Update(int_key(int(rng.integers(0, n_items))), b"r" * 12),
                   Get(int_key(int(rng.integers(0, n_items)))),
                   Get(int_key(int(rng.integers(0, n_items))))))
    svc.drain()
    reads = [t.result() for t in tickets if not t.op.IS_WRITE]
    # settle bursts: an epoch whose updates overflow a leaf log merges the
    # leaf (pending page-table command -> metered fallback to the image
    # delta); the next burst appends into the freshly merged leaves, so
    # within a few rounds an epoch MUST ship over the log feed — a silent
    # regression to delta-only would break the log-shipping claim
    burst = [int_key(0), int_key(n_items - 1)]      # one leaf per shard
    for _ in range(4):
        if st.feed_stats.log_feed_epochs > 0:
            break
        for k in burst * 3:
            st.update(k, b"l" * 12)
        st.export_snapshot()
    fs = st.feed_stats
    assert fs.log_feed_epochs > 0, "log feed never engaged"
    assert fs.log_bytes > 0 and fs.wire_bytes > 0
    log_replays = sum(f.sync_stats.log_replays
                      for sh in st.shards for f in sh.followers)
    assert log_replays > 0, "no follower replayed a log payload on device"
    # followers inherit the cache tier through the feeds (delta applies
    # re-attach it with cfg; log replays rebuild it from the replayed
    # image) and their fused reads match the reference fallback
    vmem_hits = 0
    for sh in st.shards:
        for f in sh.followers:
            snap = f.snapshot
            assert snap is not None and snap.cache_image is not None, \
                "follower lost the cache tier over the feed"
            pk = [int_key(int(k)) for k in rng.integers(0, n_items, 8)]
            got = sh.primary._device_get(snap, pk)
            ref = sh.primary._device_get(snap._replace(cache_image=None), pk)
            assert got == ref, "follower fused GET diverged from reference"
        vmem_hits += sh.cache.stats.vmem_hits
    assert vmem_hits > 0, "cache tier never served a descend level"
    return {
        "shards": shards, "replicas": replicas, "items": n_items,
        "layout": cfg.layout,
        "primary_image_dmas": st.sync_stats.image_dma_count,
        "served_replica_lanes": sorted({r.replica for r in reads}),
        "serving_versions": sorted({r.serving_version for r in reads}),
        "per_shard_replica_ops": st.per_shard_replica_ops,
        "replica_load_imbalance": st.replica_load_imbalance,
        "replication_bytes": st.replication_bytes,
        "feed": {
            "feed_bytes": fs.feed_bytes,
            "log_feed_epochs": fs.log_feed_epochs,
            "log_fallback_epochs": fs.log_fallback_epochs,
            "log_bytes": fs.log_bytes,
            "wire_bytes": fs.wire_bytes,
            "fallback_bytes": fs.fallback_bytes,
            "primary_egress_bytes": fs.primary_egress_bytes,
            "relay_hop_bytes": fs.relay_hop_bytes,
            "log_replays": log_replays,
        },
        "primary_sync_bytes": st.sync_stats.bytes_synced,
        "read_path": {
            "backend": cfg.read_backend,
            "vmem_hits": vmem_hits,
            "followers_cache_resident": True,    # asserted above
            "fused_matches_reference": True,     # asserted above
        },
        "replica_lag_epochs": st.replica_lag_epochs,
        "replica_staleness": st.replica_staleness,
        "lagging_skips": st.lagging_skips,
        "telemetry": _telemetry_report(svc),
    }


def main(batch_per_shard: int = 512, n_items: int = 128_000_000):
    cfg = HoneycombConfig()   # paper geometry: 64-cap nodes, 8 shortcuts
    mesh = make_production_mesh(multi_pod=False)
    shards = mesh.devices.size
    snap_abs, S = abstract_snapshot(cfg, n_items, shards)

    B = batch_per_shard * shards
    sds = jax.ShapeDtypeStruct
    keys = sds((B, cfg.key_words), jnp.uint32)
    lens = sds((B,), jnp.int32)

    def service(snap, lo, lolen, hi, hilen):
        """One shard: its own tree, its slice of the request batch."""
        res = batched_scan(snap, lo, lolen, hi, hilen, cfg)
        get = batched_get(snap, lo, lolen, cfg)
        return res.count, res.vals, get.found

    # every chip holds a DIFFERENT shard's tree: logically the snapshot is
    # a [shards, ...] stack sharded one-per-chip; requests shard likewise
    stacked = jax.tree.map(
        lambda a: sds((shards, *a.shape), a.dtype), snap_abs)
    spec_tree = jax.tree.map(lambda a: P(("data", "model")), snap_abs)

    def svc(snap_stk, lo, lolen, hi, hilen):
        body = lambda s, a, b, c, d: service(
            jax.tree.map(lambda x: x[0], s), a, b, c, d)
        return shard_map(
            body, mesh=mesh,
            in_specs=(spec_tree, P(("data", "model")), P(("data", "model")),
                      P(("data", "model")), P(("data", "model"))),
            out_specs=(P(("data", "model")), P(("data", "model")),
                       P(("data", "model"))),
            check_vma=False)(snap_stk, lo, lolen, hi, hilen)

    with mesh:
        lowered = jax.jit(svc).lower(stacked, keys, lens, keys, lens)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = hla.collective_bytes(compiled.as_text())

    rl = hla.roofline(cost, coll, model_flops_per_device=0.0)
    out = {
        "workload": f"honeycomb GET+SCAN, {n_items/1e6:.0f}M items "
                    f"range-sharded over {shards} chips, "
                    f"{batch_per_shard} requests/chip",
        "slots_per_shard": S,
        "peak_gb_per_chip": (mem.argument_size_in_bytes
                             + mem.output_size_in_bytes
                             + mem.temp_size_in_bytes
                             - mem.alias_size_in_bytes) / 2 ** 30,
        "compute_s": rl.compute_s, "memory_s": rl.memory_s,
        "collective_s": rl.collective_s,
        "collective_bytes": coll["total_bytes"],
        "reads_per_s_per_chip_bound": (
            batch_per_shard / max(rl.memory_s, rl.compute_s, 1e-12)),
        "delta_sync": delta_sync_analysis(cfg, snap_abs),
        "pipeline": pipeline_occupancy_model(cfg, snap_abs, batch_per_shard),
        "live_sharded_store": live_sharded_smoke(),
        "live_replicated_store": live_replicated_smoke(),
    }
    # the observability artifacts land NEXT TO the results (CI uploads
    # them): one registry metrics snapshot per live smoke, plus the
    # replicated smoke's sampled lifecycle traces as a Perfetto-loadable
    # Chrome trace-event file.  The bulky exports are popped out of the
    # main results JSON; the parsed/asserted surfaces stay inline.
    exp = Path("experiments")
    exp.mkdir(exist_ok=True)
    metrics = {k: out[k]["telemetry"]["snapshot"]
               for k in ("live_sharded_store", "live_replicated_store")}
    (exp / "store_dryrun_metrics.json").write_text(
        json.dumps(metrics, indent=1))
    trace = out["live_replicated_store"]["telemetry"].pop("chrome_trace")
    out["live_sharded_store"]["telemetry"].pop("chrome_trace")
    (exp / "store_dryrun_trace.json").write_text(json.dumps(trace))
    print(json.dumps(out, indent=1))
    (exp / "store_dryrun.json").write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
