"""End-to-end serving driver (continuous batching + Honeycomb paged KV).

Smoke scale:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 8
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.telemetry import CLOCK
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    eng = ServingEngine(cfg, batch_size=args.batch, max_seq=256,
                        page_size=16)
    rng = np.random.default_rng(0)
    t0 = CLOCK()
    for _ in range(args.requests):
        eng.submit(rng.integers(1, cfg.vocab, (args.prompt_len,)),
                   max_new_tokens=args.new_tokens)
    outs = eng.run_until_done()
    dt = CLOCK() - t0
    print(f"served {len(outs)} requests, {eng.stats['tokens']} tokens "
          f"in {dt:.2f}s ({eng.stats['tokens'] / dt:.1f} tok/s)")
    print(f"stats: {eng.stats}; honeycomb page-table "
          f"puts={eng.kv.table.stats.puts} "
          f"deletes={eng.kv.table.stats.deletes} "
          f"merges={eng.kv.table.stats.merges}")
    for rid, toks in list(outs.items())[:3]:
        print(f"  rid {rid}: {toks}")


if __name__ == "__main__":
    main()
