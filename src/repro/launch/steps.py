"""Step builders + abstract input specs for every (arch x shape) cell.

``build_step`` returns (fn, in_shardings, out_shardings, abstract_args,
donate) ready for ``jax.jit(...).lower(*abstract_args)`` — the single entry
point shared by the dry-run, the roofline harness and the real drivers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.paged_attention import paged_attention_local
from repro.distributed.sharding import (ShardingPolicy, batch_shardings,
                                        make_rules, make_shard_fn)
from repro.models import moe as me
from repro.models import schema as sc
from repro.models import transformer as tf
from repro.models.config import ArchConfig, ShapeConfig
from repro.train import optimizer as opt


@dataclasses.dataclass
class BuiltStep:
    fn: Any
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple


def _ns(mesh, *axes):
    return NamedSharding(mesh, P(*axes))


# ------------------------------------------------------------- input specs
def train_inputs(cfg: ArchConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for one global training batch."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch: dict[str, Any] = {"labels": sds((B, S), jnp.int32)}
    if cfg.embeds_in:
        batch["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = sds((B, S), jnp.int32)
    if cfg.n_enc_layers:
        batch["enc_embeds"] = sds((B, S // cfg.enc_seq_divisor, cfg.d_model),
                                  jnp.bfloat16)
    return batch


def decode_cache_abstract(cfg: ArchConfig, shape: ShapeConfig):
    B, S, P_ = shape.global_batch, shape.seq_len, shape.page_size
    pps = S // P_
    layer_tree = sc.abstract(
        sc.stack(cfg.n_superblocks,
                 tf.layer_cache_schema(cfg, B, pps, P_)))
    sds = jax.ShapeDtypeStruct
    return tf.DecodeCache(layers=layer_tree,
                          block_tables=sds((B, pps), jnp.int32),
                          seq_lens=sds((B,), jnp.int32))


def decode_cache_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                           rules: dict):
    B, S, P_ = shape.global_batch, shape.seq_len, shape.page_size
    pps = S // P_
    layer_specs = sc.shardings(
        sc.stack(cfg.n_superblocks, tf.layer_cache_schema(cfg, B, pps, P_)),
        rules, mesh)
    b = rules.get("batch")
    return tf.DecodeCache(layers=layer_specs,
                          block_tables=_ns(mesh, b, None),
                          seq_lens=_ns(mesh, b))


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """All abstract model inputs for an (arch x shape) cell — the dry-run's
    ShapeDtypeStruct stand-ins (no allocation)."""
    if shape.kind == "train":
        return {"batch": train_inputs(cfg, shape)}
    if shape.kind == "prefill":
        b = train_inputs(cfg, shape)
        b.pop("labels")
        return {"batch": b}
    sds = jax.ShapeDtypeStruct
    B = shape.global_batch
    spec = {"tokens": sds((B, 1), jnp.int32),
            "cache": decode_cache_abstract(cfg, shape)}
    if cfg.n_enc_layers:
        spec["enc_out"] = sds((B, shape.seq_len // cfg.enc_seq_divisor // 16,
                               cfg.d_model), jnp.bfloat16)
    return spec


# -------------------------------------------------------------- step build
def build_step(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               policy: ShardingPolicy = ShardingPolicy(),
               moe_impl: str = "dense",
               opt_cfg: opt.AdamWConfig = opt.AdamWConfig(),
               attn_backend: str | None = "ref",
               unroll: bool = False,
               grad_accum: int = 4) -> BuiltStep:
    rules = make_rules(cfg, mesh, shape, policy)
    shard = make_shard_fn(mesh, rules)
    params_abs = sc.abstract(tf.schema(cfg))
    params_sh = sc.shardings(tf.schema(cfg), rules, mesh)
    dp_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    n_data = 1
    for a in dp_axes:
        n_data *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]

    # §Perf variants: shard_map EP MoE / locality-preserving paged decode
    if moe_impl == "ep_ragged":
        assert rules["expert"] == "model", \
            "ep_ragged needs --policy ep and E %% model == 0"
        moe_impl = functools.partial(me.moe_ep_ragged, mesh=mesh,
                                     dp_axes=dp_axes)
    elif moe_impl == "fsliced":
        moe_impl = functools.partial(me.moe_fsliced_ragged, mesh=mesh,
                                     dp_axes=dp_axes)
    attn_local = None
    if policy.decode_impl == "local" and shape.kind == "decode" \
            and shape.global_batch % n_data == 0:
        attn_local = functools.partial(
            paged_attention_local, mesh=mesh, batch_axes=dp_axes,
            kv_head_axis=rules["kv_heads"], head_dim_axis=rules["head_dim"],
            page_size=shape.page_size)

    if shape.kind == "train":
        batch_abs = train_inputs(cfg, shape)
        batch_sh = batch_shardings(cfg, mesh, rules, batch_abs)
        opt_abs = opt.abstract_state(params_abs)
        opt_sh = opt.OptState(step=_ns(mesh), mu=params_sh, nu=params_sh)

        accum = grad_accum if shape.global_batch % grad_accum == 0 else 1

        def train_step(params, opt_state, batch):
            # microbatched gradient accumulation: activation memory scales
            # with B/accum while FSDP weight gathers amortize across the
            # inner scan (compute/comm overlap at the schedule level)
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum,
                                    *x.shape[1:]), batch)

            def one(carry, mb):
                gsum = carry
                loss, grads = jax.value_and_grad(tf.lm_loss)(
                    params, cfg, mb, moe_impl=moe_impl, shard=shard,
                    unroll=unroll)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return gsum, loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, losses = jax.lax.scan(one, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            new_params, new_opt, gnorm = opt.update(
                opt_cfg, grads, opt_state, params)
            return new_params, new_opt, {"loss": losses.mean(),
                                         "gnorm": gnorm}

        scalars = {"loss": _ns(mesh), "gnorm": _ns(mesh)}
        return BuiltStep(
            fn=train_step,
            abstract_args=(params_abs, opt_abs, batch_abs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, scalars),
            donate_argnums=(0, 1))

    if shape.kind == "prefill":
        batch_abs = input_specs(cfg, shape)["batch"]
        batch_sh = batch_shardings(cfg, mesh, rules, batch_abs)
        cache_sh = decode_cache_shardings(cfg, shape, mesh, rules)
        b = rules.get("batch")
        vshard = rules.get("vocab")

        def prefill_step(params, batch):
            enc_out = None
            if cfg.n_enc_layers:
                enc_out = tf.encode(params, cfg, batch["enc_embeds"],
                                    shard=shard, unroll=unroll)
            logits, cache = tf.prefill(
                params, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), enc_out=enc_out,
                page_size=shape.page_size, moe_impl=moe_impl, shard=shard,
                unroll=unroll)
            return logits, cache

        return BuiltStep(
            fn=prefill_step,
            abstract_args=(params_abs, batch_abs),
            in_shardings=(params_sh, batch_sh),
            out_shardings=(_ns(mesh, b, vshard), cache_sh),
            donate_argnums=())

    # ---- decode ----------------------------------------------------------
    specs = input_specs(cfg, shape)
    cache_sh = decode_cache_shardings(cfg, shape, mesh, rules)
    b = rules.get("batch")
    vshard = rules.get("vocab")
    has_enc = cfg.n_enc_layers > 0

    def decode_step(params, cache, tokens, enc_out=None):
        return tf.decode_step(params, cfg, cache, tokens,
                              page_size=shape.page_size, enc_out=enc_out,
                              attn_backend=attn_backend, shard=shard,
                              unroll=unroll, attn_local_impl=attn_local)

    args = (params_abs, specs["cache"], specs["tokens"])
    shards = (params_sh, cache_sh, _ns(mesh, b, None))
    if has_enc:
        args = args + (specs["enc_out"],)
        shards = shards + (_ns(mesh, b, None, None),)
    return BuiltStep(
        fn=decode_step,
        abstract_args=args,
        in_shardings=shards,
        out_shardings=(_ns(mesh, b, vshard), cache_sh),
        donate_argnums=(1,))
