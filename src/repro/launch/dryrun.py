import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh with ShapeDtypeStruct inputs (no allocation), record memory
analysis, cost analysis and the collective schedule, and derive the roofline
terms.

The two lines above MUST stay the very first statements: jax locks the
device count at first initialization, and the 512 placeholder CPU devices
exist only for this process (smoke tests and benchmarks see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k --mesh single
  ... --policy ep --moe-impl ragged    # hillclimb variants
"""
import argparse
import dataclasses
import json
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, ALIASES, get_config
from repro.core.telemetry import CLOCK
from repro.distributed.sharding import ShardingPolicy
from repro.launch import hlo_analysis as hla
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.models.config import LM_SHAPES, long_context_ok, shape_by_name

DEFAULT_OUT = Path("experiments/dryrun.json")


def _compile(cfg, shape, mesh, policy, moe_impl, unroll=False,
             grad_accum=4):
    built = build_step(cfg, shape, mesh, policy=policy, moe_impl=moe_impl,
                       unroll=unroll, grad_accum=grad_accum)
    with mesh:
        lowered = jax.jit(
            built.fn,
            in_shardings=built.in_shardings,
            out_shardings=built.out_shardings,
            donate_argnums=built.donate_argnums,
        ).lower(*built.abstract_args)
        compiled = lowered.compile()
        return (compiled.memory_analysis(), compiled.cost_analysis(),
                compiled.as_text())


def _reduced(cfg, t: int):
    """cfg with t superblocks (and proportional encoder depth) — used to
    extrapolate per-layer costs, since XLA's cost analysis visits a while
    (scan) body once instead of multiplying by the trip count."""
    plen = len(cfg.pattern)
    enc = (cfg.n_enc_layers * t) // cfg.n_superblocks \
        if cfg.n_enc_layers else 0
    return dataclasses.replace(cfg, n_layers=plen * t, n_enc_layers=enc)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy: ShardingPolicy, moe_impl: str,
             grad_accum: int = 4) -> dict:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = CLOCK()    # monotonic: compile_s is an interval
    # full-depth compile: the dry-run proof + memory analysis
    mem, cost_full, hlo = _compile(cfg, shape, mesh, policy, moe_impl,
                                   grad_accum=grad_accum)
    # 1- and 2-superblock compiles with the layer scan UNROLLED (loop-free,
    # so XLA's cost analysis is exact) -> linear extrapolation
    # cost(n) = c1 + (n-1) * (c2 - c1)
    # (grad_accum=1 in the probes: the accumulation scan is another while
    # loop the analysis would visit once; step flops are accum-invariant)
    nsb = cfg.n_superblocks
    if nsb > 1:
        _, c1, h1 = _compile(_reduced(cfg, 1), shape, mesh, policy, moe_impl,
                             unroll=True, grad_accum=1)
        _, c2, h2 = _compile(_reduced(cfg, 2), shape, mesh, policy, moe_impl,
                             unroll=True, grad_accum=1)
        cost = {k: c1.get(k, 0.0) + (nsb - 1) * (c2.get(k, 0.0)
                                                 - c1.get(k, 0.0))
                for k in ("flops", "bytes accessed", "transcendentals")}
        b1 = hla.collective_bytes(h1)
        b2 = hla.collective_bytes(h2)
        coll = {
            "bytes": {k: b1["bytes"][k] + (nsb - 1)
                      * (b2["bytes"][k] - b1["bytes"][k])
                      for k in b1["bytes"]},
            "counts": hla.collective_bytes(hlo)["counts"],
            "total_bytes": b1["total_bytes"] + (nsb - 1)
            * (b2["total_bytes"] - b1["total_bytes"]),
            "extrapolated": True,
        }
    else:
        cost = cost_full
        coll = hla.collective_bytes(hlo)
    t1 = CLOCK()

    mf = hla.model_flops_per_step(cfg, shape) / n_chips
    rl = hla.roofline(cost, coll, mf)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "compile_s": round(t1 - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes),
        },
        "collectives": coll,
        "roofline": rl.to_dict(),
    }


def cells(archs, shapes):
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            sh = shape_by_name(s)
            if sh.name == "long_500k" and not long_context_ok(cfg):
                yield a, s, "skip", ("full-attention family: long_500k "
                                     "inapplicable (DESIGN.md Section 6)")
                continue
            yield a, s, "run", ""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--policy", default="base",
                    choices=["base", "ep", "noseqpages", "localpages"])
    ap.add_argument("--moe-impl", default="dense",
                    choices=["dense", "ragged", "ep_ragged", "fsliced"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--accum", type=int, default=4,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    policy = {
        "base": ShardingPolicy(),
        "ep": ShardingPolicy(expert_parallel=True),
        "noseqpages": ShardingPolicy(seq_parallel_pages=False),
        "localpages": ShardingPolicy(decode_impl="local"),
    }[args.policy]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    archs = [args.arch] if args.arch else list(ALIASES.keys())
    shapes = [args.shape] if args.shape else [s.name for s in LM_SHAPES]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch, shape, what, why in cells(archs, shapes):
        for multi in meshes:
            key = f"{arch}|{shape}|{'multi' if multi else 'single'}"
            if args.policy != "base" or args.moe_impl != "dense":
                key += f"|{args.policy}|{args.moe_impl}"
            if args.tag:
                key += f"|{args.tag}"
            if key in results and results[key].get("status") == "ok" \
                    and not args.force:
                print(f"[cached] {key}")
                n_ok += 1
                continue
            if what == "skip":
                results[key] = {"arch": arch, "shape": shape,
                                "status": "skip", "reason": why}
                print(f"[skip]   {key}: {why}")
                n_skip += 1
            else:
                print(f"[run]    {key} ...", flush=True)
                try:
                    r = run_cell(arch, shape, multi, policy, args.moe_impl,
                                 grad_accum=args.accum)
                    r["policy"] = args.policy
                    r["moe_impl"] = args.moe_impl
                    results[key] = r
                    rl = r["roofline"]
                    print(f"         ok in {r['compile_s']}s  "
                          f"dominant={rl['dominant']} "
                          f"compute={rl['compute_s']:.3e}s "
                          f"memory={rl['memory_s']:.3e}s "
                          f"coll={rl['collective_s']:.3e}s "
                          f"useful={rl['useful_ratio']:.2f} "
                          f"peakGB={r['memory']['peak_bytes']/2**30:.2f}",
                          flush=True)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001 — record and continue
                    results[key] = {"arch": arch, "shape": shape,
                                    "status": "error",
                                    "error": f"{type(e).__name__}: {e}",
                                    "trace": traceback.format_exc()[-2000:]}
                    print(f"         FAILED: {type(e).__name__}: "
                          f"{str(e)[:300]}", flush=True)
                    n_fail += 1
            out_path.write_text(json.dumps(results, indent=1))
    print(f"done: {n_ok} ok, {n_skip} skip, {n_fail} fail "
          f"-> {out_path}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
