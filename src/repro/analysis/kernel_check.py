"""Kernel jaxpr analyzer — static checks over every Pallas entry point.

Traces each kernel in ``repro/kernels`` with abstract inputs (no device
execution, no compilation) and audits the jaxpr:

  * ``kernel-no-f64`` — no float64/complex128 value anywhere: the TPU
    lowering would silently demote (or refuse), and the store's device
    contract is u32/i32 lanes throughout.
  * ``kernel-no-callback`` — no host callback primitives inside a kernel
    dispatch: a callback re-enters Python mid-batch and breaks the
    one-dispatch-per-batch budget the paper's PCIe accounting assumes.
  * ``kernel-inplace-alias`` — every in-place scatter
    (``snapshot_image_scatter``, ``log_replay_scatter``,
    ``snapshot_multi_scatter``) must declare ``input_output_aliases`` on
    its ``pallas_call``: without donation the scatter materializes a
    second store-sized image per sync.
  * ``kernel-single-dispatch`` — the fused read megakernels lower to
    EXACTLY one ``pallas_call``: the whole point of PR 8's fusion is one
    launch per batch, and a refactor that splits the traversal back into
    per-level calls must fail loudly.
  * ``kernel-vmem-budget`` — per-kernel VMEM block footprint (the sum of
    every non-ANY BlockSpec block, which Pallas materializes in VMEM)
    stays under a configurable budget (default 4 MiB, override with
    ``HONEYCOMB_VMEM_BUDGET_BYTES``): ~16 MB is the whole core's VMEM
    and the cache tier must leave room for double buffering.

CLI::

    python -m repro.analysis.kernel_check [--json OUT]
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import sys
from pathlib import Path

from .lint import Finding

DEFAULT_VMEM_BUDGET = 4 * 2 ** 20   # bytes; see module docstring


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One traceable Pallas entry point and the properties it must hold."""
    name: str               # display name, e.g. "delta_scatter.log_replay"
    path: str               # repo-relative source file (finding anchor)
    build: "object"         # () -> (fn, args, kwargs) with abstract args
    in_place: bool = False  # must declare input_output_aliases
    fused: bool = False     # must lower to exactly one pallas_call


# ----------------------------------------------------------- jaxpr walking
def iter_eqns(jaxpr):
    """Every eqn in ``jaxpr`` and all nested (closed) jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield from iter_eqns(inner)
            elif hasattr(v, "eqns"):
                yield from iter_eqns(v)


def pallas_eqns(jaxpr):
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


def vmem_block_bytes(eqn) -> int:
    """VMEM bytes the pallas_call's block windows occupy: every block
    mapping whose memory space is not ANY gets a VMEM-resident window of
    ``prod(block_shape)`` elements (None entries are squeezed dims)."""
    gm = eqn.params["grid_mapping"]
    total = 0
    for bm in gm.block_mappings:
        ms = str(getattr(bm.block_aval, "memory_space", None)).lower()
        if "any" in ms:
            continue
        shape = [d for d in bm.block_shape if isinstance(d, int)]
        dtype = bm.array_shape_dtype.dtype
        total += math.prod(shape) * dtype.itemsize
    return total


def check_jaxpr(name: str, path: str, jaxpr, *, in_place: bool = False,
                fused: bool = False,
                vmem_budget: int | None = None) -> list[Finding]:
    """Audit one traced entry point; pure function of the jaxpr so tests
    can feed deliberately broken kernels through it."""
    import numpy as np
    budget = vmem_budget if vmem_budget is not None else int(os.environ.get(
        "HONEYCOMB_VMEM_BUDGET_BYTES", DEFAULT_VMEM_BUDGET))
    findings: list[Finding] = []
    calls = pallas_eqns(jaxpr)

    for eqn in iter_eqns(jaxpr):
        if "callback" in eqn.primitive.name:
            findings.append(Finding(
                "kernel-no-callback", path, 1,
                f"{name}: host callback primitive "
                f"'{eqn.primitive.name}' inside a kernel dispatch"))
        for v in (*eqn.invars, *eqn.outvars):
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and dt in (np.float64, np.complex128):
                findings.append(Finding(
                    "kernel-no-f64", path, 1,
                    f"{name}: {dt} value flows through "
                    f"'{eqn.primitive.name}' — device lanes are 32-bit"))
                break

    if fused and len(calls) != 1:
        findings.append(Finding(
            "kernel-single-dispatch", path, 1,
            f"{name}: fused read path lowered to {len(calls)} pallas_call"
            f"(s), expected exactly 1 — the single-launch contract of the "
            f"fused megakernel is broken"))
    if in_place:
        for eqn in calls:
            if not eqn.params.get("input_output_aliases"):
                findings.append(Finding(
                    "kernel-inplace-alias", path, 1,
                    f"{name}: in-place scatter's pallas_call declares no "
                    f"input_output_aliases — the device will materialize "
                    f"a full copy of the image every sync"))
    for eqn in calls:
        used = vmem_block_bytes(eqn)
        if used > budget:
            findings.append(Finding(
                "kernel-vmem-budget", path, 1,
                f"{name}: VMEM block footprint {used} B exceeds the "
                f"{budget} B budget — shrink the VMEM-pinned blocks or "
                f"raise HONEYCOMB_VMEM_BUDGET_BYTES deliberately"))
    return findings


def trace_entry(entry: KernelEntry):
    import jax
    fn, args, kwargs = entry.build()
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)


# --------------------------------------------------------- entry registry
def _abstract(shape, dtype):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, getattr(jnp, dtype))


def kernel_entries() -> list[KernelEntry]:
    """Every Pallas entry point in ``repro/kernels``, with abstract
    inputs at the default geometry (shapes only — nothing executes)."""
    from repro.core.config import HoneycombConfig
    from repro.core.schema import NodeImageLayout

    cfg = HoneycombConfig()
    layout = NodeImageLayout.for_config(cfg)
    IW, LW = layout.image_words, layout.log_entry_words
    KW, VW, C = cfg.key_words, cfg.val_words, cfg.cache_slots
    S, B, E = 64, 8, 4
    u32, i32 = "uint32", "int32"

    def delta():
        from repro.kernels.delta_scatter import snapshot_delta_scatter
        return (snapshot_delta_scatter,
                (_abstract((S, KW), u32), _abstract((E,), i32),
                 _abstract((E, KW), u32)), {})

    def image():
        from repro.kernels.delta_scatter import snapshot_image_scatter
        return (snapshot_image_scatter,
                (_abstract((S, IW), u32), _abstract((E,), i32),
                 _abstract((E, IW), u32)), {})

    def multi():
        from repro.kernels.delta_scatter import snapshot_multi_scatter
        dsts = tuple(_abstract((S, KW), u32) for _ in range(3))
        upds = tuple(_abstract((E, KW), u32) for _ in range(3))
        return (lambda rows, *flat: snapshot_multi_scatter(
                    flat[:3], rows, flat[3:]),
                (_abstract((E,), i32), *dsts, *upds), {})

    def log_replay():
        from repro.kernels.delta_scatter import log_replay_scatter
        return (log_replay_scatter,
                (_abstract((S, IW), u32), _abstract((E,), i32),
                 _abstract((E,), i32), _abstract((E, LW), u32)),
                {"offs": layout.log_replay_offsets()})

    def fused(mode):
        def build():
            from repro.kernels import fused_read
            fn = (fused_read.batched_get_fused if mode == "get"
                  else fused_read.batched_scan_fused)
            args = [_abstract((S, IW), u32), _abstract((2 * S,), i32),
                    _abstract((), i32), _abstract((), i32),
                    _abstract((C,), i32), _abstract((C, IW), u32),
                    _abstract((B, KW), u32), _abstract((B,), i32)]
            if mode == "scan":
                args += [_abstract((B, KW), u32), _abstract((B,), i32)]
            return fn, tuple(args), {"cfg": cfg}
        return build

    def key_search():
        from repro.kernels.key_search import key_search as fn
        N = cfg.node_cap
        return (fn, (_abstract((B, KW), u32), _abstract((B,), i32),
                     _abstract((B, N, KW), u32), _abstract((B, N), i32),
                     _abstract((B, N), i32)), {})

    def key_search_image():
        from repro.kernels.key_search import key_search_image as fn
        offs = layout.offsets()
        return (fn, (_abstract((B, KW), u32), _abstract((B,), i32),
                     _abstract((B, IW), u32)),
                {"keys_off": offs["sc_keys"][0],
                 "lens_off": offs["sc_keylen"][0],
                 "count_off": offs["n_shortcuts"][0],
                 "n_keys": cfg.n_shortcuts, "key_words": KW})

    def leaf_merge():
        from repro.kernels.leaf_merge import leaf_merge as fn
        L = cfg.log_cap
        return (fn, (_abstract((B,), i32), _abstract((B,), i32),
                     _abstract((B, L), i32), _abstract((B, L), i32)),
                {"node_cap": cfg.node_cap, "log_cap": L})

    def paged():
        from repro.kernels.paged_attention import paged_attention as fn
        H, D, P, PS, T = 4, 64, 16, 16, 2
        return (fn, (_abstract((T, H, D), "float32"),
                     _abstract((P, PS, H, D), "float32"),
                     _abstract((P, PS, H, D), "float32"),
                     _abstract((T, 4), i32), _abstract((T,), i32),
                     _abstract((T,), i32)), {})

    k = "src/repro/kernels"
    return [
        KernelEntry("delta_scatter.snapshot_delta_scatter",
                    f"{k}/delta_scatter.py", delta, in_place=True),
        KernelEntry("delta_scatter.snapshot_image_scatter",
                    f"{k}/delta_scatter.py", image, in_place=True),
        KernelEntry("delta_scatter.snapshot_multi_scatter",
                    f"{k}/delta_scatter.py", multi, in_place=True),
        KernelEntry("delta_scatter.log_replay_scatter",
                    f"{k}/delta_scatter.py", log_replay, in_place=True),
        KernelEntry("fused_read.batched_get_fused",
                    f"{k}/fused_read.py", fused("get"), fused=True),
        KernelEntry("fused_read.batched_scan_fused",
                    f"{k}/fused_read.py", fused("scan"), fused=True),
        KernelEntry("key_search.key_search",
                    f"{k}/key_search.py", key_search),
        KernelEntry("key_search.key_search_image",
                    f"{k}/key_search.py", key_search_image),
        KernelEntry("leaf_merge.leaf_merge",
                    f"{k}/leaf_merge.py", leaf_merge),
        KernelEntry("paged_attention.paged_attention",
                    f"{k}/paged_attention.py", paged),
    ]


def run_kernel_checks(vmem_budget: int | None = None) -> list[Finding]:
    findings: list[Finding] = []
    for entry in kernel_entries():
        try:
            jaxpr = trace_entry(entry)
        except Exception as e:  # noqa  # honeylint: disable=no-bare-except -- a kernel that fails to TRACE is itself a finding, whatever the error type
            findings.append(Finding(
                "kernel-trace-error", entry.path, 1,
                f"{entry.name}: failed to trace with abstract inputs: "
                f"{type(e).__name__}: {e}"))
            continue
        findings.extend(check_jaxpr(
            entry.name, entry.path, jaxpr.jaxpr, in_place=entry.in_place,
            fused=entry.fused, vmem_budget=vmem_budget))
    return findings


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.analysis.kernel_check")
    ap.add_argument("--json", help="write findings as JSON to this path")
    ap.add_argument("--vmem-budget", type=int, default=None)
    args = ap.parse_args(argv)
    findings = run_kernel_checks(vmem_budget=args.vmem_budget)
    for f in findings:
        print(f)
    if args.json:
        Path(args.json).write_text(json.dumps(
            {"findings": [f.to_json() for f in findings]}, indent=1) + "\n")
    n = len(kernel_entries())
    print(f"kernel_check: {n} entry points traced, "
          f"{len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
