"""honeylint — Honeycomb's repo-specific static analysis + sanitizers.

Three parts, one gate (``scripts/verify.sh --analyze``, CI job
``analyze``):

  * ``analysis/lint.py``         — AST lint pass + golden schema hash
  * ``analysis/kernel_check.py`` — jaxpr audit of every Pallas entry point
  * ``analysis/epochsan.py``     — env-gated runtime sanitizer
    (``HONEYCOMB_EPOCHSAN=1``) for the epoch/snapshot protocol

Every rule encodes a bug class this repo has already hit (or a
neighbouring repo class the protocol-verification literature insists on
checking mechanically).  Rule reference:

====================== ============================================= =====
rule id                bug class it encodes                          origin
====================== ============================================= =====
no-raw-clock           raw time.time()/perf_counter() bypassing the  PR 9
                       injectable telemetry.CLOCK (unfreezable
                       timings, untestable timers)
no-aliased-publish     jnp.asarray() aliasing the live host heap in  PR 1
                       a snapshot publish (zero-copy on CPU: the
                       published epoch mutates under readers — the
                       PR 1 flake)
no-magic-image-offsets integer-literal word offsets into the packed  PR 8
                       node image instead of NodeImageLayout /
                       log_replay_offsets() (silently desynced
                       kernels when NODE_SCHEMA changes)
stats-must-collect     *Stats dataclass without collect(): meters    PR 9
                       invisible to the telemetry registry and the
                       Prometheus/JSON exporters
no-bare-except         bare/over-broad except swallowing protocol    PR 10
                       violations (incl. EpochSan assertions)
schema-golden-drift    NODE_SCHEMA / wire-codec layout drift without PR 4/7
                       re-pinning the golden (device image + replica
                       feed are cross-version contracts)
kernel-no-f64          float64 values inside a device kernel         PR 8
kernel-no-callback     host callbacks inside a kernel dispatch       PR 8
kernel-inplace-alias   in-place scatter without declared             PR 4
                       input_output_aliases (full image copy per
                       sync)
kernel-single-dispatch fused read path lowering to more than one     PR 8
                       pallas_call (single-launch contract)
kernel-vmem-budget     per-kernel VMEM block footprint over budget   PR 8
standby-read           device batch reading an UNFLIPPED standby     PR 6
                       snapshot (EpochSan)
pinned-epoch-gc        GC reclaiming buffers a pinned accelerator/   PR 6
                       CPU epoch still needs (EpochSan)
follower-freshness     follower dispatch below the primary's         PR 7
                       serving read version (EpochSan)
stale-cache-rows       staged snapshot shipping cache rows not       PR 8
                       refreshed since a PageTable remap (EpochSan)
unflipped-standby-     scheduler stage_export leaving a staged       PR 6
after-export           standby unpublished (EpochSan)
====================== ============================================= =====

Import is deliberately lazy: ``repro.core`` modules import
``repro.analysis.epochsan`` for seam hooks, so this package must load
without jax or repro.core on the path.
"""
from __future__ import annotations

__all__ = ["lint", "kernel_check", "epochsan", "runner"]


def __getattr__(name):
    if name in __all__:
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(name)
