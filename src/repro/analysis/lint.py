"""honeylint — repo-specific AST lint pass.

Each rule encodes a bug class this repo has already paid for at runtime
(the table in ``analysis/__init__`` maps rule ids to the originating
PR).  The pass is pure ``ast`` — no third-party linter — plus one
runtime rule (``schema-golden-drift``) that imports the schema/codec
modules and fingerprints their layout against a pinned golden.

Suppressions
============

Inline, on the offending line or the line above::

    t0 = time.perf_counter()  # honeylint: disable=no-raw-clock -- reason

Baseline (``analysis/baseline.json``): a list of entries

    {"rule": "...", "path": "src/...", "reason": "why this is justified"}

matching every finding of that rule in that file.  The baseline is for
debt the rule post-dates; new code suppresses inline with a reason.

CLI::

    python -m repro.analysis.lint [--baseline PATH] [--json OUT] [ROOT...]
    python -m repro.analysis.lint --pin-golden   # re-pin after schema bumps
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_ROOTS = ("src/repro",)
BASELINE_PATH = Path(__file__).with_name("baseline.json")
GOLDEN_PATH = Path(__file__).with_name("golden_schema.json")

# the one module allowed to touch the raw clock (it OWNS telemetry.CLOCK)
CLOCK_OWNER = "core/telemetry.py"
RAW_CLOCK_ATTRS = {"time", "perf_counter", "perf_counter_ns",
                   "monotonic", "monotonic_ns"}

# snapshot-publish surfaces the aliasing rule patrols, and the function
# name shapes that mark a publish path inside them
PUBLISH_FILES = ("core/shard.py", "core/replica.py", "core/read_path.py")
PUBLISH_FN = re.compile(r"publish|stage|export|flip|snapshot")

# Pallas ref names the magic-offset rule treats as packed-image handles
IMAGE_REF = re.compile(r"(^|_)(img|image|out|dst|node)_?ref$|^image$|^img$")
# names whose attributes mark a layout-derived index expression
OFFSET_SOURCES = {"offs", "off", "offsets", "layout", "slot", "cfg", "self"}
MAGIC_MIN = 8   # literals below this are lane/step arithmetic, not offsets

_SUPPRESS_RE = re.compile(
    r"#\s*honeylint:\s*disable=([a-z0-9_,-]+)(?:\s*--\s*(.*))?")


@dataclasses.dataclass
class Finding:
    rule: str
    path: str           # repo-relative
    line: int
    message: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _suppressions(source: str) -> dict[int, set[str]]:
    """line number -> rule ids disabled there (a directive also covers
    the NEXT line, so it can sit above long statements)."""
    out: dict[int, set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out.setdefault(i, set()).update(rules)
            out.setdefault(i + 1, set()).update(rules)
    return out


# ------------------------------------------------------------ rule helpers
def _is_raw_clock(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute)
            and node.attr in RAW_CLOCK_ATTRS
            and isinstance(node.value, ast.Name)
            and node.value.id == "time")


def _names_in(node: ast.AST):
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            yield n.id
        elif isinstance(n, ast.Attribute):
            base = n
            while isinstance(base, ast.Attribute):
                base = base.value
            if isinstance(base, ast.Name):
                yield base.id


def _int_literals(node: ast.AST):
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, int) \
                and not isinstance(n.value, bool):
            yield n


def _broad_handler(h: ast.ExceptHandler) -> bool:
    t = h.type
    if t is None:
        return True
    names = [t] if not isinstance(t, ast.Tuple) else list(t.elts)
    return any(isinstance(n, ast.Name)
               and n.id in ("Exception", "BaseException") for n in names)


class _FileLinter(ast.NodeVisitor):
    """One pass over one module; accumulates findings for all AST rules."""

    def __init__(self, rel: str, source: str):
        self.rel = rel
        self.findings: list[Finding] = []
        self._publish_depth = 0
        # per-function map of local names bound to aliasing expressions
        # (attribute chains / sliced views of live host arrays)
        self._alias_stack: list[set[str]] = []
        self.in_publish_file = any(self.rel.endswith(p)
                                   for p in PUBLISH_FILES)
        self.in_kernels = "/kernels/" in self.rel
        self.is_clock_owner = self.rel.endswith(CLOCK_OWNER)

    def emit(self, rule: str, node: ast.AST, message: str):
        self.findings.append(Finding(rule, self.rel,
                                     getattr(node, "lineno", 1), message))

    # ------------------------------------------------------- no-raw-clock
    def visit_Call(self, node: ast.Call):
        if not self.is_clock_owner and _is_raw_clock(node.func):
            self.emit(
                "no-raw-clock", node,
                f"time.{node.func.attr}() bypasses telemetry.CLOCK — the "
                f"one injectable clock (freeze/advance in tests); import "
                f"CLOCK from repro.core.telemetry")
        if self._publish_depth and self._is_jnp_asarray(node):
            arg = node.args[0] if node.args else None
            if arg is not None and self._aliases_host(arg):
                self.emit(
                    "no-aliased-publish", node,
                    "jnp.asarray() of a live host array inside a snapshot "
                    "publish path: zero-copy on the CPU backend aliases the "
                    "mutable heap (the PR 1 flake) — copy first "
                    "(np.asarray(...).copy() / .astype(...))")
        self.generic_visit(node)

    @staticmethod
    def _is_jnp_asarray(node: ast.Call) -> bool:
        f = node.func
        return (isinstance(f, ast.Attribute) and f.attr == "asarray"
                and isinstance(f.value, ast.Name)
                and f.value.id in ("jnp", "jax_numpy"))

    def _aliases_host(self, expr: ast.AST) -> bool:
        """Could ``expr`` be a view of a live host array?  Attribute
        chains (``h.ntype``), ``getattr(...)`` and slice subscripts alias;
        calls produce fresh buffers; local names inherit what they were
        bound to (one-pass forward dataflow per function)."""
        if isinstance(expr, ast.Attribute):
            return True
        if isinstance(expr, ast.Call):
            return (isinstance(expr.func, ast.Name)
                    and expr.func.id == "getattr")
        if isinstance(expr, ast.Subscript):
            return any(isinstance(n, ast.Slice) for n in ast.walk(expr.slice))
        if isinstance(expr, ast.Name) and self._alias_stack:
            return expr.id in self._alias_stack[-1]
        return False

    def visit_Assign(self, node: ast.Assign):
        if self._alias_stack:
            aliases = self._alias_stack[-1]
            for t in node.targets:
                if isinstance(t, ast.Name):
                    if self._aliases_host(node.value):
                        aliases.add(t.id)
                    else:
                        aliases.discard(t.id)
        self.generic_visit(node)

    # --------------------------------------------------- no-bare-except
    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        if _broad_handler(node):
            what = "bare except" if node.type is None else "except Exception"
            self.emit(
                "no-bare-except", node,
                f"{what} swallows protocol violations (including EpochSan "
                f"assertions) — name the exception types this handler "
                f"actually recovers from")
        self.generic_visit(node)

    # ------------------------------------------- publish-path bookkeeping
    def visit_FunctionDef(self, node: ast.FunctionDef):
        is_pub = self.in_publish_file and bool(PUBLISH_FN.search(node.name))
        self._publish_depth += is_pub
        self._alias_stack.append(set())
        self.generic_visit(node)
        self._alias_stack.pop()
        self._publish_depth -= is_pub

    visit_AsyncFunctionDef = visit_FunctionDef

    # -------------------------------------------- no-magic-image-offsets
    def visit_Subscript(self, node: ast.Subscript):
        if self.in_kernels and isinstance(node.value, ast.Name) \
                and IMAGE_REF.search(node.value.id):
            self._check_index(node, node.slice)
        self.generic_visit(node)

    def _check_index(self, node: ast.AST, index: ast.AST):
        bad = [c for c in _int_literals(index) if c.value >= MAGIC_MIN]
        if bad and not (set(_names_in(index)) & OFFSET_SOURCES):
            self.emit(
                "no-magic-image-offsets", bad[0],
                f"integer literal {bad[0].value} used as a packed-image "
                f"offset: kernel indices must derive from NodeImageLayout "
                f"offsets / log_replay_offsets(), which re-layout when "
                f"NODE_SCHEMA changes")

    # ------------------------------------------------- stats-must-collect
    def visit_ClassDef(self, node: ast.ClassDef):
        is_dc = any("dataclass" in ast.dump(d) for d in node.decorator_list)
        if is_dc and node.name.endswith("Stats"):
            methods = {n.name for n in node.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))}
            if "collect" not in methods:
                self.emit(
                    "stats-must-collect", node,
                    f"{node.name} is a *Stats dataclass without collect(): "
                    f"every stats surface must speak the telemetry registry "
                    f"protocol (core/telemetry.samples_from) so its meters "
                    f"export")
        self.generic_visit(node)


# --------------------------------------------------------- golden schema
def schema_fingerprint() -> dict:
    """Canonical description of the device-visible layouts: the packed
    node image (NODE_SCHEMA -> NodeImageLayout offsets at the default
    geometry) and the op wire codec (core/api.py).  Any drift here
    changes what crosses the bus / what followers replay — the golden
    must be re-pinned deliberately (``--pin-golden``), never silently."""
    from repro.core import api, schema
    from repro.core.config import HoneycombConfig

    cfg = HoneycombConfig()
    layout = schema.NodeImageLayout.for_config(cfg)
    detail = {
        "node_schema": [
            {"name": f.name, "dims": list(f.dims), "host": f.host,
             "device": f.device, "fill": f.fill}
            for f in schema.NODE_SCHEMA
        ],
        "image_offsets": {name: [int(off), int(width)]
                          for name, (off, width)
                          in sorted(layout.offsets().items())},
        "image_words": int(layout.image_words),
        "log_entry_words": int(layout.log_entry_words),
        "wire_entry_overhead": int(api.WIRE_ENTRY_OVERHEAD),
        "wire_header_format": api._WIRE_HEADER.format,
        "wire_u16_format": api._WIRE_U16.format,
        "op_codes": {cls.__name__: code
                     for code, cls in sorted(api.OPS_BY_CODE.items())},
    }
    blob = json.dumps(detail, sort_keys=True).encode()
    return {"sha256": hashlib.sha256(blob).hexdigest(), "detail": detail}


def pin_golden(path: Path = GOLDEN_PATH) -> dict:
    fp = schema_fingerprint()
    path.write_text(json.dumps(fp, indent=1, sort_keys=True) + "\n")
    return fp


def check_golden(path: Path = GOLDEN_PATH) -> list[Finding]:
    rel = str(path.relative_to(REPO_ROOT)) if path.is_relative_to(REPO_ROOT) \
        else str(path)
    if not path.exists():
        return [Finding("schema-golden-drift", rel, 1,
                        "golden schema fingerprint missing — run "
                        "`python -m repro.analysis.lint --pin-golden`")]
    golden = json.loads(path.read_text())
    fp = schema_fingerprint()
    if fp["sha256"] == golden.get("sha256"):
        return []
    drift = []
    old, new = golden.get("detail", {}), fp["detail"]
    for k in sorted(set(old) | set(new)):
        if old.get(k) != new.get(k):
            drift.append(k)
    return [Finding(
        "schema-golden-drift", rel, 1,
        f"NODE_SCHEMA / wire-codec layout drifted from the pinned golden "
        f"(changed: {', '.join(drift) or 'unknown'}): the device image and "
        f"the replica feed wire format are cross-version contracts — "
        f"re-pin deliberately with --pin-golden after auditing replayers")]


# ---------------------------------------------------------------- driver
def load_baseline(path: Path | None = BASELINE_PATH) -> list[dict]:
    if path is None or not Path(path).exists():
        return []
    return json.loads(Path(path).read_text())


def _baselined(f: Finding, baseline: list[dict]) -> bool:
    return any(b.get("rule") == f.rule and b.get("path") == f.path
               for b in baseline)


def lint_file(path: Path, root: Path = REPO_ROOT) -> list[Finding]:
    rel = str(path.relative_to(root)) if path.is_relative_to(root) \
        else str(path)
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as e:
        return [Finding("syntax-error", rel, e.lineno or 1, str(e.msg))]
    linter = _FileLinter(rel, source)
    linter.visit(tree)
    sup = _suppressions(source)
    return [f for f in linter.findings
            if f.rule not in sup.get(f.line, ())]


def run_lint(roots=DEFAULT_ROOTS, *, root: Path = REPO_ROOT,
             baseline: Path | None = BASELINE_PATH,
             golden: Path | None = GOLDEN_PATH
             ) -> tuple[list[Finding], int]:
    """Lint every .py under ``roots``.  Returns (findings, n_baselined)."""
    base = load_baseline(baseline)
    findings: list[Finding] = []
    suppressed = 0
    for r in roots:
        top = root / r if not Path(r).is_absolute() else Path(r)
        files = sorted(top.rglob("*.py")) if top.is_dir() else [top]
        for path in files:
            for f in lint_file(path, root):
                if _baselined(f, base):
                    suppressed += 1
                else:
                    findings.append(f)
    if golden is not None:
        findings.extend(check_golden(golden))
    return findings, suppressed


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.analysis.lint")
    ap.add_argument("roots", nargs="*", default=list(DEFAULT_ROOTS))
    ap.add_argument("--baseline", default=str(BASELINE_PATH))
    ap.add_argument("--no-baseline", action="store_true")
    ap.add_argument("--json", help="write findings as JSON to this path")
    ap.add_argument("--pin-golden", action="store_true",
                    help="re-pin the schema/wire golden and exit")
    args = ap.parse_args(argv)
    if args.pin_golden:
        fp = pin_golden()
        print(f"pinned golden schema fingerprint {fp['sha256'][:12]} "
              f"-> {GOLDEN_PATH}")
        return 0
    baseline = None if args.no_baseline else Path(args.baseline)
    findings, suppressed = run_lint(args.roots or DEFAULT_ROOTS,
                                    baseline=baseline)
    for f in findings:
        print(f)
    if args.json:
        Path(args.json).write_text(json.dumps(
            {"findings": [f.to_json() for f in findings],
             "baselined": suppressed}, indent=1) + "\n")
    print(f"honeylint: {len(findings)} finding(s), "
          f"{suppressed} baselined")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
