"""honeylint driver: run the lint pass + kernel checks, one JSON report.

``scripts/verify.sh --analyze`` and the CI ``analyze`` job call this;
EpochSan is exercised separately (it is a *runtime* sanitizer — the
verify script re-runs the epoch/replica test subset under
``HONEYCOMB_EPOCHSAN=1``).

    python -m repro.analysis [--json experiments/analysis_report.json]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.analysis")
    ap.add_argument("--json", default=None,
                    help="write the combined findings report here")
    ap.add_argument("--no-baseline", action="store_true")
    args = ap.parse_args(argv)

    from . import kernel_check, lint

    lint_findings, baselined = lint.run_lint(
        baseline=None if args.no_baseline else lint.BASELINE_PATH)
    kernel_findings = kernel_check.run_kernel_checks()
    findings = lint_findings + kernel_findings
    for f in findings:
        print(f)
    report = {
        "lint": [f.to_json() for f in lint_findings],
        "kernel_check": [f.to_json() for f in kernel_findings],
        "baselined": baselined,
        "entry_points": len(kernel_check.kernel_entries()),
        "ok": not findings,
    }
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=1) + "\n")
        print(f"report -> {out}")
    print(f"honeylint: {len(lint_findings)} lint + "
          f"{len(kernel_findings)} kernel finding(s), "
          f"{baselined} baselined, "
          f"{report['entry_points']} kernel entry points")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
