"""EpochSan — runtime sanitizer for the epoch/snapshot pipeline.

The double-buffered snapshot protocol (core/shard.py, core/replica.py)
carries happens-before rules no type system enforces: a device batch may
only read a snapshot that was *flipped* (published), garbage may only be
reclaimed once every pinned accelerator epoch has moved past it, a
follower may only serve a batch when its published read version covers
the primary's, and a snapshot staged after a ``PageTable`` remap must
carry a refreshed interior-cache frontier.  Each of these was a runtime
bug class once (see the rule table in ``analysis/__init__``); EpochSan
turns them into checked invariants.

Activation is environment-gated so the hooks cost one module-attribute
read + ``is None`` test when off::

    HONEYCOMB_EPOCHSAN=1 python -m pytest -q

or programmatically (tests)::

    from repro.analysis import epochsan
    with epochsan.enabled():
        ...

The sanitizer tags every snapshot buffer it sees at a staging/flip seam
with ``(epoch, pin-state, role)`` (role is ``standby`` until the flip
publishes it as ``active``; earlier actives retire).  Detection itself
never trusts the tags alone — the standby-read check compares *object
identity* against every live owner's ``_standby`` attribute, the GC
audit re-derives reclaimability from the pre-collect epoch window, and
the freshness check recomputes the read-version comparison — so a seam
that lies (the bug the sanitizer exists to catch) cannot also silence
the check.

This module deliberately imports nothing from ``repro.core`` at module
scope: core modules import *it* for the seam hooks, and the telemetry
bridge (``EpochSanStats.collect``) resolves lazily at collect time.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import weakref
from typing import NamedTuple

ENV_VAR = "HONEYCOMB_EPOCHSAN"

#: violation kinds, for reports and tests
STANDBY_READ = "standby-read"
PINNED_EPOCH_GC = "pinned-epoch-gc"
FOLLOWER_FRESHNESS = "follower-freshness"
STALE_CACHE_ROWS = "stale-cache-rows"
UNFLIPPED_EXPORT = "unflipped-standby-after-export"


class EpochSanViolation(AssertionError):
    """An epoch/snapshot protocol invariant was broken at a checked seam."""

    def __init__(self, kind: str, message: str):
        super().__init__(f"[epochsan:{kind}] {message}")
        self.kind = kind


@dataclasses.dataclass
class EpochSanStats:
    """Sanitizer meters (telemetry collect protocol — registered by
    ``Telemetry.wire_store`` when the sanitizer is active)."""
    read_checks: int = 0
    stagings: int = 0
    flips: int = 0
    gc_audits: int = 0
    dispatch_checks: int = 0
    violations: int = 0

    def collect(self):
        from repro.core.telemetry import samples_from
        return samples_from(self, "epochsan", "epochsan")


class SnapshotTag(NamedTuple):
    """What the sanitizer knows about one snapshot buffer."""
    epoch: int
    role: str                  # "standby" | "active" | "retired"
    read_version: int | None
    pinned: bool               # an accelerator epoch pin covers it


@dataclasses.dataclass
class _GcGuard:
    """Pre-collect capture of the garbage list and the epoch window the
    reclaimability decision must be audited against."""
    entries: list
    cpu_seq: dict
    accel_s_old: int


class EpochSanitizer:
    """The active sanitizer: owns tags, owner registry, cache ticks and
    the violation log.  ``strict=True`` raises on the first violation;
    ``strict=False`` records only (the findings-report mode)."""

    def __init__(self, strict: bool = True):
        self.strict = strict
        self.stats = EpochSanStats()
        self.violations: list[EpochSanViolation] = []
        # owners (StoreShard / FollowerReplica) whose ``_standby`` the
        # read check scans by identity; weak so the sanitizer never keeps
        # a store alive
        self._owners: weakref.WeakSet = weakref.WeakSet()
        # id(snapshot) -> tag; informational (identity checks decide)
        self._tags: dict[int, SnapshotTag] = {}
        # per-InteriorCache remap/refresh ticks for the stale-rows check
        self._cache_ticks: "weakref.WeakKeyDictionary" = \
            weakref.WeakKeyDictionary()

    # ------------------------------------------------------------ report
    def _violate(self, kind: str, message: str):
        self.stats.violations += 1
        err = EpochSanViolation(kind, message)
        self.violations.append(err)
        if self.strict:
            raise err

    def report(self) -> list[dict]:
        return [{"kind": v.kind, "message": str(v)} for v in self.violations]

    # ----------------------------------------------------- staging seams
    def note_staged(self, owner, snap) -> None:
        """A standby was (re)staged on ``owner`` (shard or follower)."""
        if snap is None:
            return
        self.stats.stagings += 1
        self._owners.add(owner)
        pinned = getattr(owner, "_standby_pin", None) is not None
        self._tags[id(snap)] = SnapshotTag(
            epoch=getattr(owner, "epoch", 0) + 1, role="standby",
            read_version=getattr(owner, "_standby_rv", None), pinned=pinned)
        cache = getattr(owner, "cache", None)
        if cache is not None:
            self._check_cache_fresh(owner, cache)

    def note_flip(self, owner, snap) -> None:
        """The standby was published as ``owner``'s active snapshot."""
        if snap is None:
            return
        self.stats.flips += 1
        self._owners.add(owner)
        old = self._tags.get(id(snap))
        self._tags[id(snap)] = SnapshotTag(
            epoch=getattr(owner, "epoch", old.epoch if old else 0),
            role="active",
            read_version=getattr(owner, "snapshot_rv", None)
            or getattr(owner, "_snapshot_rv", None),
            pinned=getattr(owner, "_snapshot_pin", None) is not None)

    # -------------------------------------------------------- read seams
    def check_read(self, dispatcher, snap) -> None:
        """A device batch is about to execute against ``snap``.  The
        snapshot must not be any live owner's unflipped standby."""
        self.stats.read_checks += 1
        if snap is None:
            return
        for owner in list(self._owners):
            if getattr(owner, "_standby", None) is snap:
                tag = self._tags.get(id(snap))
                self._violate(
                    STANDBY_READ,
                    f"device batch dispatched against the UNFLIPPED standby "
                    f"of {type(owner).__name__} (tag={tag}); reads must only "
                    f"see snapshots published by flip()")

    def check_follower_dispatch(self, group, follower) -> None:
        """A batch resolved to ``follower``; recompute the freshness rule
        independently of ``ReplicaGroup._covers`` (the seam under test)."""
        self.stats.dispatch_checks += 1
        need = getattr(group.primary, "_snapshot_rv", None)
        if need is None:
            return
        got = getattr(follower, "snapshot_rv", None)
        if follower.snapshot is None or got is None or got < need:
            self._violate(
                FOLLOWER_FRESHNESS,
                f"replica {follower.replica_id} dispatched at read version "
                f"{got} but the group serves at {need}: the freshness rule "
                f"(follower covers the primary's active snapshot) is broken")

    def check_exported(self, store) -> None:
        """After a scheduler ``stage_export`` every staged standby must
        have been flipped: primary always, followers when unpaused and in
        sync (exactly the set ``_on_primary_flip`` publishes)."""
        shards = getattr(store, "shards", None) or [store]
        for s in shards:
            prim = getattr(s, "primary", s)
            if getattr(prim, "_standby", None) is not None:
                self._violate(
                    UNFLIPPED_EXPORT,
                    f"shard {getattr(prim, 'shard_id', '?')} left "
                    f"stage_export with a staged, unpublished standby")
            for f in getattr(s, "followers", ()) or ():
                if not f.paused and f.in_sync and f._standby is not None:
                    self._violate(
                        UNFLIPPED_EXPORT,
                        f"replica {f.replica_id} (in sync, unpaused) left "
                        f"stage_export with an unpublished standby")

    # ---------------------------------------------------------- GC seams
    def gc_begin(self, shard) -> _GcGuard:
        ep = shard.tree.epochs
        return _GcGuard(entries=list(shard.tree.gc.list),
                        cpu_seq=dict(ep.cpu_seq),
                        accel_s_old=ep.accel_s_old)

    def gc_end(self, shard, guard: _GcGuard) -> None:
        """Audit one ``collect()``: every entry it freed must have been
        reclaimable under the PRE-collect epoch window (no pinned epoch —
        accelerator or CPU thread — may lose its buffers)."""
        self.stats.gc_audits += 1
        remaining = {id(e) for e in shard.tree.gc.list}
        for e in guard.entries:
            if id(e) in remaining:
                continue
            cpu_pinned = any(guard.cpu_seq.get(t, 0) <= s
                             for t, s in e.cpu_stamp.items())
            accel_pinned = guard.accel_s_old <= e.accel_stamp
            if cpu_pinned or accel_pinned:
                self._violate(
                    PINNED_EPOCH_GC,
                    f"GC reclaimed slots {e.slots} stamped S={e.accel_stamp} "
                    f"while the accelerator window still pins "
                    f"S_old={guard.accel_s_old}"
                    + (" (CPU thread pinned too)" if cpu_pinned else "")
                    + "; a pinned epoch's buffers were freed under it")

    # -------------------------------------------------------- cache seams
    def note_cache_invalidate(self, cache) -> None:
        t = self._cache_ticks.setdefault(cache, {"inval": 0, "at_refresh": 0})
        t["inval"] += 1

    def note_cache_refresh(self, cache) -> None:
        t = self._cache_ticks.setdefault(cache, {"inval": 0, "at_refresh": 0})
        t["at_refresh"] = t["inval"]

    def _check_cache_fresh(self, owner, cache) -> None:
        """At staging time the interior cache must have been refreshed
        after the last ``PageTable`` remap invalidation — otherwise the
        staged snapshot ships stale cache rows to the device."""
        t = self._cache_ticks.get(cache)
        if t is not None and t["inval"] > t["at_refresh"]:
            self._violate(
                STALE_CACHE_ROWS,
                f"{type(owner).__name__} staged a snapshot while the "
                f"interior cache saw {t['inval'] - t['at_refresh']} remap "
                f"invalidation(s) after its last refresh: stale cache rows "
                f"would survive the PageTable remap on-device")


# --------------------------------------------------------------- gating
_ACTIVE: EpochSanitizer | None = None
_ENV_CHECKED = False


def get() -> EpochSanitizer | None:
    """The active sanitizer, or None.  Reads ``HONEYCOMB_EPOCHSAN`` once
    (first seam hit); ``enabled()``/``enable()`` override it for tests."""
    global _ACTIVE, _ENV_CHECKED
    if _ACTIVE is None and not _ENV_CHECKED:
        _ENV_CHECKED = True
        if os.environ.get(ENV_VAR, "").strip() not in ("", "0", "false"):
            _ACTIVE = EpochSanitizer()
    return _ACTIVE


def enable(strict: bool = True) -> EpochSanitizer:
    global _ACTIVE, _ENV_CHECKED
    _ENV_CHECKED = True
    _ACTIVE = EpochSanitizer(strict=strict)
    return _ACTIVE


def disable() -> None:
    global _ACTIVE
    _ACTIVE = None


@contextlib.contextmanager
def enabled(strict: bool = True):
    """Scoped activation for tests: ``with epochsan.enabled() as san:``."""
    global _ACTIVE
    prev = get()   # resolve the env-driven sanitizer before overriding
    san = enable(strict=strict)
    try:
        yield san
    finally:
        _ACTIVE = prev
