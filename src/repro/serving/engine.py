"""Batched serving engine: continuous batching over the paged KV cache.

The request path mirrors the paper's architecture end to end:
  network ingest -> slot admission (continuous batching) -> block-table
  assembly via batched Honeycomb GETs (the accelerator read path) -> jitted
  decode step (paged attention) -> in-order response delivery.  Page
  allocation and completion-time frees are host-side Honeycomb writes —
  the paper's read/write split, transplanted.

Every active request owns a fixed batch *slot*: attention state lives in
pages (slot-independent, indexed through the Honeycomb table) while mamba
recurrent states live at the slot row — both are handed from prefill to
decode through the same DecodeCache pytree the dry-run lowers.

Page 0 is reserved scratch: idle slots' block tables point at it, so their
(ignored) decode lanes can never corrupt a live page.

This runs for real at CPU smoke scale (tests + examples) and is the same
code path the dry-run lowers at production scale.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import schema as sc
from repro.models import transformer as tf
from repro.models.config import ArchConfig
from repro.serving.kv_cache import PagedKVCache, page_key


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # int32 [S]
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    seq_len: int = 0
    slot: int = -1
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params=None, *, batch_size: int = 4,
                 max_seq: int = 256, page_size: int = 32, seed: int = 0):
        assert max_seq % page_size == 0
        self.cfg = cfg
        self.page_size = page_size
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.pps = max_seq // page_size
        self.params = params if params is not None else sc.init(
            tf.schema(cfg), jax.random.key(seed))
        n_pages = batch_size * self.pps + 1     # +1: reserved scratch page 0
        self.kv = PagedKVCache(n_pages, page_size)
        self.kv.free_pages = list(range(n_pages - 1, 0, -1))  # reserve 0
        cache_tree = sc.stack(
            cfg.n_superblocks,
            tf.layer_cache_schema(cfg, batch_size, self.pps, page_size))

        def mk(path, d):
            names = {getattr(p, "key", None) for p in path}
            if names & {"k_pages", "v_pages"}:   # pool rows = physical pages
                return jnp.zeros((d.shape[0], n_pages, *d.shape[2:]),
                                 d.dtype)
            return jnp.zeros(d.shape, d.dtype)   # mamba states: slot rows

        self._pools = jax.tree_util.tree_map_with_path(
            mk, sc.abstract(cache_tree))
        self._slots: list[int | None] = [None] * batch_size
        self._requests: dict[int, Request] = {}
        self._next_rid = 0
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0}

        self._decode = jax.jit(
            lambda p, cache, toks: tf.decode_step(
                p, cfg, cache, toks, page_size=page_size,
                attn_backend="ref"),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, t, last: tf.prefill(p, cfg, tokens=t,
                                          page_size=page_size,
                                          last_pos=last))

    # ------------------------------------------------------------- intake
    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._requests[rid] = Request(rid, np.asarray(prompt, np.int32),
                                      max_new_tokens=max_new_tokens)
        return rid

    # ------------------------------------------------------------ prefill
    def _prefill_one(self, r: Request, slot: int):
        S = len(r.prompt)
        pad = -S % self.page_size
        toks = np.pad(r.prompt, (0, pad))
        n_blocks = len(toks) // self.page_size
        pages = np.asarray([self.kv.allocate(r.rid, b)
                            for b in range(n_blocks)])
        logits, cache = self._prefill(self.params, toks[None, :],
                                      jnp.int32(S - 1))

        def place(path, pool, new):
            names = {getattr(p, "key", None) for p in path}
            if names & {"k_pages", "v_pages"}:
                # KV pages -> allocated physical page slots
                return pool.at[:, pages].set(new[:, :n_blocks])
            # mamba state [n_sb, 1, ...] -> this request's slot row
            return pool.at[:, slot].set(new[:, 0])

        self._pools = jax.tree_util.tree_map_with_path(
            place, self._pools, cache.layers)
        r.seq_len = S
        r.slot = slot
        self._slots[slot] = r.rid
        r.out_tokens.append(int(np.argmax(np.asarray(logits)[0])))
        self.stats["prefills"] += 1
        self.stats["tokens"] += 1

    # ------------------------------------------------------------- decode
    def _active(self) -> list[Request]:
        return [self._requests[rid] for rid in self._slots
                if rid is not None and not self._requests[rid].done]

    def _decode_batch(self):
        act = self._active()
        if not act:
            return
        B = self.batch_size
        for r in act:   # page for the next token (host-side Honeycomb PUT)
            blk = r.seq_len // self.page_size
            if self.kv.table.get(page_key(r.rid, blk)) is None:
                self.kv.allocate(r.rid, blk)
        bt = np.zeros((B, self.pps), np.int32)
        lens = np.zeros((B,), np.int32)
        toks = np.zeros((B, 1), np.int32)
        rows = self.kv.lookup_block_tables([r.rid for r in act], self.pps)
        for i, r in enumerate(act):
            bt[r.slot] = rows[i]
            lens[r.slot] = r.seq_len
            toks[r.slot, 0] = r.out_tokens[-1]

        cache = tf.DecodeCache(layers=self._pools,
                               block_tables=jnp.asarray(bt),
                               seq_lens=jnp.asarray(lens))
        logits, cache = self._decode(self.params, cache, jnp.asarray(toks))
        self._pools = cache.layers
        out = np.asarray(jnp.argmax(logits, axis=-1))
        for r in act:
            r.seq_len += 1
            r.out_tokens.append(int(out[r.slot]))
            self.stats["tokens"] += 1
            if len(r.out_tokens) >= r.max_new_tokens \
                    or r.seq_len >= self.max_seq - 1:
                r.done = True
                self._slots[r.slot] = None
                self.kv.free_seq(r.rid, -(-(r.seq_len + 1)
                                          // self.page_size))
        self.stats["decode_steps"] += 1

    # ----------------------------------------------------------------- run
    def step(self):
        """One scheduler tick: admit into free slots, then decode."""
        waiting = [r for r in self._requests.values()
                   if r.slot < 0 and not r.done]
        for r in waiting:
            if None not in self._slots:
                break
            self._prefill_one(r, self._slots.index(None))
        self._decode_batch()

    def run_until_done(self, max_ticks: int = 1000):
        for _ in range(max_ticks):
            if all(r.done for r in self._requests.values()):
                break
            self.step()
        return {rid: r.out_tokens for rid, r in self._requests.items()}
