"""Paged KV cache whose page table IS a Honeycomb ordered store.

The paper's read/write split maps directly onto serving:
  * page-table reads (decode-time batched lookups of (seq, block) -> page)
    run on the accelerator path — wait-free batched GETs;
  * page allocation/free (scheduler decisions) are host-side writes
    (PUT/DELETE), exactly the CPU half of the paper;
  * the prefix cache exploits SCAN's floor semantics: keys are rolling-hash
    chains of token prefixes, and "longest cached prefix of this prompt" is
    ``largest key <= K`` — the same primitive the paper built for file-
    offset ranges.

Keys: 16-byte big-endian (seq_id u64, block_idx u64) for pages;
      (hash u64, length u64) for prefixes.  Values: 4-byte page ids.
"""
from __future__ import annotations

import numpy as np

from repro.core import HoneycombConfig, HoneycombStore


def page_key(seq_id: int, block: int) -> bytes:
    return int(seq_id).to_bytes(8, "big") + int(block).to_bytes(8, "big")


def prefix_key(h: int, length: int) -> bytes:
    return int(h & (2 ** 64 - 1)).to_bytes(8, "big") \
        + int(length).to_bytes(8, "big")


def rolling_hashes(tokens: np.ndarray, block: int) -> list[tuple[int, int]]:
    """[(hash, n_tokens)] for every block-aligned prefix."""
    out = []
    h = np.uint64(1469598103934665603)          # FNV offset
    prime = np.uint64(1099511628211)
    with np.errstate(over="ignore"):
        for i, t in enumerate(tokens.tolist()):
            h = np.uint64(h ^ np.uint64(t & 0xFFFFFFFF)) * prime
            if (i + 1) % block == 0:
                out.append((int(h), i + 1))
    return out


class PagedKVCache:
    """Physical page pool + Honeycomb page table."""

    def __init__(self, n_pages: int, page_size: int,
                 cfg: HoneycombConfig | None = None):
        self.n_pages = n_pages
        self.page_size = page_size
        self.free_pages = list(range(n_pages - 1, -1, -1))
        self.table = HoneycombStore(cfg or HoneycombConfig(
            node_cap=64, log_cap=16, n_shortcuts=8, key_words=4))
        self.prefix = HoneycombStore(HoneycombConfig(
            node_cap=64, log_cap=16, n_shortcuts=8, key_words=4))

    # ------------------------------------------------------- allocation
    def allocate(self, seq_id: int, block: int) -> int:
        """Host-side write (the paper's CPU PUT)."""
        if not self.free_pages:
            raise RuntimeError("KV pool exhausted")
        page = self.free_pages.pop()
        self.table.put(page_key(seq_id, block),
                       int(page).to_bytes(4, "big"))
        return page

    def free_seq(self, seq_id: int, n_blocks: int):
        for b in range(n_blocks):
            k = page_key(seq_id, b)
            v = self.table.get(k)
            if v is not None:
                self.table.delete(k)
                self.free_pages.append(int.from_bytes(v, "big"))

    # ----------------------------------------------------- batched reads
    def lookup_block_tables(self, seq_ids: list[int], n_blocks: int
                            ) -> np.ndarray:
        """Accelerator-path batched GET: [len(seq_ids), n_blocks] int32.
        Missing blocks map to page 0 (masked off by seq_lens downstream)."""
        keys = [page_key(s, b) for s in seq_ids for b in range(n_blocks)]
        vals = self.table.get_batch(keys)
        out = np.zeros((len(seq_ids), n_blocks), np.int32)
        i = 0
        for r in range(len(seq_ids)):
            for b in range(n_blocks):
                v = vals[i]
                out[r, b] = int.from_bytes(v, "big") if v is not None else 0
                i += 1
        return out

    # ------------------------------------------------------ prefix cache
    def register_prefix(self, tokens: np.ndarray, seq_id: int):
        """Record every block-aligned prefix of a finished prompt."""
        for h, ln in rolling_hashes(tokens, self.page_size):
            self.prefix.put(prefix_key(h, ln),
                            int(seq_id).to_bytes(8, "big"))

    def longest_cached_prefix(self, tokens: np.ndarray) -> tuple[int, int]:
        """(source seq_id, n_tokens) of the longest cached prefix, or
        (-1, 0).  Floor-SCAN per candidate hash, longest first."""
        cands = rolling_hashes(tokens, self.page_size)
        for h, ln in reversed(cands):
            hits = self.prefix.scan_batch([(prefix_key(h, ln),
                                            prefix_key(h, ln))])[0]
            for k, v in hits:
                if k == prefix_key(h, ln):
                    return int.from_bytes(v, "big"), ln
        return -1, 0

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self.free_pages)
