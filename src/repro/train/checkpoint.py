"""Fault-tolerant checkpointing.

Design points (the 1000-node posture):
  * atomic:   leaves written to ``<dir>.tmp``, manifest last, then a single
              rename publishes the checkpoint — a died writer leaves no
              half-readable state.
  * async:    device->host gather happens on the caller thread (cheap);
              serialization runs on a worker thread so the train loop
              overlaps step N+1 with persisting step N.
  * elastic:  the manifest stores shapes/dtypes + the *logical* tree, not
              shardings.  ``restore`` re-shards onto whatever mesh is alive
              (different data-axis size, different chip count).
  * catalog:  every checkpoint registers into a Honeycomb ordered store
              (step -> path); "resume from the newest checkpoint <= S" is a
              floor SCAN — the paper's own lookup semantics (DESIGN.md §4).
  * retention: keep the newest K checkpoints, delete older ones (and their
              catalog entries) after a successful publish.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import ml_dtypes
import numpy as np

from repro.core import HoneycombConfig, HoneycombStore
from repro.core.keys import int_key

_BF16 = np.dtype(ml_dtypes.bfloat16)


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _to_savable(a: np.ndarray) -> tuple[np.ndarray, str]:
    """numpy can't serialize bfloat16 — persist a uint16 view + dtype tag."""
    if a.dtype == _BF16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _from_savable(a: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        return a.view(_BF16)
    return a


class CheckpointManager:
    def __init__(self, root: str | Path, keep: int = 3,
                 catalog: HoneycombStore | None = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.catalog = catalog or HoneycombStore(
            HoneycombConfig(node_cap=32, log_cap=8, n_shortcuts=4))
        self._worker: threading.Thread | None = None
        self._load_existing()

    def _load_existing(self):
        for d in sorted(self.root.glob("step_*")):
            if (d / "manifest.json").exists():
                step = int(d.name.split("_")[1])
                self.catalog.put(int_key(step), str(d).encode())

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = True,
             extra: dict | None = None) -> Path:
        """Checkpoint a pytree.  With blocking=False the device->host copy
        happens now and serialization happens on a worker thread."""
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]   # device -> host
        final = self.root / f"step_{step:010d}"

        def work():
            tmp = final.with_suffix(".tmp")
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            shapes = []
            for i, a in enumerate(host_leaves):
                savable, dtype = _to_savable(a)
                np.save(tmp / f"leaf_{i:05d}.npy", savable)
                shapes.append([list(a.shape), dtype])
            manifest = {"step": step, "n_leaves": len(host_leaves),
                        "shapes": shapes,
                        "treedef": str(treedef),
                        "extra": extra or {}}
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)                      # atomic publish
            self.catalog.put(int_key(step), str(final).encode())
            self._retain()

        if blocking:
            work()
        else:
            self.wait()
            self._worker = threading.Thread(target=work, daemon=True)
            self._worker.start()
        return final

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _retain(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            d = self.root / f"step_{s:010d}"
            if d.exists():
                shutil.rmtree(d)
            self.catalog.delete(int_key(s))

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        items = self.catalog.scan(int_key(0), int_key(2 ** 62))
        return [int.from_bytes(k, "big") for k, _ in items]

    def latest_step(self, at_or_before: int | None = None) -> int | None:
        """Floor lookup through the Honeycomb catalog (SCAN semantics)."""
        if at_or_before is None:
            steps = self.all_steps()
            return steps[-1] if steps else None
        hit = self.catalog.scan(int_key(at_or_before), int_key(at_or_before))
        return int.from_bytes(hit[0][0], "big") if hit else None

    def restore(self, step: int, like_tree, shardings=None):
        """Load a checkpoint into the structure of ``like_tree``; with
        ``shardings`` (a matching pytree of NamedSharding) the leaves are
        placed sharded — onto any mesh (elastic re-shard)."""
        d = self.root / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        _, treedef = _flatten(like_tree)
        leaves = [_from_savable(np.load(d / f"leaf_{i:05d}.npy"),
                                manifest["shapes"][i][1])
                  for i in range(manifest["n_leaves"])]
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, manifest
