"""AdamW with gradient clipping and an optional cross-pod gradient
compression hook (error-feedback int8) — self-contained, no optax.

Moment tensors inherit the parameter shardings (the schema's logical axes),
so optimizer state is fully sharded alongside FSDP weights.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def abstract_state(abstract_params) -> OptState:
    z = lambda p: jax.ShapeDtypeStruct(p.shape, F32)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                    mu=jax.tree.map(z, abstract_params),
                    nu=jax.tree.map(z, abstract_params))


def state_logical_specs(param_logical_specs) -> OptState:
    from jax.sharding import PartitionSpec as P
    return OptState(step=P(),
                    mu=param_logical_specs, nu=param_logical_specs)


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cosine = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * cosine


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: OptState, params,
           grad_transform: Callable | None = None):
    """One AdamW step.  ``grad_transform`` is the compression / cross-pod
    reduction hook (applied after clipping)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(F32) * scale, grads)
    if grad_transform is not None:
        grads = grad_transform(grads)

    step = state.step + 1
    lr = _schedule(cfg, step.astype(F32))
    b1c = 1 - cfg.b1 ** step.astype(F32)
    b2c = 1 - cfg.b2 ** step.astype(F32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(F32)
        return (p.astype(F32) - lr * step_).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step=step, mu=mu, nu=nu), gnorm
