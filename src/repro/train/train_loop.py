"""Training driver: checkpoint/restart, straggler detection, failure
handling — the parts of a 1000-node deployment that live above the jitted
step.

Fault-tolerance model (documented in README):
  * checkpoint every ``ckpt_every`` steps, async, atomic, cataloged in a
    Honeycomb store (restore = floor lookup, the paper's SCAN semantics);
  * on restart, ``TrainLoop.restore_latest`` re-shards the checkpoint onto
    whatever mesh is alive (elastic: fewer/more data shards);
  * straggler mitigation: per-step wall time tracked against an EMA
    watermark; a step slower than ``straggler_factor``x the EMA raises a
    callback (production: re-dispatch the step on the hot-spare slice /
    exclude the slow host at the next checkpoint boundary).  Here the hook
    is observable state that tests assert on;
  * data-pipeline starvation is surfaced separately (input vs compute).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.data.pipeline import DataPipeline
from repro.core.telemetry import CLOCK
from repro.train import optimizer as opt
from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    ema_alpha: float = 0.2


class TrainLoop:
    def __init__(self, step_fn: Callable, params, opt_state,
                 pipeline: DataPipeline, ckpt: CheckpointManager,
                 cfg: LoopConfig = LoopConfig(),
                 on_straggler: Callable[[int, float], None] | None = None):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.step = int(np.asarray(opt_state.step)) \
            if hasattr(opt_state, "step") else 0
        self.metrics_log: list[dict] = []
        self.straggler_events: list[tuple[int, float]] = []
        self._ema: float | None = None
        self._timed_steps = 0

    # ------------------------------------------------------------ restore
    def restore_latest(self, shardings=None) -> bool:
        s = self.ckpt.latest_step()
        if s is None:
            return False
        (self.params, self.opt_state), _ = self.ckpt.restore(
            s, (self.params, self.opt_state),
            shardings=shardings)
        self.step = s
        self.pipeline.seek(s)       # deterministic data resume
        return True

    # --------------------------------------------------------------- run
    def run(self, steps: int | None = None) -> dict:
        target = self.step + (steps or self.cfg.total_steps)
        while self.step < target:
            batch = next(self.pipeline)
            t0 = CLOCK()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = CLOCK() - t0
            self.step += 1

            self._timed_steps += 1
            if self._timed_steps == 1:
                pass          # first step includes compilation: never seeds
            elif self._ema is None:
                self._ema = dt
            elif dt > self.cfg.straggler_factor * self._ema:
                self.straggler_events.append((self.step, dt))
                if self.on_straggler:
                    self.on_straggler(self.step, dt)
                # slow steps do not poison the watermark
            else:
                a = self.cfg.ema_alpha
                self._ema = (1 - a) * self._ema + a * dt

            if self.step % self.cfg.log_every == 0 or self.step == target:
                self.metrics_log.append(
                    {"step": self.step,
                     "loss": float(np.asarray(metrics["loss"])),
                     "gnorm": float(np.asarray(metrics["gnorm"])),
                     "step_time_s": dt,
                     "starvations": self.pipeline.starvations})
            if self.step % self.cfg.ckpt_every == 0:
                self.ckpt.save(self.step,
                               (self.params, self.opt_state),
                               blocking=False)
        self.ckpt.wait()
        return {"final_step": self.step,
                "final_loss": self.metrics_log[-1]["loss"]
                if self.metrics_log else None,
                "stragglers": len(self.straggler_events)}


def build_smoke_loop(cfg, *, batch: int = 8, seq: int = 64,
                     ckpt_dir: str = "/tmp/repro_ckpt",
                     opt_cfg: opt.AdamWConfig | None = None,
                     loop_cfg: LoopConfig = LoopConfig()):
    """Single-device training loop for a reduced config (examples/tests)."""
    from repro.data.pipeline import SyntheticSource
    from repro.models import schema as sc
    from repro.models import transformer as tf
    import jax.numpy as jnp

    params = sc.init(tf.schema(cfg), jax.random.key(0))
    opt_cfg = opt_cfg or opt.AdamWConfig(lr=1e-3, warmup_steps=10,
                                         total_steps=loop_cfg.total_steps)
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, grads = jax.value_and_grad(tf.lm_loss)(params, cfg, batch)
        new_params, new_opt, gnorm = opt.update(opt_cfg, grads, opt_state,
                                                params)
        return new_params, new_opt, {"loss": loss, "gnorm": gnorm}

    pipe = DataPipeline(SyntheticSource(cfg.vocab), global_batch=batch,
                        seq_len=seq)
    ckpt = CheckpointManager(ckpt_dir, keep=2)
    return TrainLoop(step_fn, params, opt_state, pipe, ckpt, loop_cfg)
