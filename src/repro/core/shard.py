"""StoreShard — one device's slice of the store (tree + resident snapshot).

This is the per-device unit the sharded serving stack is built from: a host
B+Tree writer (``HoneycombTree``), the MVCC/epoch machinery, an interior
cache, and the accelerator read path, all bound to a DOUBLE-BUFFERED
resident device snapshot kept in sync by the incremental delta subsystem
(see core/pipeline.py for the pipeline design):

  * ``begin_export()`` / ``flip()`` — the two halves of the
    host->accelerator synchronization point (the PCIe DMA + page-table
    command analogue).  ``begin_export`` *stages*: the first export
    publishes the packed heap arrays wholesale; afterwards only *dirty
    node rows* plus the batched page-table commands and the read version
    are scattered — asynchronously — into the STANDBY buffer, so sync
    traffic scales with write volume, not store size, and in-flight read
    batches keep answering from the untouched active snapshot.  ``flip``
    *publishes*: an atomic epoch advance that makes the standby active
    (``epoch`` counts flips); old-epoch snapshots are functional device
    copies and keep answering at their pinned read version.
  * ``export_snapshot()`` ≡ ``begin_export(); flip()`` — the serial
    composition, byte-for-byte what the pre-pipeline code did.
    ``SyncStats`` meters both sync modes, plus a log-entry *wire-format*
    estimate (key+value+op per write) so benchmarks can compare dirty-row
    accounting against the paper's append-only log-block encoding.
  * ``cfg.sync_policy`` — when the sync happens: lazily before device reads
    ("on_read"), after every K writes ("every_k"), or only when explicitly
    requested ("explicit", stale-but-consistent reads).  Under "explicit"
    the shard pins an accelerator epoch for the resident snapshot so host
    fallbacks can run at the snapshot's read version (GC keeps the old
    buffers alive until the next export).
  * ``get_batch()/scan_batch()`` — wait-free accelerated reads against the
    shard's snapshot, epoch-stamped so GC never reclaims a buffer an
    in-flight batch might read.  Batch lengths are padded to power-of-two
    buckets so the jit cache stays bounded under ragged per-shard batches.

``HoneycombStore`` (core/store.py) is a single StoreShard behind the public
facade; ``ShardedHoneycombStore`` (core/router.py) range-partitions the
keyspace across many of them.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .api import (OPS_BY_KIND, WIRE_ENTRY_OVERHEAD, Delete, Routing,
                  wire_entry_nbytes)
from .btree import HoneycombTree
from .cache import InteriorCache
from .config import HoneycombConfig, bucket_pow2
from .keys import pack_keys
from .pipeline import PipelineStats
from .read_path import (NODE_FIELDS, GetResult, LegacySnapshotDelta,
                        LegacyTreeSnapshot, ScanResult, SnapshotDelta,
                        TreeSnapshot, apply_snapshot_delta,
                        attach_cache_image, batched_get, batched_scan)
from .schema import NARROWED_FIELDS, NodeImageLayout
from .telemetry import CLOCK, samples_from
from repro.kernels import ops as kernel_ops
# EpochSan seams (repro/analysis/epochsan.py): get() is None unless the
# sanitizer is enabled, so each hook costs one call + None test
from ..analysis import epochsan as _epochsan

# jit the accelerator entry points once per (config, snapshot-shape): the
# eager op-by-op dispatch otherwise accumulates thousands of tiny LLVM JIT
# dylibs across a benchmark run (vm.max_map_count exhaustion)
_jit_get = jax.jit(batched_get, static_argnames="cfg")
_jit_scan = jax.jit(batched_scan, static_argnames="cfg")
# the fused read path (ONE traversal dispatch per batch, cache tier pinned
# in VMEM — kernels/fused_read.py): compiled Pallas on TPU, the jnp oracle
# everywhere else (XLA:CPU lowers it; interpret-mode parity is tested)
_READ_KERNEL_BACKEND = "pallas" if jax.default_backend() == "tpu" else "ref"
_jit_get_fused = jax.jit(kernel_ops.batched_get_fused,
                         static_argnames=("cfg", "lb_fraction", "backend"))
_jit_scan_fused = jax.jit(kernel_ops.batched_scan_fused,
                          static_argnames=("cfg", "lb_fraction", "backend"))
# the delta-sync scatter; NOT donated — old snapshots held by in-flight
# batches must keep answering at their read version.  On TPU the node-field
# scatters fuse into ONE Pallas multi-field kernel call; elsewhere the jnp
# oracle path lowers through XLA (kernels/ops.py dispatch).
_DELTA_BACKEND = "pallas" if jax.default_backend() == "tpu" else None
_jit_apply_delta = jax.jit(apply_snapshot_delta,
                           static_argnames=("backend", "cfg"))

# snapshot fields narrowed to int32 on device (host keeps 64-bit authority)
# — derived from the one layout schema, not hand-kept
_I32_FIELDS = NARROWED_FIELDS

_now = CLOCK            # THE injectable monotonic clock (core/telemetry.py)


@dataclasses.dataclass
class SyncStats:
    snapshots: int = 0            # exports that refreshed the device image
    full_syncs: int = 0           # wholesale republishes
    delta_syncs: int = 0          # incremental scatters
    bytes_synced: int = 0         # host->device array traffic (both modes)
    pagetable_commands: int = 0   # accumulated PCIe page-table updates
    read_version_updates: int = 0  # accumulated PCIe read-version writes
    delta_rows: int = 0           # dirty node rows scattered (cumulative)
    delta_fraction: float = 0.0   # dirty fraction at the last sync
    log_entries: int = 0          # writes accepted (one log entry each)
    log_wire_bytes: int = 0       # append-only wire-format estimate
    #   (key+value+WIRE_ENTRY_OVERHEAD per write) — the paper's log-block
    #   byte accounting, alongside the dirty-row accounting above
    image_dma_count: int = 0      # node-image DMA invocations: the packed
    #   layout issues exactly ONE per dirty node (one per whole image on a
    #   full publish); legacy issues one per field per node — the counter
    #   the layout refactor exists to collapse
    image_bytes: int = 0          # node-image payload bytes (both layouts
    #   carry image_words * 4 per node; the DMA *count* is what differs)
    log_replays: int = 0          # follower stagings applied by replaying
    #   the epoch's op wire stream on device (log_replay_scatter) instead
    #   of re-issuing the primary's image-row DMAs — the log-shipped feed

    def merge(self, other: "SyncStats"):
        """Accumulate another shard's counters (router aggregation)."""
        for f in dataclasses.fields(self):
            if f.name == "delta_fraction":
                self.delta_fraction = max(self.delta_fraction,
                                          other.delta_fraction)
            else:
                setattr(self, f.name,
                        getattr(self, f.name) + getattr(other, f.name))

    def collect(self):
        """Registry samples (core/telemetry.py collect protocol):
        ``sync_*`` counters, ``sync_delta_fraction`` as a gauge."""
        return samples_from(self, "sync", "shard",
                            gauges=("delta_fraction",))


@dataclasses.dataclass
class StagedSync:
    """One ``begin_export`` staging as it crossed the "bus" — the artifact a
    follower replica replays (core/replica.py).

    ``kind`` is "full" or "delta"; ``delta`` carries the dirty-row +
    page-table scatter for delta stagings (None for full publishes) — a
    packed ``SnapshotDelta`` (one image row per dirty node) or a
    ``LegacySnapshotDelta`` (per-field blocks), matching ``cfg.layout``;
    ``snapshot`` is the staged standby itself, which doubles as the catch-up
    source for followers that fell out of sync; ``nbytes`` is the traffic
    this staging metered and ``delta_rows`` the unpadded dirty-row count, so
    per-replica feeding costs O(replicas x dirty_rows) can be accounted
    exactly; ``image_dmas``/``image_bytes`` are the staging's node-image
    DMA invocations and payload bytes (what each follower replay re-issues);
    ``read_version`` is what the standby answers at once flipped.
    """
    kind: str
    snapshot: TreeSnapshot | LegacyTreeSnapshot
    delta: SnapshotDelta | LegacySnapshotDelta | None
    nbytes: int
    delta_rows: int
    read_version: int
    image_dmas: int = 0
    image_bytes: int = 0
    # the log-shipped feed unit: present iff the epoch was replayable (all
    # writes took the leaf fast path — no splits/GC/pt moves/overflow
    # values) and log capture is on.  None means followers must take the
    # image delta (the metered per-epoch fallback).
    log_payload: "LogPayload | None" = None


@dataclasses.dataclass
class LogPayload:
    """One sync epoch's writes, encoded ONCE for every follower lane.

    ``wire`` is the op stream in the exact core/api.py wire format
    (``len(wire)`` equals the epoch's ``SyncStats.log_wire_bytes`` growth —
    encoder and meter share ``wire_entry_nbytes``).  The sidecar vectors
    carry each write's fast-path placement — physical leaf row, log slot,
    backptr, order hint, version delta — which the primary derived from
    its pre-epoch tree state; shipping them (4 B x 5 per entry) spares
    every follower re-deriving placements from a host tree it does not
    have, and keeps replay a pure device scatter.  ``nbytes`` is what one
    follower edge actually moves: wire + sidecar."""
    wire: bytes
    rows: np.ndarray          # [E] int32 physical leaf slot per entry
    slots: np.ndarray         # [E] int32 log slot index per entry
    backptrs: np.ndarray      # [E] int32 sorted-block back pointers
    hints: np.ndarray         # [E] int32 log order hints
    vdeltas: np.ndarray       # [E] int64 version deltas (narrow on device)
    entries: int
    read_version: int
    wire_nbytes: int
    nbytes: int


class StoreShard:
    """One range-shard of the store: its own tree, resident device snapshot,
    incremental delta sync and SyncStats."""

    def __init__(self, cfg: HoneycombConfig | None = None,
                 heap_capacity: int = 1024, shard_id: int = 0):
        self.cfg = cfg or HoneycombConfig()
        self.shard_id = shard_id
        self.tree = HoneycombTree(self.cfg, heap_capacity)
        self.cache = InteriorCache(self.cfg)
        # Section 5: a page-table command for a LID invalidates that LID's
        # cache entry — every remap/free notifies the interior cache, so a
        # stale physical address can never serve from the metadata table
        self.tree.pt.on_remap = self.cache.invalidate
        self.sync_stats = SyncStats()
        self._snapshot: TreeSnapshot | None = None
        self._snapshot_dirty = True
        self._writes_since_sync = 0
        self._sync_deferred = False
        # counter watermarks so multi-sync runs accumulate (not overwrite)
        self._pt_commands_seen = 0
        self._rv_updates_seen = 0
        # array generations the resident snapshot was published against;
        # growth changes shapes and forces a full republish
        self._heap_gen = -1
        self._pt_gen = -1
        # read version the resident snapshot answers at; under "explicit"
        # an accelerator epoch pins it so GC keeps old buffers alive and
        # host fallbacks stay linearizable with the stale device image
        self._snapshot_rv: int | None = None
        self._snapshot_pin: tuple[int, int] | None = None
        # double-buffered snapshot: begin_export() stages the next epoch
        # into the standby buffer (async scatter); flip() publishes it
        self.epoch = 0                    # flips published so far
        self.pipeline_stats = PipelineStats()
        self._standby: TreeSnapshot | None = None
        self._standby_rv: int | None = None
        self._standby_pin: tuple[int, int] | None = None
        # replication hooks (core/replica.py): a ReplicaGroup wires these so
        # EVERY staging/flip — facade-driven, scheduler-driven, or a policy
        # auto-sync — feeds the follower replicas the same payload.  Unset
        # (the unreplicated store) they cost one None check per sync.
        # last_staged describes the CURRENTLY staged (unflipped) standby
        # only; flip() clears it.
        self.last_staged: StagedSync | None = None
        self.on_staged: Callable[[StagedSync], None] | None = None
        self.on_flip: Callable[[], None] | None = None
        self._staged_delta: SnapshotDelta | None = None
        # log-shipped feed capture (core/replica.py sets log_capture when
        # followers ride the "log" feed; the unreplicated store pays one
        # bool check per write).  The epoch log holds (op, placement) per
        # write since the last staging; any write that missed the leaf
        # fast path — or carried an overflow-length value, or a GC pass —
        # poisons the epoch, and its staging falls back to the image delta.
        self.log_capture = False
        self._epoch_log: list = []
        self._epoch_replayable = True
        self._staged_pt_cmds = 0

    # ------------------------------------------------------------- writes
    def put(self, key: bytes, value: bytes, thread: int = 0):
        self.tree.put(key, value, thread)
        self._note_write(key, value, "put")

    def update(self, key: bytes, value: bytes, thread: int = 0):
        self.tree.update(key, value, thread)
        self._note_write(key, value, "update")

    def delete(self, key: bytes, thread: int = 0):
        self.tree.delete(key, thread)
        self._note_write(key, b"", "delete")

    def _note_write(self, key: bytes, value: bytes, kind: str = "put"):
        self._snapshot_dirty = True
        self._writes_since_sync += 1
        self.sync_stats.log_entries += 1
        # the op wire encoder's exact size (core/api.py) — the meter and
        # encode_wire() share one accounting and can never drift
        self.sync_stats.log_wire_bytes += wire_entry_nbytes(key, value)
        if self.log_capture:
            # capture BEFORE any policy auto-sync below, so the staging
            # that this very write triggers still carries it
            self._capture_op(key, value, kind)
        if (self.cfg.sync_policy == "every_k"
                and self._writes_since_sync >= self.cfg.sync_every_k
                and not self._sync_deferred):
            self.export_snapshot()

    def _capture_op(self, key: bytes, value: bytes, kind: str):
        """Append this write to the epoch log for the log-shipped feed.
        A write that missed the fast path (split/merge/underflow — the
        tree shape changed) or stored an overflow-length value (the
        overflow slot id is not derivable from the wire value) poisons
        the epoch: its staging ships the image delta instead."""
        placement = self.tree.last_placement
        if placement is None or len(value) > self.cfg.max_inline_val_bytes:
            self._epoch_replayable = False
            self._epoch_log.clear()
            return
        if self._epoch_replayable:
            op = Delete(key) if kind == "delete" \
                else OPS_BY_KIND[kind](key, value)
            self._epoch_log.append((op, placement))

    @contextlib.contextmanager
    def deferred_sync(self):
        """Suspend automatic policy syncs ("every_k") for a write burst the
        caller will close with ONE batched sync (scheduler.run)."""
        self._sync_deferred = True
        try:
            yield
        finally:
            self._sync_deferred = False

    # ---------------------------------------------------- host-side reads
    def get(self, key: bytes) -> bytes | None:
        return self.tree.get(key)

    def scan(self, lo: bytes, hi: bytes, max_items: int | None = None):
        return self.tree.scan(lo, hi, max_items)

    # ------------------------------------------------------------ routing
    @property
    def serving_version(self) -> int:
        """Read version of the active snapshot — what a device batch that
        just dispatched here answered at (0 before the first publish)."""
        return self._snapshot_rv if self._snapshot_rv is not None else 0

    def routing(self) -> Routing:
        """The single-shard wiring for the service/scheduler (core/api.py):
        everything routes to shard 0, no replica spreading, reads stamped
        with the active snapshot's read version."""
        return Routing(
            shard_of=lambda key: 0,
            replica_of=None,
            report=lambda shard: (0, self.serving_version),
            live_version=lambda shard: int(self.tree.versions.read_version()))

    # ------------------------------------------------- snapshot mechanics
    def begin_export(self, force: bool = False, full: bool = False) -> bool:
        """Stage the host->accelerator sync into the STANDBY buffer (the
        async half of the PCIe analogue).

        After the first wholesale publish, only dirty node rows + batched
        page-table commands + the read version cross the "bus"; ``full=True``
        forces a wholesale republish (benchmarks use it to meter the
        non-amortized traffic), ``force=True`` re-stages even when clean.
        The scatter is enqueued asynchronously; the ACTIVE snapshot keeps
        answering in-flight reads untouched until ``flip()`` publishes the
        standby.  Returns True when a standby was (re)staged."""
        if ((self._snapshot is not None or self._standby is not None)
                and not self._snapshot_dirty and not force and not full):
            return False   # clean, and some epoch (staged or active) exists
        t0 = _now()
        t = self.tree
        h = t.heap
        stats = self.sync_stats
        # accumulate command counters as deltas: multi-sync runs must report
        # total traffic, not the last sync's snapshot of the counters
        stats.pagetable_commands += t.pt.sync_commands - self._pt_commands_seen
        self._pt_commands_seen = t.pt.sync_commands
        stats.read_version_updates += (t.versions.device_updates
                                       - self._rv_updates_seen)
        self._rv_updates_seen = t.versions.device_updates
        stats.snapshots += 1

        # an unflipped standby accumulates further deltas; otherwise the
        # active snapshot is the scatter base
        base = self._standby if self._standby is not None else self._snapshot
        dirty = h.dirty
        frac = len(dirty) / h.capacity
        can_delta = (base is not None and not full
                     and self._heap_gen == h.generation
                     and self._pt_gen == t.pt.generation
                     and frac <= self.cfg.delta_full_threshold)
        # the interior-cache update rides along with the sync DMA: refresh
        # BEFORE publishing so the staged snapshot carries the epoch's cache
        # frontier (cache_lids) and its VMEM tier mirrors the standby
        self.cache.refresh(t)
        bytes0 = stats.bytes_synced
        dmas0, ibytes0 = stats.image_dma_count, stats.image_bytes
        if can_delta:
            snap = self._publish_delta(base,
                                       np.fromiter(sorted(dirty), np.int32,
                                                   len(dirty)))
            stats.delta_syncs += 1
            stats.delta_rows += len(dirty)
            stats.delta_fraction = frac
            staged_kind, staged_rows = "delta", len(dirty)
        else:
            snap = self._publish_full()
            stats.full_syncs += 1
            stats.delta_fraction = 1.0
            staged_kind, staged_rows = "full", 0
        dirty.clear()
        self._heap_gen = h.generation
        self._pt_gen = t.pt.generation
        self._snapshot_dirty = False
        self._writes_since_sync = 0
        self._standby = snap
        # captured host-side (never block on the device scalar): the read
        # version the standby will answer at once flipped
        self._standby_rv = int(t.versions.read_version())
        if self.cfg.sync_policy == "explicit" and self._standby_pin is None:
            # pin an accelerator epoch NOW, while the staged read version is
            # current: garbage deferred from here on stays unreclaimed, so
            # after the flip host fallbacks can still walk version chains
            # back to the standby's read version even if writes landed in
            # the staging window; the pin rolls forward at the next flip
            self._standby_pin = t.epochs.accel_begin_batch(1)
        self.pipeline_stats.staged_exports += 1
        self.pipeline_stats.export_s += _now() - t0
        # replication feed: record what crossed the bus and let the replica
        # group replay it into every follower's standby (after the export
        # meters close, so follower staging never pollutes primary timings)
        self.last_staged = StagedSync(
            kind=staged_kind, snapshot=snap,
            delta=self._staged_delta if staged_kind == "delta" else None,
            nbytes=stats.bytes_synced - bytes0, delta_rows=staged_rows,
            read_version=self._standby_rv,
            image_dmas=stats.image_dma_count - dmas0,
            image_bytes=stats.image_bytes - ibytes0,
            log_payload=self._build_log_payload(staged_kind))
        self._staged_delta = None
        # epoch boundary for the log-shipped feed: whatever happens next
        # belongs to the next staging
        self._epoch_log = []
        self._epoch_replayable = True
        san = _epochsan.get()
        if san is not None:   # tag the standby; audit the cache frontier
            san.note_staged(self, snap)
        if self.on_staged is not None:
            self.on_staged(self.last_staged)
        return True

    def _build_log_payload(self, staged_kind: str) -> LogPayload | None:
        """Encode the epoch's writes ONCE as the wire stream + placement
        sidecar every follower edge ships (the log-shipped feed unit).
        None — the per-epoch fallback — when capture is off, the staging
        was a full publish (bases regress/reshape), the epoch saw a
        non-fast-path write or GC, or page-table commands rode the delta
        (tree shape changed: a log replay could not reproduce them)."""
        if (not self.log_capture or staged_kind != "delta"
                or not self._epoch_replayable or self._staged_pt_cmds):
            return None
        log = self._epoch_log
        E = len(log)
        wire = b"".join(op.encode_wire() for op, _ in log)
        rows = np.fromiter((p[0] for _, p in log), np.int32, E)
        slots = np.fromiter((p[1] for _, p in log), np.int32, E)
        backptrs = np.fromiter((p[2] for _, p in log), np.int32, E)
        hints = np.fromiter((p[3] for _, p in log), np.int32, E)
        vdeltas = np.fromiter((p[4] for _, p in log), np.int64, E)
        sidecar = (rows.nbytes + slots.nbytes + backptrs.nbytes
                   + hints.nbytes + vdeltas.nbytes)
        return LogPayload(
            wire=wire, rows=rows, slots=slots, backptrs=backptrs,
            hints=hints, vdeltas=vdeltas, entries=E,
            read_version=self._standby_rv, wire_nbytes=len(wire),
            nbytes=len(wire) + sidecar)

    def flip(self) -> TreeSnapshot | None:
        """Publish the staged standby as the active snapshot — the atomic
        epoch advance of the double buffer.  Old-epoch snapshots are
        functional device copies, so batches already in flight finish at
        their pinned read version.  No-op when nothing is staged."""
        if self._standby is None:
            return self._snapshot
        self._snapshot = self._standby
        self._snapshot_rv = self._standby_rv
        self._standby = None
        self._standby_rv = None
        self.epoch += 1
        self.pipeline_stats.flips += 1
        old_pin = self._snapshot_pin
        self._snapshot_pin = self._standby_pin
        self._standby_pin = None
        if old_pin is not None:
            self.tree.epochs.accel_complete_batch(*old_pin)
        san = _epochsan.get()
        if san is not None:               # retag the published snapshot
            san.note_flip(self, self._snapshot)
        if self.on_flip is not None:      # replica group: flip the followers
            self.on_flip()
        # the payload only describes the (now published) standby; followers
        # consumed it at staging time — drop it so the delta's device
        # arrays don't outlive the sync on a quiescent store
        self.last_staged = None
        return self._snapshot

    def export_snapshot(self, force: bool = False,
                        full: bool = False) -> TreeSnapshot:
        """Host -> accelerator sync (the PCIe analogue): stage + publish in
        one step — ``begin_export()`` then ``flip()``.  Identical, including
        sync byte counts, to the pre-double-buffer serial behavior."""
        self.begin_export(force=force, full=full)
        return self.flip()   # no-op returning the active snapshot if clean

    def _publish_full(self):
        """Wholesale republish: the whole store crosses the bus — ONE
        contiguous [S, image_words] image DMA on the packed layout, one
        array per field on legacy (same bytes, ~24x the DMA invocations)."""
        t = self.tree
        h = t.heap
        pt_image = t.pt.flush_to_device()
        stats = self.sync_stats
        layout = NodeImageLayout.for_config(self.cfg)
        stats.image_bytes += h.capacity * layout.node_image_bytes

        def dev(a, dtype=None):
            # ALWAYS copy: jnp.asarray is typically zero-copy on the CPU
            # backend, and an aliased snapshot would see in-place host
            # mutations (log appends, GC wipes) — the snapshot must be the
            # immutable device image the paper's DMA produces
            arr = np.asarray(a)
            arr = arr.astype(dtype) if dtype is not None else arr.copy()
            stats.bytes_synced += arr.nbytes
            return jnp.asarray(arr)

        if self.cfg.layout == "packed":
            # pack() marshals every field into contiguous node images — the
            # whole publish is one image transfer (plus the page table)
            img = layout.pack(h)
            stats.bytes_synced += img.nbytes
            stats.image_dma_count += 1
            snap = TreeSnapshot(
                image=jnp.asarray(img),
                pagetable=dev(pt_image),
                root_lid=jnp.int32(t.root_lid),
                read_version=jnp.int32(t.versions.read_version()),
                cache_lids=jnp.asarray(self.cache.device_lids()))
            # materialize the VMEM cache tier device-side from the image
            # just shipped — only the ~KB LID vector crossed the bus
            return attach_cache_image(snap, self.cfg)
        stats.image_dma_count += len(NODE_FIELDS)
        fields = {f: dev(getattr(h, f),
                         np.int32 if f in _I32_FIELDS else None)
                  for f in NODE_FIELDS}
        return LegacyTreeSnapshot(
            pagetable=dev(pt_image),
            root_lid=jnp.int32(t.root_lid),
            read_version=jnp.int32(t.versions.read_version()),
            **fields)

    def _publish_delta(self, base, rows: np.ndarray):
        """Incremental sync: scatter dirty node rows and pending page-table
        commands over ``base`` (the standby-in-progress, or the active
        snapshot when none is staged).  Transfers (and meters) O(dirty)
        bytes instead of O(store); the host-side gathers below copy out of
        the heap eagerly, so later host mutations/GC wipes can never reach
        a staged standby.

        Packed layout: each dirty node is marshalled into ONE contiguous
        image row and issued as a single DMA (``image_dma_count`` grows by
        exactly len(rows) — the acceptance invariant); legacy ships the
        same bytes as one row block per field (~24 DMAs per node)."""
        t = self.tree
        h = t.heap
        stats = self.sync_stats
        layout = NodeImageLayout.for_config(self.cfg)
        pt_lids, pt_phys = t.pt.take_pending()
        # pending LID moves mean the tree shape changed under this epoch —
        # a log replay cannot reproduce them, so the feed must fall back
        self._staged_pt_cmds = len(pt_lids)
        # pad to bucketed sizes with idempotent repeats (duplicate indices
        # carry identical data); when empty, row/lid 0 rewrites itself with
        # its current contents (clean rows match the device image)
        rows_p = self._pad_index(rows, bucket_pow2(len(rows)))
        lids_p = self._pad_index(pt_lids, bucket_pow2(len(pt_lids)))
        phys_p = t.pt.device_image[lids_p]
        # both layouts move image_words * 4 bytes per UNPADDED dirty node
        # (every device field is one u32 word per element); the accounting
        # is identical by construction — only the DMA count differs
        node_bytes = len(rows) * layout.node_image_bytes
        nbytes = pt_lids.nbytes + pt_phys.nbytes + node_bytes
        stats.image_bytes += node_bytes
        if self.cfg.layout == "packed":
            stats.image_dma_count += len(rows)       # ONE DMA per dirty node
            delta = SnapshotDelta(
                rows=jnp.asarray(rows_p),
                image=jnp.asarray(layout.pack(h, rows_p)),
                pt_lids=jnp.asarray(lids_p), pt_phys=jnp.asarray(phys_p),
                root_lid=jnp.int32(t.root_lid),
                read_version=jnp.int32(t.versions.read_version()),
                cache_lids=jnp.asarray(self.cache.device_lids()))
        else:
            stats.image_dma_count += len(rows) * len(NODE_FIELDS)
            fields = {}
            for f in NODE_FIELDS:
                arr = getattr(h, f)[rows_p]
                if f in _I32_FIELDS:
                    arr = arr.astype(np.int32)
                fields[f] = jnp.asarray(arr)
            delta = LegacySnapshotDelta(
                rows=jnp.asarray(rows_p),
                pt_lids=jnp.asarray(lids_p), pt_phys=jnp.asarray(phys_p),
                root_lid=jnp.int32(t.root_lid),
                read_version=jnp.int32(t.versions.read_version()),
                **fields)
        stats.bytes_synced += nbytes
        self._staged_delta = delta   # replayable by follower replicas
        return _jit_apply_delta(base, delta, backend=_DELTA_BACKEND,
                                cfg=self.cfg)

    @staticmethod
    def _pad_index(idx: np.ndarray, size: int) -> np.ndarray:
        idx = np.asarray(idx, np.int32)
        if len(idx) == 0:
            return np.zeros(size, np.int32)
        return np.concatenate(
            [idx, np.full(size - len(idx), idx[-1], np.int32)])

    # ------------------------------------------------- accelerated reads
    def _read_backend_for(self, snap) -> str:
        """Effective backend for one device dispatch.  The fused megakernel
        path needs a packed snapshot with the cache tier attached; legacy
        layouts, cache-less snapshots (e.g. a delta applied without cfg) and
        ``cfg.read_backend="reference"`` all serve through the staged jnp
        reference path."""
        if (self.cfg.read_backend == "fused"
                and isinstance(snap, TreeSnapshot)
                and snap.cache_lids is not None
                and snap.cache_image is not None):
            return "fused"
        return "reference"

    def _note_read_meters(self, meters):
        """Fold one fused dispatch's device meters into CacheStats (the
        dispatching shard accounts follower-served batches too)."""
        m = np.asarray(meters)
        s = self.cache.stats
        s.vmem_hits += int(m[0])
        s.heap_gathers += int(m[1])
        s.lb_routed += int(m[2])

    def _snapshot_for_read(self) -> TreeSnapshot:
        """The snapshot device batches execute against.  "explicit" policy
        reads the resident (possibly stale, always consistent) snapshot;
        the other policies sync lazily here."""
        if self.cfg.sync_policy == "explicit" and self._snapshot is not None:
            return self._snapshot
        return self.export_snapshot()

    def _fallback_read_version(self) -> int | None:
        """Read version for host fallbacks of device requests.  Under
        "explicit" the device image may be stale: fall back at the
        SNAPSHOT's read version (the epoch pin keeps those buffers alive),
        never the live tree — otherwise a truncated SCAN could observe
        writes the rest of its batch cannot (a linearizability hole)."""
        if self.cfg.sync_policy == "explicit" and self._snapshot_rv is not None:
            return self._snapshot_rv
        return None   # snapshot was just exported: live == snapshot version

    def get_batch(self, keys: Sequence[bytes]) -> list[bytes | None]:
        """Batched GET on the accelerator path, epoch-stamped."""
        keys = list(keys)
        if not keys:
            return []
        return self._device_get(self._snapshot_for_read(), keys)

    def _device_get(self, snap: TreeSnapshot,
                    keys: list[bytes]) -> list[bytes | None]:
        """Execute one dense GET batch against ``snap`` — the active
        snapshot, or a follower replica's device image (core/replica.py
        serves followers through the primary's dispatch machinery)."""
        san = _epochsan.get()
        if san is not None:   # reads may never see an unflipped standby
            san.check_read(self, snap)
        # pad ragged batches (router sub-batches) to power-of-two buckets so
        # each (cfg, shapes) compiles once per bucket, not per length
        padded = keys + [keys[0]] * (bucket_pow2(len(keys)) - len(keys))
        self.pipeline_stats.dispatched_lanes += len(keys)
        self.pipeline_stats.padded_lanes += len(padded)
        lanes, lens = pack_keys(padded, self.cfg.key_words)
        rb = self._read_backend_for(snap)
        kernel_ops.record_read_dispatch("get", rb, self.cfg)
        lo, hi = self.tree.epochs.accel_begin_batch(len(keys))
        try:
            if rb == "fused":
                res, meters = _jit_get_fused(
                    snap, jnp.asarray(lanes), jnp.asarray(lens),
                    cfg=self.cfg, lb_fraction=self.cfg.lb_fraction,
                    backend=_READ_KERNEL_BACKEND)
                self._note_read_meters(meters)
            else:
                res = _jit_get(
                    snap, jnp.asarray(lanes), jnp.asarray(lens),
                    cfg=self.cfg)
            found = np.asarray(res.found)
            vals = np.asarray(res.vals)
            vlens = np.asarray(res.vallens)
        finally:
            self.tree.epochs.accel_complete_batch(lo, hi)
        out: list[bytes | None] = []
        for i in range(len(keys)):
            if not found[i]:
                out.append(None)
            else:
                out.append(self._decode_value(vals[i], int(vlens[i])))
        return out

    def scan_batch(self, ranges: Sequence[tuple[bytes, bytes]]
                   ) -> list[list[tuple[bytes, bytes]]]:
        """Batched SCAN on the accelerator path.  Requests the device path
        could not complete (leaf budget/slots) fall back to the host — the
        paper likewise executes some SCANs on CPU cores (Section 6.3).
        Fallbacks run at the snapshot's read version (see
        ``_fallback_read_version``)."""
        ranges = list(ranges)
        if not ranges:
            return []
        snap = self._snapshot_for_read()
        return self._device_scan(snap, ranges, self._fallback_read_version())

    def _device_scan(self, snap: TreeSnapshot,
                     ranges: list[tuple[bytes, bytes]],
                     fallback_rv: int | None
                     ) -> list[list[tuple[bytes, bytes]]]:
        """Execute one dense SCAN batch against ``snap`` (active snapshot or
        a follower replica's image); truncated requests fall back to the
        host tree at ``fallback_rv``."""
        san = _epochsan.get()
        if san is not None:   # reads may never see an unflipped standby
            san.check_read(self, snap)
        pad = [ranges[0]] * (bucket_pow2(len(ranges)) - len(ranges))
        padded = ranges + pad
        self.pipeline_stats.dispatched_lanes += len(ranges)
        self.pipeline_stats.padded_lanes += len(padded)
        lo_l, lo_n = pack_keys([r[0] for r in padded], self.cfg.key_words)
        hi_l, hi_n = pack_keys([r[1] for r in padded], self.cfg.key_words)
        rb = self._read_backend_for(snap)
        kernel_ops.record_read_dispatch("scan", rb, self.cfg)
        slo, shi = self.tree.epochs.accel_begin_batch(len(ranges))
        try:
            if rb == "fused":
                res, meters = _jit_scan_fused(
                    snap, jnp.asarray(lo_l), jnp.asarray(lo_n),
                    jnp.asarray(hi_l), jnp.asarray(hi_n), cfg=self.cfg,
                    lb_fraction=self.cfg.lb_fraction,
                    backend=_READ_KERNEL_BACKEND)
                self._note_read_meters(meters)
            else:
                res = _jit_scan(
                    snap, jnp.asarray(lo_l), jnp.asarray(lo_n),
                    jnp.asarray(hi_l), jnp.asarray(hi_n), cfg=self.cfg)
            count = np.asarray(res.count)
            keys = np.asarray(res.keys)
            klens = np.asarray(res.keylens)
            vals = np.asarray(res.vals)
            vlens = np.asarray(res.vallens)
            trunc = np.asarray(res.truncated)
        finally:
            self.tree.epochs.accel_complete_batch(slo, shi)
        out = []
        for b, (lo, hi) in enumerate(ranges):
            if trunc[b]:
                out.append(self.tree.scan(lo, hi, read_version=fallback_rv))
                continue
            items = []
            for j in range(int(count[b])):
                k = keys[b, j].astype(">u4").tobytes()[: int(klens[b, j])]
                items.append((k, self._decode_value(vals[b, j],
                                                    int(vlens[b, j]))))
            out.append(items)
        return out

    def _decode_value(self, lanes: np.ndarray, length: int) -> bytes:
        if length <= self.cfg.max_inline_val_bytes:
            return lanes.astype(">u4").tobytes()[:length]
        return self.tree.overflow.read(int(lanes[0]))

    # ------------------------------------------------------------- misc
    def collect_garbage(self) -> int:
        san = _epochsan.get()
        # audit the collect against the PRE-collect epoch window: nothing
        # a pinned accelerator/CPU epoch still covers may be reclaimed
        guard = san.gc_begin(self) if san is not None else None
        n = self.tree.gc.collect()
        if guard is not None:
            san.gc_end(self, guard)
        if n:
            # GC wipes freed slots (marking them dirty) and queues LID
            # frees — row mutations no wire entry describes, so the
            # epoch's staging must ship the image delta
            self._epoch_replayable = False
            self._epoch_log.clear()
        return n

    @property
    def stats(self):
        return self.tree.stats

    @property
    def cache_stats(self):
        """The interior cache's meters (Section 5 metadata-table probes
        plus the fused read path's vmem/heap split) — named so the facade
        family shares one accessor (telemetry wiring, router aggregation;
        a ``ReplicaGroup`` reaches it through the primary fallthrough)."""
        return self.cache.stats
