"""LID -> physical slot page table with host and accelerator copies.

The paper (Sections 2, 3.4, 5) keeps the page table in host DRAM and a copy
in FPGA on-board DRAM; the CPU updates the host copy and issues a PCIe
command to update the accelerator copy.  We keep the host copy in numpy and
model the accelerator copy as a *pending update queue*: updates are applied
to the device image at the next snapshot export, and the number of sync
commands is counted (it is the paper's key PCIe-traffic metric — log blocks
exist precisely to amortize it, one sync per merge instead of per write).
"""
from __future__ import annotations

import numpy as np

NULL = -1


class PageTable:
    def __init__(self, capacity: int = 1024):
        self.host = np.full(capacity, NULL, np.int32)
        self._free: list[int] = list(range(capacity - 1, -1, -1))
        self.pending: dict[int, int] = {}   # LID -> phys, not yet on device
        self.sync_commands = 0              # paper: PCIe page-table updates
        self.device_image = self.host.copy()
        # bumped on growth: a resident device table has the old shape and
        # must be republished in full
        self.generation = 0
        # remap observer (paper Section 5: a page-table command for a LID
        # invalidates that LID's interior-cache entry); the owning shard
        # wires this to InteriorCache.invalidate
        self.on_remap = None

    def _grow(self):
        cap = len(self.host)
        self.host = np.concatenate([self.host, np.full(cap, NULL, np.int32)])
        self.device_image = np.concatenate(
            [self.device_image, np.full(cap, NULL, np.int32)])
        self._free.extend(range(2 * cap - 1, cap - 1, -1))
        self.generation += 1

    def alloc_lid(self, phys: int) -> int:
        if not self._free:
            self._grow()
        lid = self._free.pop()
        self.host[lid] = phys
        self.pending[lid] = phys
        self.sync_commands += 1
        return lid

    def remap(self, lid: int, phys: int):
        """Atomic subtree swap (paper Fig. 3c / 4c): one mapping change makes
        a whole new buffer (or subtree) visible."""
        self.host[lid] = phys
        self.pending[lid] = phys
        self.sync_commands += 1
        if self.on_remap is not None:
            self.on_remap(lid)

    def free_lid(self, lid: int):
        self.host[lid] = NULL
        self.pending[lid] = NULL
        self._free.append(lid)
        if self.on_remap is not None:
            self.on_remap(lid)

    def lookup(self, lid: int) -> int:
        return int(self.host[lid])

    def take_pending(self) -> tuple[np.ndarray, np.ndarray]:
        """Drain the pending update queue as (lids, phys) command arrays —
        the batched PCIe page-table commands of one sync — applying them to
        the device image."""
        lids = np.fromiter(self.pending.keys(), np.int32, len(self.pending))
        phys = np.fromiter(self.pending.values(), np.int32, len(self.pending))
        self.device_image[lids] = phys
        self.pending.clear()
        return lids, phys

    def flush_to_device(self) -> np.ndarray:
        """Apply pending updates to the accelerator image (the 'PCIe
        commands' batch) and return it."""
        self.take_pending()
        return self.device_image

    @property
    def n_live(self) -> int:
        return int((self.host != NULL).sum())
