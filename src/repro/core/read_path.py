"""Batched wait-free GET/SCAN — the B-Tree accelerator (paper Section 4).

This is the pure-JAX (jit/dry-run) implementation of the interior-node search
engine (KSU ring) and the leaf-node scan engine (RSU ring).  The Pallas
kernels in ``repro.kernels`` implement the same contracts for TPU; this module
is their oracle and the path XLA:CPU can lower.

Faithfulness map:
  * request-level parallelism  -> the batch dimension B (every lane is an
    independent request; no head-of-line blocking between lanes).
  * KSU shortcut search        -> gather ONLY the shortcut block, then gather
    ONLY the selected sorted-block segment (bytes-touched matches Section 3.1).
  * wait-free MVCC reads       -> bounded old-version chain walk; a jitted
    batch executes against an immutable array snapshot, which also realizes
    the NAT guarantee (a request can never observe a half-swapped node).
  * RSU order-hint log sort    -> shift-register simulation, one vector step
    per log entry, no key comparisons (Section 4.3, Figs. 7-8).
  * merged emission            -> ranks derived from back pointers + hint
    order; equal keys come out adjacent and are resolved to the newest
    visible version (delete markers drop the key).

All shapes are static; versions are int32 on device (the paper uses 64-bit
with 5-byte log deltas; 32-bit covers any single snapshot's window and the
host keeps the authoritative 64-bit counters).

Snapshot layouts: the default device-resident representation is the PACKED
node image (core/schema.py) — one contiguous ``[S, image_words]`` u32 array
holding every per-node field at a static word offset, the reproduction's
analogue of the paper's contiguous 8 KB node buffer.  The pre-packing
per-field representation survives as ``LegacyTreeSnapshot`` /
``LegacySnapshotDelta`` (selected by ``cfg.layout="legacy"``) and is the
parity reference the equivalence tests hold the packed layout to.  All
search/scan code below is layout-agnostic: it reads fields through
``snapshot_fields()``, which decodes packed images via the layout's static
offsets and passes legacy snapshots through untouched.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import HoneycombConfig
from .heap import LEAF, LOG_DELETE, NULL
from .keys import jax_key_cmp
from .schema import FIELD_NAMES, NodeImageLayout


class TreeSnapshot(NamedTuple):
    """Immutable device image of the store: ONE packed node-image array
    (every per-node field at its static layout offset — core/schema.py)
    plus the page table and the two sync scalars.

    ``cache_lids``/``cache_image`` are the device cache tier (paper
    Section 5): the root + top interior levels packed contiguously so the
    fused read kernels pin them in VMEM and resolve the first levels with
    zero heap-image gathers.  Only ``cache_lids`` travels on the sync
    feeds (~KB); ``cache_image`` is rebuilt device-side from the resident
    image via ``attach_cache_image`` wherever a snapshot is (re)staged, so
    its rows are bit-identical to the version-resolved heap rows by
    construction.  ``None`` on legacy-era snapshots (fused reads fall back
    to the reference path)."""
    image: jax.Array        # u32 [S, image_words] packed node images
    pagetable: jax.Array    # i32 [LIDS]
    root_lid: jax.Array     # i32 []
    read_version: jax.Array  # i32 []
    cache_lids: jax.Array | None = None   # i32 [C], NULL-padded
    cache_image: jax.Array | None = None  # u32 [C, image_words]


class LegacyTreeSnapshot(NamedTuple):
    """Per-field device image (the pre-packing layout, cfg.layout="legacy"):
    kept as the packed layout's op-for-op parity reference."""
    ntype: jax.Array        # i32 [S]
    nitems: jax.Array       # i32 [S]
    version: jax.Array      # i32 [S]
    oldptr: jax.Array       # i32 [S]
    left_child: jax.Array   # i32 [S]
    lsib: jax.Array         # i32 [S]
    rsib: jax.Array         # i32 [S]
    skeys: jax.Array        # u32 [S, N, KW]
    skeylen: jax.Array      # i32 [S, N]
    svals: jax.Array        # u32 [S, N, VW]
    svallen: jax.Array      # i32 [S, N]
    n_shortcuts: jax.Array  # i32 [S]
    sc_keys: jax.Array      # u32 [S, NSC, KW]
    sc_keylen: jax.Array    # i32 [S, NSC]
    sc_pos: jax.Array       # i32 [S, NSC]
    nlog: jax.Array         # i32 [S]
    log_keys: jax.Array     # u32 [S, L, KW]
    log_keylen: jax.Array   # i32 [S, L]
    log_vals: jax.Array     # u32 [S, L, VW]
    log_vallen: jax.Array   # i32 [S, L]
    log_op: jax.Array       # i32 [S, L]
    log_backptr: jax.Array  # i32 [S, L]
    log_hint: jax.Array     # i32 [S, L]
    log_vdelta: jax.Array   # i32 [S, L]
    pagetable: jax.Array    # i32 [LIDS]
    root_lid: jax.Array     # i32 []
    read_version: jax.Array  # i32 []


# per-node-row snapshot fields, in layout order — derived from the ONE
# schema (core/schema.py), not re-enumerated
NODE_FIELDS = FIELD_NAMES


class SnapshotFields:
    """Layout-agnostic per-field view of a snapshot.

    For a packed ``TreeSnapshot`` each attribute is a static column slice
    of the image decoded to the field's device dtype (bitcast for signed
    fields, so NULL = -1 survives the u32 transit); XLA folds the slices
    into the downstream gathers, so the search engines read exactly the
    bytes they always did.  Legacy snapshots already expose the attributes
    and pass through ``snapshot_fields`` untouched.
    """
    __slots__ = FIELD_NAMES + ("pagetable", "root_lid", "read_version")

    def __init__(self, **fields):
        for k, v in fields.items():
            object.__setattr__(self, k, v)


def snapshot_fields(snap, cfg: HoneycombConfig):
    """Adapt any snapshot (packed, legacy, or an existing view) to
    per-field attribute access."""
    if isinstance(snap, TreeSnapshot):
        layout = NodeImageLayout.for_config(cfg)
        return SnapshotFields(pagetable=snap.pagetable,
                              root_lid=snap.root_lid,
                              read_version=snap.read_version,
                              **layout.field_views(snap.image))
    return snap


def attach_cache_image(snap, cfg: HoneycombConfig):
    """(Re)build the snapshot's contiguous cache tier from its own heap
    image: one version-resolved image row per cached LID, zeros in the
    NULL-padded slots.

    Called wherever a snapshot is staged — primary export, delta apply,
    follower log replay — so only the ~KB ``cache_lids`` vector ever
    travels on a feed while every serving copy's ``cache_image`` rows stay
    bit-identical to the heap rows the reference path would resolve (the
    invariant the fused≡reference equivalence rests on)."""
    if not isinstance(snap, TreeSnapshot) or snap.cache_lids is None:
        return snap
    view = snapshot_fields(snap, cfg)
    lids = snap.cache_lids
    phys = snap.pagetable[jnp.maximum(lids, 0)]
    phys = _resolve_version(view, jnp.maximum(phys, 0),
                            snap.read_version, cfg)
    rows = jnp.where((lids != NULL)[:, None], snap.image[phys],
                     jnp.uint32(0))
    return snap._replace(cache_image=rows)


class SnapshotDelta(NamedTuple):
    """One host->device sync's worth of changed state for the packed
    layout (paper Sections 3-4: node-buffer DMAs + batched page-table
    commands + read-version update).

    ``rows`` are the dirty physical slots; ``image`` carries each dirty
    node's ENTIRE packed image row — one contiguous DMA per dirty node,
    the paper's whole-node transfer unit.  Rows may repeat (padding to a
    bucketed size keeps the jit cache small); repeated rows carry
    identical data, so the scatter is idempotent.
    """
    rows: jax.Array          # i32 [D] dirty physical slots
    image: jax.Array         # u32 [D, image_words] replacement node images
    pt_lids: jax.Array       # i32 [P] page-table command targets
    pt_phys: jax.Array       # i32 [P] new mappings (may repeat, identical)
    root_lid: jax.Array      # i32 []
    read_version: jax.Array  # i32 []
    cache_lids: jax.Array | None = None  # i32 [C] next epoch's cache tier


class LegacySnapshotDelta(NamedTuple):
    """Per-field delta (cfg.layout="legacy"): one [D, ...] update block per
    node field — ~24 row scatters per sync, the traffic shape the packed
    layout collapses to one."""
    rows: jax.Array          # i32 [D] dirty physical slots
    ntype: jax.Array         # i32 [D]
    nitems: jax.Array        # i32 [D]
    version: jax.Array       # i32 [D]
    oldptr: jax.Array        # i32 [D]
    left_child: jax.Array    # i32 [D]
    lsib: jax.Array          # i32 [D]
    rsib: jax.Array          # i32 [D]
    skeys: jax.Array         # u32 [D, N, KW]
    skeylen: jax.Array       # i32 [D, N]
    svals: jax.Array         # u32 [D, N, VW]
    svallen: jax.Array       # i32 [D, N]
    n_shortcuts: jax.Array   # i32 [D]
    sc_keys: jax.Array       # u32 [D, NSC, KW]
    sc_keylen: jax.Array     # i32 [D, NSC]
    sc_pos: jax.Array        # i32 [D, NSC]
    nlog: jax.Array          # i32 [D]
    log_keys: jax.Array      # u32 [D, L, KW]
    log_keylen: jax.Array    # i32 [D, L]
    log_vals: jax.Array      # u32 [D, L, VW]
    log_vallen: jax.Array    # i32 [D, L]
    log_op: jax.Array        # i32 [D, L]
    log_backptr: jax.Array   # i32 [D, L]
    log_hint: jax.Array      # i32 [D, L]
    log_vdelta: jax.Array    # i32 [D, L]
    pt_lids: jax.Array       # i32 [P] page-table command targets
    pt_phys: jax.Array       # i32 [P] new mappings (may repeat, identical)
    root_lid: jax.Array      # i32 []
    read_version: jax.Array  # i32 []


def apply_snapshot_delta(snap, delta, *, backend: str | None = None,
                         cfg: HoneycombConfig | None = None):
    """Scatter one sync's dirty rows + page-table commands into a resident
    device snapshot, yielding the next snapshot.

    Functional on purpose: the input snapshot's buffers are never donated,
    so old snapshots held by in-flight batches keep answering at their read
    version (wait-free MVCC).  Dispatches on the delta's layout:

      * packed ``SnapshotDelta`` — ONE image-row scatter patches every
        field of a dirty node in a single contiguous DMA
        (``repro.kernels.delta_scatter.snapshot_image_scatter`` on
        ``"pallas"``/``"interpret"``; ``backend=None`` is the jnp oracle
        XLA:CPU lowers, kept as the parity reference);
      * ``LegacySnapshotDelta`` — the per-field path: ``backend=None``
        scatters field by field, the kernel backends fuse all fields into
        one multi-field Pallas call (``snapshot_multi_scatter``).

    For packed deltas ``cfg`` enables the cache tier: the delta's
    ``cache_lids`` replace the snapshot's and the contiguous cache image is
    rebuilt from the patched heap image (``attach_cache_image``) inside the
    same jitted apply.  Without ``cfg`` the cache image is dropped (fused
    reads then fall back to the reference path) rather than served stale.
    """
    if isinstance(delta, SnapshotDelta):
        if backend is None:
            image = snap.image.at[delta.rows].set(delta.image)
        else:
            from repro.kernels import ops  # deferred: kernels.ref imports us
            image = ops.snapshot_image_scatter(snap.image, delta.rows,
                                               delta.image, backend=backend)
        cache_lids = snap.cache_lids if delta.cache_lids is None \
            else delta.cache_lids
        nxt = snap._replace(
            image=image,
            pagetable=snap.pagetable.at[delta.pt_lids].set(delta.pt_phys),
            root_lid=delta.root_lid, read_version=delta.read_version,
            cache_lids=cache_lids)
        if cfg is not None:
            return attach_cache_image(nxt, cfg)
        return nxt._replace(cache_image=None)
    if backend is None:
        upd = {f: getattr(snap, f).at[delta.rows].set(getattr(delta, f))
               for f in NODE_FIELDS}
    else:
        from repro.kernels import ops  # deferred: kernels.ref imports us
        shapes = [getattr(snap, f).shape for f in NODE_FIELDS]
        dsts = [getattr(snap, f).reshape(s[0], -1)
                for f, s in zip(NODE_FIELDS, shapes)]
        upds = [getattr(delta, f).reshape(getattr(delta, f).shape[0], -1)
                for f in NODE_FIELDS]
        outs = ops.snapshot_multi_scatter(dsts, delta.rows, upds,
                                          backend=backend)
        upd = {f: o.reshape(s)
               for f, o, s in zip(NODE_FIELDS, outs, shapes)}
    return snap._replace(
        pagetable=snap.pagetable.at[delta.pt_lids].set(delta.pt_phys),
        root_lid=delta.root_lid, read_version=delta.read_version, **upd)


class ScanResult(NamedTuple):
    count: jax.Array       # i32 [B] items emitted
    keys: jax.Array        # u32 [B, M, KW]
    keylens: jax.Array     # i32 [B, M]
    vals: jax.Array        # u32 [B, M, VW]
    vallens: jax.Array     # i32 [B, M]
    truncated: jax.Array   # bool [B] (ran out of result slots / leaf budget)


class GetResult(NamedTuple):
    found: jax.Array       # bool [B]
    vals: jax.Array        # u32 [B, VW]
    vallens: jax.Array     # i32 [B]


# --------------------------------------------------------------------------
# interior-node search engine (KSU)
# --------------------------------------------------------------------------

def _resolve_version(snap: SnapshotFields, phys: jax.Array, rv: jax.Array,
                     cfg: HoneycombConfig) -> jax.Array:
    """Follow old-version pointers until node version <= rv (Section 3.2).
    Bounded walk; wait-free (no locks, no retries)."""
    def step(_, p):
        too_new = (snap.version[p] > rv) & (snap.oldptr[p] != NULL)
        return jnp.where(too_new, snap.oldptr[p], p)
    return jax.lax.fori_loop(0, cfg.max_version_chain, step, phys)


def _shortcut_floor(snap: SnapshotFields, phys: jax.Array, key: jax.Array,
                    klen: jax.Array) -> jax.Array:
    """Largest shortcut index whose key <= query (0 if none: the query then
    falls below the first segment and the segment search yields -1)."""
    sck = snap.sc_keys[phys]          # [B, NSC, KW]
    scl = snap.sc_keylen[phys]        # [B, NSC]
    nsc = snap.n_shortcuts[phys]      # [B]
    c = jax_key_cmp(sck, scl, key[:, None, :], klen[:, None])
    valid = jnp.arange(sck.shape[1])[None, :] < nsc[:, None]
    leq = (c <= 0) & valid
    # last True index, 0 when none
    idx = jnp.where(leq, jnp.arange(sck.shape[1])[None, :], -1).max(axis=1)
    return jnp.maximum(idx, 0)


def _segment_floor(snap: SnapshotFields, phys: jax.Array, seg: jax.Array,
                   key: jax.Array, klen: jax.Array,
                   cfg: HoneycombConfig) -> jax.Array:
    """Floor item index within the selected segment; -1 when the query is
    below every key in the node.  Gathers ONLY the segment (bytes-touched
    parity with the paper's DMA of one segment)."""
    base = snap.sc_pos[phys, seg]                       # [B]
    offs = base[:, None] + jnp.arange(cfg.segment_items)[None, :]
    n = snap.nitems[phys]
    offs_c = jnp.minimum(offs, cfg.node_cap - 1)
    seg_keys = snap.skeys[phys[:, None], offs_c]        # [B, seg, KW]
    seg_lens = snap.skeylen[phys[:, None], offs_c]
    valid = offs < n[:, None]
    c = jax_key_cmp(seg_keys, seg_lens, key[:, None, :], klen[:, None])
    leq = (c <= 0) & valid
    local = jnp.where(leq, jnp.arange(cfg.segment_items)[None, :], -1).max(axis=1)
    return jnp.where(local >= 0, base + local, -1)


def descend(snap, key: jax.Array, klen: jax.Array,
            cfg: HoneycombConfig) -> jax.Array:
    """Traverse interior nodes root->leaf for a batch.  Returns the resolved
    physical slot of the leaf each request lands in.  Accepts any snapshot
    layout (fields resolved via the static layout offsets when packed)."""
    snap = snapshot_fields(snap, cfg)
    B = key.shape[0]
    rv = snap.read_version
    lid = jnp.broadcast_to(snap.root_lid, (B,))

    def level(_, state):
        lid, phys, done = state
        cur = _resolve_version(snap, snap.pagetable[lid], rv, cfg)
        cur = jnp.where(done, phys, cur)
        is_leaf = snap.ntype[cur] == LEAF
        seg = _shortcut_floor(snap, cur, key, klen)
        idx = _segment_floor(snap, cur, seg, key, klen, cfg)
        child = jnp.where(idx >= 0,
                          snap.svals[cur, jnp.maximum(idx, 0), 0].astype(jnp.int32),
                          snap.left_child[cur])
        new_done = done | is_leaf
        new_lid = jnp.where(new_done, lid, child)
        return new_lid, jnp.where(done, phys, cur), new_done

    _, phys, _ = jax.lax.fori_loop(
        0, cfg.max_height,
        level, (lid, jnp.zeros((B,), jnp.int32), jnp.zeros((B,), bool)))
    return phys


def fused_view(snap: TreeSnapshot, cfg: HoneycombConfig) -> SnapshotFields:
    """Field view over the heap image CONCATENATED with the snapshot's
    cache image: combined row indices >= S address cache rows.  Because
    cache rows are bit-identical to their version-resolved heap rows
    (``attach_cache_image``), any search code running on this view yields
    the same results whether a level resolved from the cache or the heap —
    THE structural argument behind fused ≡ reference."""
    layout = NodeImageLayout.for_config(cfg)
    combined = jnp.concatenate([snap.image, snap.cache_image], axis=0)
    return SnapshotFields(pagetable=snap.pagetable, root_lid=snap.root_lid,
                          read_version=snap.read_version,
                          **layout.field_views(combined))


def lb_routed_lanes(lane: jax.Array, lb_fraction: float) -> jax.Array:
    """Deterministic Section-5 dual-pipe routing: lanes whose index mod 16
    falls under round(lb_fraction * 16) send their cache-hit lookups down
    the heap pipe anyway.  Compile-time constant per lb_fraction, identical
    between the jnp oracle (lane = arange over the batch) and the Pallas
    kernels (lane = program id), so routing never perturbs results."""
    return (lane % 16) < int(round(lb_fraction * 16))


def descend_fused(snap: TreeSnapshot, view: SnapshotFields, key: jax.Array,
                  klen: jax.Array, cfg: HoneycombConfig, *,
                  lb_fraction: float = 0.0):
    """Cache-tiered descend (the fused path's oracle): a level whose LID is
    in the snapshot's cache tier resolves straight to its cache row
    (combined index S + slot — no pagetable lookup, no MVCC walk, zero heap
    gathers), everything below the cached frontier falls through to the
    heap path, and an ``lb_fraction`` slice of cache-HIT lanes is routed to
    the heap pipe anyway (Section 5's load balancer: identical results,
    different byte split).  ``view`` must be ``fused_view(snap, cfg)``.

    Returns (leaf phys in the combined view, meters i32[3] =
    [vmem_hits, heap_gathers, lb_routed] counted over traversed levels).
    """
    S = snap.image.shape[0]
    clids = snap.cache_lids
    B = key.shape[0]
    rv = view.read_version
    lid = jnp.broadcast_to(view.root_lid, (B,))
    routed_lane = lb_routed_lanes(jnp.arange(B), lb_fraction)

    def level(_, state):
        lid, phys, done, vh, hg, lr = state
        eq = clids[None, :] == lid[:, None]
        hit = eq.any(axis=1) & (lid != NULL)
        slot = jnp.argmax(eq, axis=1).astype(jnp.int32)
        use_cache = hit & ~routed_lane
        heap_phys = _resolve_version(view, view.pagetable[lid], rv, cfg)
        cur = jnp.where(use_cache, S + slot, heap_phys)
        cur = jnp.where(done, phys, cur)
        live = ~done
        vh = vh + (use_cache & live).sum(dtype=jnp.int32)
        hg = hg + (~use_cache & live).sum(dtype=jnp.int32)
        lr = lr + (hit & routed_lane & live).sum(dtype=jnp.int32)
        is_leaf = view.ntype[cur] == LEAF
        seg = _shortcut_floor(view, cur, key, klen)
        idx = _segment_floor(view, cur, seg, key, klen, cfg)
        child = jnp.where(idx >= 0,
                          view.svals[cur, jnp.maximum(idx, 0), 0]
                          .astype(jnp.int32),
                          view.left_child[cur])
        new_done = done | is_leaf
        new_lid = jnp.where(new_done, lid, child)
        return (new_lid, jnp.where(done, phys, cur), new_done, vh, hg, lr)

    z = jnp.zeros((), jnp.int32)
    _, phys, _, vh, hg, lr = jax.lax.fori_loop(
        0, cfg.max_height, level,
        (lid, jnp.zeros((B,), jnp.int32), jnp.zeros((B,), bool), z, z, z))
    return phys, jnp.stack([vh, hg, lr])


# --------------------------------------------------------------------------
# leaf-node scan engine (RSU)
# --------------------------------------------------------------------------

def log_sort_positions(hints: jax.Array, nlog: jax.Array,
                       log_cap: int) -> jax.Array:
    """Shift-register sort of the log block using order hints (Fig. 8).

    hints: i32 [B, L]; returns pos [B, L] — the position of each log entry in
    ascending key order.  One vector step per entry, no key comparisons,
    mirroring the paper's one-cycle-per-item hardware sort.
    """
    B, L = hints.shape

    def insert(j, pos):
        # entries already placed at positions >= hints[:, j] shift right
        placed = jnp.arange(L)[None, :] < j
        active = placed & (j < nlog)[:, None]
        shift = active & (pos >= hints[:, j][:, None])
        pos = pos + shift.astype(pos.dtype)
        return pos.at[:, j].set(jnp.where(j < nlog, hints[:, j], pos[:, j]))

    del log_cap  # L is static from the shape
    pos0 = jnp.zeros((B, L), hints.dtype)
    return jax.lax.fori_loop(0, L, insert, pos0)


def _resolve_leaf(snap: SnapshotFields, phys: jax.Array,
                  cfg: HoneycombConfig):
    """Merged, shadow-resolved enumeration of one leaf per request.

    Returns (keys [B,T,KW], keylens, vals [B,T,VW], vallens, live [B,T]) in
    ascending key order, where T = node_cap + log_cap.  ``live`` marks items
    that survive MVCC filtering and delete markers.
    """
    c = cfg
    N, L = c.node_cap, c.log_cap
    T = N + L
    rv = snap.read_version
    nv = snap.version[phys]                    # [B]
    nit = snap.nitems[phys]
    nlg = snap.nlog[phys]

    # --- RSU log sort via order hints -------------------------------------
    hints = snap.log_hint[phys].astype(jnp.int32)          # [B, L]
    logpos = log_sort_positions(hints, nlg, L)             # [B, L]

    # merged rank: log entries go right before the sorted item their back
    # pointer names; hint order breaks ties among them (Section 4.3)
    rank_log = snap.log_backptr[phys] * (L + 1) + logpos   # [B, L]
    rank_sorted = jnp.arange(N)[None, :] * (L + 1) + L     # [1, N]

    svis = jnp.arange(N)[None, :] < nit[:, None]
    lvis_slot = jnp.arange(L)[None, :] < nlg[:, None]
    lver = nv[:, None] + snap.log_vdelta[phys]
    lvis = lvis_slot & (lver <= rv)

    keys = jnp.concatenate([snap.skeys[phys], snap.log_keys[phys]], axis=1)
    klens = jnp.concatenate([snap.skeylen[phys], snap.log_keylen[phys]], axis=1)
    vals = jnp.concatenate([snap.svals[phys], snap.log_vals[phys]], axis=1)
    vlens = jnp.concatenate([snap.svallen[phys], snap.log_vallen[phys]], axis=1)
    vers = jnp.concatenate(
        [jnp.broadcast_to(nv[:, None], (nv.shape[0], N)), lver], axis=1)
    isdel = jnp.concatenate(
        [jnp.zeros((nv.shape[0], N), bool),
         snap.log_op[phys] == LOG_DELETE], axis=1)
    vis = jnp.concatenate([svis, lvis], axis=1)
    slot_used = jnp.concatenate([svis, lvis_slot], axis=1)
    rank = jnp.concatenate(
        [jnp.broadcast_to(rank_sorted, (nv.shape[0], N)), rank_log], axis=1)
    rank = jnp.where(slot_used, rank, jnp.iinfo(jnp.int32).max)

    # order by rank (stable, ranks of used slots are unique)
    order = jnp.argsort(rank, axis=1)
    take = lambda a: jnp.take_along_axis(
        a, order.reshape(order.shape + (1,) * (a.ndim - 2)), axis=1)
    keys, klens = take(keys), jnp.take_along_axis(klens, order, axis=1)
    vals, vlens = take(vals), jnp.take_along_axis(vlens, order, axis=1)
    vers = jnp.take_along_axis(vers, order, axis=1)
    isdel = jnp.take_along_axis(isdel, order, axis=1)
    vis = jnp.take_along_axis(vis, order, axis=1)
    used = jnp.take_along_axis(slot_used, order, axis=1)

    # --- shadow resolution: equal keys are adjacent; newest visible wins ---
    same_prev = (jax_key_cmp(keys[:, 1:], klens[:, 1:],
                             keys[:, :-1], klens[:, :-1]) == 0) \
        & used[:, 1:] & used[:, :-1]
    run_id = jnp.concatenate(
        [jnp.zeros((keys.shape[0], 1), jnp.int32),
         jnp.cumsum(~same_prev, axis=1).astype(jnp.int32)], axis=1)
    vmask = jnp.where(vis, vers, jnp.iinfo(jnp.int32).min)
    # per-run max version via scatter-max into T bins (run_id < T)
    seg_max = jnp.full((keys.shape[0], T), jnp.iinfo(jnp.int32).min,
                       jnp.int32)
    seg_max = seg_max.at[jnp.arange(keys.shape[0])[:, None], run_id].max(vmask)
    winner = vis & (vmask == seg_max[jnp.arange(keys.shape[0])[:, None],
                                     run_id])
    live = winner & ~isdel
    return keys, klens, vals, vlens, live


def batched_scan(snap, lo: jax.Array, lolen: jax.Array,
                 hi: jax.Array, hilen: jax.Array,
                 cfg: HoneycombConfig) -> ScanResult:
    """SCAN(K_l, K_u) for a batch: floor-start semantics, forward across
    sibling leaves with bounded budget (Section 3.3).  Layout-agnostic:
    packed snapshots are read through static image offsets."""
    snap = snapshot_fields(snap, cfg)
    leaf0 = descend(snap, lo, lolen, cfg)
    return scan_from_leaf(snap, leaf0, lo, lolen, hi, hilen, cfg)


def scan_from_leaf(snap: SnapshotFields, leaf0: jax.Array,
                   lo: jax.Array, lolen: jax.Array,
                   hi: jax.Array, hilen: jax.Array,
                   cfg: HoneycombConfig) -> ScanResult:
    """The scan engine proper, starting from pre-descended leaf slots —
    shared verbatim between the reference path (heap-view ``snap``, heap
    ``leaf0``) and the fused oracle (combined cache+heap view,
    ``descend_fused`` leaf slots), so the two paths cannot drift."""
    c = cfg
    B = lo.shape[0]
    M = c.max_scan_items
    KW, VW = c.key_words, c.val_words
    T = c.node_cap + c.log_cap
    rv = snap.read_version

    out_keys = jnp.zeros((B, M, KW), jnp.uint32)
    out_klens = jnp.zeros((B, M), jnp.int32)
    out_vals = jnp.zeros((B, M, VW), jnp.uint32)
    out_vlens = jnp.zeros((B, M), jnp.int32)
    count = jnp.zeros((B,), jnp.int32)
    trunc = jnp.zeros((B,), bool)
    rows = jnp.arange(B)

    # ---- floor pre-pass: walk left until some visible key <= lo ----------
    def floor_step(_, state):
        phys, fkeys, fklens, fvals, fvlens, have = state
        keys, klens, vals, vlens, live = _resolve_leaf(snap, phys, c)
        leq = live & (jax_key_cmp(keys, klens, lo[:, None, :],
                                  lolen[:, None]) <= 0)
        idx = jnp.where(leq, jnp.arange(T)[None, :], -1).max(axis=1)
        found = idx >= 0
        sel = jnp.maximum(idx, 0)
        upd = found & ~have
        fkeys = jnp.where(upd[:, None], keys[rows, sel], fkeys)
        fklens = jnp.where(upd, klens[rows, sel], fklens)
        fvals = jnp.where(upd[:, None], vals[rows, sel], fvals)
        fvlens = jnp.where(upd, vlens[rows, sel], fvlens)
        have = have | found
        nxt = snap.lsib[phys]
        can_move = ~have & (nxt != NULL)
        nxt_phys = _resolve_version(
            snap, snap.pagetable[jnp.maximum(nxt, 0)], rv, c)
        phys = jnp.where(can_move, nxt_phys, phys)
        return phys, fkeys, fklens, fvals, fvlens, have

    _, fkeys, fklens, fvals, fvlens, have_floor = jax.lax.fori_loop(
        0, c.max_scan_leaves, floor_step,
        (leaf0, jnp.zeros((B, KW), jnp.uint32), jnp.zeros((B,), jnp.int32),
         jnp.zeros((B, VW), jnp.uint32), jnp.zeros((B,), jnp.int32),
         jnp.zeros((B,), bool)))

    emit_floor = have_floor & (jax_key_cmp(fkeys, fklens, hi, hilen) <= 0)
    out_keys = out_keys.at[:, 0].set(jnp.where(emit_floor[:, None], fkeys, 0))
    out_klens = out_klens.at[:, 0].set(jnp.where(emit_floor, fklens, 0))
    out_vals = out_vals.at[:, 0].set(jnp.where(emit_floor[:, None], fvals, 0))
    out_vlens = out_vlens.at[:, 0].set(jnp.where(emit_floor, fvlens, 0))
    count = count + emit_floor.astype(jnp.int32)

    # ---- forward scan across sibling leaves ------------------------------
    def leaf_step(_, state):
        (phys, out_keys, out_klens, out_vals, out_vlens, count, trunc,
         done) = state
        keys, klens, vals, vlens, live = _resolve_leaf(snap, phys, c)
        gt_lo = jax_key_cmp(keys, klens, lo[:, None, :], lolen[:, None]) > 0
        leq_hi = jax_key_cmp(keys, klens, hi[:, None, :], hilen[:, None]) <= 0
        emit = live & gt_lo & leq_hi & ~done[:, None]
        local = jnp.cumsum(emit, axis=1) - 1
        slot = count[:, None] + local
        ok = emit & (slot < M)
        # non-emitted lanes target the out-of-range slot M and are dropped,
        # so emitted slots are written exactly once (scatter stays ordered)
        slot_c = jnp.where(ok, jnp.clip(slot, 0, M - 1), M)
        br = rows[:, None]
        out_keys = out_keys.at[br, slot_c].set(keys, mode="drop")
        out_klens = out_klens.at[br, slot_c].set(klens, mode="drop")
        out_vals = out_vals.at[br, slot_c].set(vals, mode="drop")
        out_vlens = out_vlens.at[br, slot_c].set(vlens, mode="drop")
        count = count + ok.sum(axis=1)
        trunc = trunc | (emit & ~ok).any(axis=1)
        # a request is done when this leaf contained a live key beyond hi or
        # there is no right sibling
        past_hi = (live & ~leq_hi).any(axis=1)
        nxt = snap.rsib[phys]
        done = done | past_hi | (nxt == NULL) | trunc
        nxt_phys = _resolve_version(
            snap, snap.pagetable[jnp.maximum(nxt, 0)], rv, c)
        phys = jnp.where(done, phys, nxt_phys)
        return (phys, out_keys, out_klens, out_vals, out_vlens, count,
                trunc, done)

    state = (leaf0, out_keys, out_klens, out_vals, out_vlens, count, trunc,
             jnp.zeros((B,), bool))
    (_, out_keys, out_klens, out_vals, out_vlens, count, trunc,
     done) = jax.lax.fori_loop(0, c.max_scan_leaves, leaf_step, state)
    trunc = trunc | ~done
    return ScanResult(count, out_keys, out_klens, out_vals, out_vlens, trunc)


def batched_get(snap, key: jax.Array, klen: jax.Array,
                cfg: HoneycombConfig) -> GetResult:
    """GET(K) implemented as SCAN(K, K) + post-processing (Section 3.3)."""
    res = batched_scan(snap, key, klen, key, klen, cfg)
    return get_from_scan(res, key, klen)


def get_from_scan(res: ScanResult, key: jax.Array,
                  klen: jax.Array) -> GetResult:
    """The GET equality post-pass over a SCAN(K, K) result (shared with the
    fused oracle)."""
    eq = (jax_key_cmp(res.keys, res.keylens, key[:, None, :],
                      klen[:, None]) == 0) \
        & (jnp.arange(res.keys.shape[1])[None, :] < res.count[:, None])
    found = eq.any(axis=1)
    idx = jnp.argmax(eq, axis=1)
    rows = jnp.arange(key.shape[0])
    return GetResult(found, res.vals[rows, idx], res.vallens[rows, idx])


def gather_overflow(vals: jax.Array, vallens: jax.Array,
                    overflow_vals: jax.Array, cfg: HoneycombConfig):
    """Expand out-of-node values: result lanes [B, OW] padded, using lane 0
    as the overflow slot when the length exceeds the inline capacity."""
    inline_cap = cfg.max_inline_val_bytes
    is_ovf = vallens > inline_cap
    slot = jnp.where(is_ovf, vals[..., 0].astype(jnp.int32), 0)
    ow = overflow_vals.shape[-1]
    inline = jnp.pad(vals, [(0, 0)] * (vals.ndim - 1)
                     + [(0, ow - vals.shape[-1])])
    return jnp.where(is_ovf[..., None], overflow_vals[slot], inline)
