"""Unified telemetry: ONE metrics registry, log-bucketed latency
histograms, and sampled per-request lifecycle tracing (design doc — this
docstring IS the reference).

Honeycomb's evaluation lives on per-component breakdowns — PCIe traffic
split, cache hit rate, sync stall, tail latency (paper Figs. 13-16) — and
the serving stack already meters every layer (``SyncStats``,
``CacheStats``, ``PipelineStats``, ``FeedStats``, ``TreeStats``, the
kernel-dispatch counter).  This module gives those scattered dataclasses
one front door:

  * ``MetricsRegistry`` — counters, gauges, and log-bucketed latency
    ``Histogram``s (p50/p95/p99/p999), plus *registered sources*: any
    object with a ``collect()`` method (or a zero-arg callable returning
    samples) re-reads live at every ``collect()``/export, so registry
    snapshots are always current without push-style instrumentation.
  * ``Tracer`` — sampled per-request ``Trace``s recording spans across the
    full ticket lifecycle (submit -> admit -> export_stage -> flip ->
    dispatch -> resolve), each tagged with (shard, replica, epoch,
    serving_version) so a linearizability or freshness-redirect anomaly is
    diagnosable from one trace.  Traces land in a bounded ring buffer
    (``deque(maxlen=trace_capacity)``); sampling is deterministic (every
    ``round(1/rate)``-th request), and rate 0 means NO tracer object at
    all — the scheduler's hot path then only pays ``is None`` branches.
  * Three exporters — Prometheus text exposition (``to_prometheus``),
    JSON snapshot (``snapshot``), and Chrome trace-event JSON
    (``chrome_trace_events`` — load the file in Perfetto / chrome://tracing).
  * ``Clock`` — THE injectable monotonic clock (module singleton
    ``CLOCK``).  core/shard.py, core/replica.py and core/scheduler.py all
    alias it as their ``_now``, so a test freezes ONE clock
    (``CLOCK.frozen()``) instead of monkeypatching three modules.
  * ``merge_stats`` — THE per-layer aggregation helper (moved here from
    core/router.py, which keeps ``aggregate_stats`` as the historical
    alias): merge per-shard / per-replica stats objects via their
    ``merge()`` when they define one, else plain field sums.

Wiring: ``HoneycombService`` builds a ``Telemetry`` bundle from
``ServiceConfig.telemetry`` (a ``TelemetryConfig``, core/config.py),
calls ``wire_store(store)`` — which registers every stats surface the
facade exposes (works for ``StoreShard``/``HoneycombStore``,
``ShardedHoneycombStore`` and bare ``ReplicaGroup`` alike, because they
all share the meter property names) — and hands the bundle to the
``OutOfOrderScheduler``, which records dispatch/request latency
histograms and drives the tracer.  ``enabled=False`` skips ALL of it:
no registry, no histograms, no tracer, byte-identical scheduler behaviour
to the pre-telemetry code.

Metric-name reference (the names benchmarks columns, verify.sh asserts
and Prometheus scrapes key on — keep in sync with the ``collect()``
implementations; Prometheus names carry the ``hc_`` prefix):

  name                            type       layer      meaning
  ------------------------------- ---------- ---------- -------------------
  sync_snapshots                  counter    shard      exports that refreshed the device image
  sync_full_syncs                 counter    shard      wholesale republishes
  sync_delta_syncs                counter    shard      incremental scatters
  sync_bytes_synced               counter    shard      host->device array traffic
  sync_pagetable_commands         counter    shard      PCIe page-table updates
  sync_read_version_updates       counter    shard      PCIe read-version writes
  sync_delta_rows                 counter    shard      dirty node rows scattered
  sync_delta_fraction             gauge      shard      dirty fraction at last sync (worst shard)
  sync_log_entries                counter    shard      writes accepted (one log entry each)
  sync_log_wire_bytes             counter    shard      append-only wire-format bytes
  sync_image_dma_count            counter    shard      node-image DMA invocations
  sync_image_bytes                counter    shard      node-image payload bytes
  sync_log_replays                counter    shard      follower stagings replayed from the op log
    (labels src="primary" — the serving path's own sync traffic — and
     src="followers" — the replication amplification on top of it)
  tree_puts/updates/deletes       counter    btree      host write ops applied
  tree_fast_path                  counter    btree      log-append fast-path writes
  tree_merges/splits/node_merges  counter    btree      structural maintenance ops
  tree_restarts/grows             counter    btree      CAS retries / root growths
  pipeline_runs                   counter    pipeline   scheduler epochs (src="scheduler")
  pipeline_admit_s                counter    pipeline   host write-apply wall seconds
  pipeline_export_s               counter    pipeline   standby staging wall seconds
  pipeline_dispatch_s             counter    pipeline   read-dispatch wall seconds
  pipeline_sync_stall_s           counter    pipeline   blocked-on-sync wall seconds
  pipeline_staged_exports         counter    pipeline   begin_export standby stagings
  pipeline_flips                  counter    pipeline   epoch publishes
  pipeline_dispatched_lanes       counter    pipeline   real requests inside device batches
  pipeline_padded_lanes           counter    pipeline   bucket_pow2 lanes those occupied
  pipeline_lane_occupancy         gauge      pipeline   dispatched/padded (1.0 = no waste)
  pipeline_stall_fraction         gauge      pipeline   sync stall share of epoch wall time
    (labels src="store" — the shard-side staging meters — and
     src="scheduler" — the scheduler's epoch-stage meters)
  cache_hits/misses/invalidations counter    cache      metadata-table probes (Section 5)
  cache_fast_path_reads           counter    cache      served from the packed cache
  cache_slow_path_reads           counter    cache      routed to the heap
  cache_fast_bytes/slow_bytes     counter    cache      bytes per pipe
  cache_vmem_hits                 counter    cache      fused-kernel levels served from VMEM
  cache_heap_gathers              counter    cache      fused-kernel levels gathered from heap
  cache_lb_routed                 counter    cache      cache hits the balancer re-routed
  cache_hit_rate                  gauge      cache      hits / probes
  cache_device_hit_rate           gauge      cache      vmem_hits / (vmem_hits + heap_gathers)
  replication_feed_bytes          counter    replica    bytes over all feed edges
  replication_wire_bytes          counter    replica    exact op wire stream bytes shipped
  replication_log_bytes           counter    replica    edge bytes of log-replay deliveries
  replication_fallback_bytes      counter    replica    image-delta bytes on fallback epochs
  replication_primary_egress_bytes counter   replica    bytes on primary->child edges
  replication_relay_hop_bytes     counter    replica    bytes on relay->child edges
  replication_log_feed_epochs     counter    replica    stagings shipped as a log payload
  replication_log_fallback_epochs counter    replica    log stagings that shipped the delta
  replication_delta_feed_epochs   counter    replica    stagings shipped as deltas by choice
  replication_full_feed_epochs    counter    replica    full-publish stagings
  replication_full_catchups       counter    replica    out-of-sync followers refed a full copy
  replication_catchup_bytes       counter    replica    bytes those catch-ups moved
  read_dispatches                 counter    kernel     device launches (labels op=, backend=)
  read_batches                    counter    kernel     read batches dispatched (same labels)
  scheduler_dispatched_batches    counter    scheduler  device batches composed
  scheduler_dispatched_requests   counter    scheduler  read requests inside them
  scheduler_applied_writes        counter    scheduler  writes admitted host-side
  scheduler_syncs                 counter    scheduler  per-shard syncs its epochs ran
  read_get_latency_seconds        histogram  scheduler  per-request GET device latency
  read_scan_latency_seconds       histogram  scheduler  per-request SCAN device latency
  request_latency_seconds         histogram  scheduler  submit->resolve (traced requests)
  traces_sampled/traces_retained  counter/gauge tracer  sampling meters

Histogram geometry: geometric buckets, ``buckets_per_decade`` per decade
over [``lo``, ``hi``) plus underflow/overflow buckets.  Percentiles
return the geometric midpoint of the rank's bucket clamped to the
observed [min, max] — worst-case relative error is one bucket ratio
(~15% at the default 16 buckets/decade), which is what the oracle test
(tests/test_telemetry.py) pins.  ``merge`` requires identical geometry
(elementwise add), so per-shard histograms aggregate exactly.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import re
import time
from collections import deque
from typing import Any, Callable, Iterable

from .config import TelemetryConfig

__all__ = [
    "CLOCK", "Clock", "Counter", "Gauge", "Histogram", "MetricSample",
    "MetricsRegistry", "Span", "Telemetry", "Trace", "Tracer",
    "chrome_trace_events", "merge_stats", "parse_prometheus", "prom_value",
    "samples_from",
]


# ------------------------------------------------------------------ clock
class Clock:
    """THE injectable monotonic clock.  Calls through to
    ``time.perf_counter`` until frozen; a frozen clock returns a
    deterministic value that only ``advance()`` moves — so tests freeze
    ONE object instead of monkeypatching ``_now`` in three modules."""

    __slots__ = ("_frozen_at",)

    def __init__(self):
        self._frozen_at: float | None = None

    def __call__(self) -> float:
        at = self._frozen_at
        return time.perf_counter() if at is None else at

    now = __call__

    def freeze(self, at: float = 0.0) -> None:
        self._frozen_at = at

    def advance(self, dt: float) -> None:
        assert self._frozen_at is not None, "advance() needs a frozen clock"
        self._frozen_at += dt

    def unfreeze(self) -> None:
        self._frozen_at = None

    @contextlib.contextmanager
    def frozen(self, at: float = 0.0):
        """``with CLOCK.frozen(10.0): ...`` — deterministic time inside."""
        prev = self._frozen_at
        self.freeze(at)
        try:
            yield self
        finally:
            self._frozen_at = prev


#: The process-wide clock every timing site (shard, replica, scheduler,
#: tracer) reads.  Freeze THIS to freeze them all.
CLOCK = Clock()


# ------------------------------------------------------- samples & merges
@dataclasses.dataclass
class MetricSample:
    """One collected observation.  ``value`` is a float for counters and
    gauges and the ``Histogram`` object itself for histograms (exporters
    render quantiles/sum/count from it)."""
    name: str
    kind: str                    # "counter" | "gauge" | "histogram"
    value: Any
    labels: dict = dataclasses.field(default_factory=dict)

    def key(self) -> str:
        """Stable flat key: ``name{k=v,...}`` (name alone when unlabeled)."""
        if not self.labels:
            return self.name
        inner = ",".join(f"{k}={self.labels[k]}" for k in sorted(self.labels))
        return f"{self.name}{{{inner}}}"


def samples_from(obj, prefix: str, layer: str,
                 gauges: Iterable[str] = (),
                 derived: Iterable[str] = ()) -> list[MetricSample]:
    """The shared ``collect()`` implementation for the stats dataclasses:
    every numeric field becomes ``{prefix}_{field}`` (counter unless named
    in ``gauges``), and each ``derived`` property name is sampled as a
    gauge.  All samples carry ``layer=<layer>``."""
    out = []
    gauges = set(gauges)
    for f in dataclasses.fields(obj):
        v = getattr(obj, f.name)
        if not isinstance(v, (int, float)):
            continue
        kind = "gauge" if f.name in gauges else "counter"
        out.append(MetricSample(f"{prefix}_{f.name}", kind, float(v),
                                {"layer": layer}))
    for name in derived:
        out.append(MetricSample(f"{prefix}_{name}", "gauge",
                                float(getattr(obj, name)), {"layer": layer}))
    return out


def merge_stats(parts, factory):
    """Merge per-shard / per-replica stat objects into one ``factory()``.

    THE aggregation helper for every layer (formerly
    ``router.aggregate_stats``, which remains as an alias): objects with a
    ``merge()`` method merge through it (``SyncStats`` maxes
    ``delta_fraction``, ``PipelineStats`` sums); plain dataclasses
    (``TreeStats``, ``CacheStats``, ``FeedStats``) field-sum.  The
    registry's ``collect()`` path reads the SAME aggregates, so Prometheus
    numbers and per-layer meter properties can never disagree
    (pinned by tests/test_telemetry.py)."""
    agg = factory()
    if hasattr(agg, "merge"):
        for p in parts:
            agg.merge(p)
    else:
        for p in parts:
            for f in dataclasses.fields(agg):
                setattr(agg, f.name,
                        getattr(agg, f.name) + getattr(p, f.name))
    return agg


# -------------------------------------------------------------- instruments
class Counter:
    """Monotone accumulator (registry-owned; layer meters stay dataclasses
    and come in through ``collect()`` sources instead)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Log-bucketed latency histogram: geometric buckets over
    [``lo``, ``hi``) at ``buckets_per_decade`` resolution, plus
    underflow/overflow buckets.  See the module docstring for the accuracy
    contract; ``record(v, n)`` is weighted so a per-batch device time can
    be spread over the batch's requests with one call."""

    __slots__ = ("lo", "hi", "bpd", "counts", "count", "total",
                 "vmin", "vmax")

    def __init__(self, lo: float = 1e-7, hi: float = 1e3,
                 buckets_per_decade: int = 16):
        assert lo > 0 and hi > lo and buckets_per_decade >= 1
        self.lo, self.hi, self.bpd = lo, hi, buckets_per_decade
        n = int(math.ceil(math.log10(hi / lo) * buckets_per_decade))
        self.counts = [0] * (n + 2)      # [underflow] + n buckets + [overflow]
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _index(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return len(self.counts) - 1
        i = 1 + int(math.log10(v / self.lo) * self.bpd)
        return min(i, len(self.counts) - 2)

    def _bounds(self, i: int) -> tuple[float, float]:
        if i == 0:
            return 0.0, self.lo
        if i == len(self.counts) - 1:
            return self.hi, math.inf
        return (self.lo * 10.0 ** ((i - 1) / self.bpd),
                self.lo * 10.0 ** (i / self.bpd))

    def record(self, v: float, n: int = 1) -> None:
        if n <= 0:
            return
        self.counts[self._index(v)] += n
        self.count += n
        self.total += v * n
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def merge(self, other: "Histogram") -> None:
        assert (self.lo, self.hi, self.bpd) == \
            (other.lo, other.hi, other.bpd), "histogram geometry mismatch"
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    def percentile(self, p: float) -> float:
        """Value at percentile ``p`` (0-100): geometric midpoint of the
        rank's bucket, clamped to the observed [min, max]."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(p / 100.0 * self.count))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                blo, bhi = self._bounds(i)
                if i == 0:
                    mid = self.vmin
                elif i == len(self.counts) - 1:
                    mid = self.vmax
                else:
                    mid = math.sqrt(blo * bhi)
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantiles(self) -> dict:
        return {"p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99), "p999": self.percentile(99.9)}

    def to_dict(self) -> dict:
        out = {"count": self.count, "sum": self.total, "mean": self.mean,
               "min": self.vmin if self.count else 0.0,
               "max": self.vmax if self.count else 0.0}
        out.update(self.quantiles())
        return out


# ---------------------------------------------------------------- registry
class MetricsRegistry:
    """One registry per ``Telemetry`` bundle: owns its counters/gauges/
    histograms (get-or-create by (name, labels)) and any number of
    registered SOURCES — zero-arg callables returning either an object
    with ``collect()`` or an iterable of samples (``MetricSample`` or
    ``(name, kind, value[, labels])`` tuples, the dependency-free form
    kernels/ops.py uses).  Sources are re-invoked on every ``collect()``,
    so exports always reflect live meter state."""

    def __init__(self):
        self._own: dict[tuple, tuple[str, Any]] = {}
        self._sources: list[tuple[Callable[[], Any], dict]] = []

    # ------------------------------------------------------- instruments
    def _get(self, name: str, kind: str, make, labels: dict):
        key = (name, tuple(sorted(labels.items())))
        hit = self._own.get(key)
        if hit is None:
            hit = (kind, make())
            self._own[key] = hit
        assert hit[0] == kind, f"{name} already registered as {hit[0]}"
        return hit[1]

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, "counter", Counter, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, "gauge", Gauge, labels)

    def histogram(self, name: str, lo: float = 1e-7, hi: float = 1e3,
                  buckets_per_decade: int = 16, **labels) -> Histogram:
        return self._get(name, "histogram",
                         lambda: Histogram(lo, hi, buckets_per_decade),
                         labels)

    # ----------------------------------------------------------- sources
    def register(self, source, **labels) -> None:
        """Register a live stats source.  ``source`` is a zero-arg
        callable (preferred — re-read every collect) or an object with a
        ``collect()`` method; extra ``labels`` are stamped onto every
        sample it yields (e.g. ``src="scheduler"`` to keep two
        ``pipeline_*`` surfaces apart)."""
        fn = source if callable(source) else (lambda: source)
        self._sources.append((fn, labels))

    @staticmethod
    def _as_sample(x, extra: dict) -> MetricSample:
        if isinstance(x, MetricSample):
            s = x
        else:
            name, kind, value = x[0], x[1], x[2]
            labels = dict(x[3]) if len(x) > 3 else {}
            s = MetricSample(name, kind, value, labels)
        if extra:
            s = MetricSample(s.name, s.kind, s.value, {**s.labels, **extra})
        return s

    def collect(self) -> list[MetricSample]:
        out = []
        for (name, litems), (kind, inst) in self._own.items():
            value = inst if kind == "histogram" else inst.value
            out.append(MetricSample(name, kind, value, dict(litems)))
        for fn, extra in self._sources:
            got = fn()
            if got is None:
                continue
            if hasattr(got, "collect"):
                got = got.collect()
            for x in got:
                out.append(self._as_sample(x, extra))
        return out

    # --------------------------------------------------------- exporters
    def snapshot(self) -> dict:
        """JSON-able flat snapshot: ``{key: value}`` with histograms
        rendered to their count/sum/quantile dicts."""
        out = {}
        for s in self.collect():
            out[s.key()] = (s.value.to_dict()
                            if isinstance(s.value, Histogram) else s.value)
        return out

    def to_prometheus(self, prefix: str = "hc") -> str:
        """Prometheus text exposition (histograms as summaries)."""
        lines = []
        typed: set[str] = set()
        for s in self.collect():
            name = _prom_name(f"{prefix}_{s.name}")
            if isinstance(s.value, Histogram):
                if name not in typed:
                    typed.add(name)
                    lines.append(f"# TYPE {name} summary")
                h = s.value
                for q, pct in (("0.5", 50), ("0.95", 95), ("0.99", 99),
                               ("0.999", 99.9)):
                    lines.append(f"{name}{_prom_labels(s.labels, quantile=q)}"
                                 f" {h.percentile(pct):g}")
                lines.append(f"{name}_sum{_prom_labels(s.labels)}"
                             f" {h.total:g}")
                lines.append(f"{name}_count{_prom_labels(s.labels)}"
                             f" {h.count:g}")
            else:
                if name not in typed:
                    typed.add(name)
                    lines.append(f"# TYPE {name} {s.kind}")
                lines.append(f"{name}{_prom_labels(s.labels)} {s.value:g}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    return re.sub(r"[^a-zA-Z0-9_:]", "_", name)


def _prom_labels(labels: dict, **extra) -> str:
    merged = {**labels, **extra}
    if not merged:
        return ""
    inner = ",".join(f'{_prom_name(k)}="{merged[k]}"'
                     for k in sorted(merged))
    return "{" + inner + "}"


_PROM_LINE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
                        r"(?:\{(.*)\})?\s+(\S+)$")
_PROM_LABEL = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="([^"]*)"')


def parse_prometheus(text: str) -> dict:
    """Parse the text exposition back into ``{name: [(labels, value)]}``
    — the verify.sh/tests half of the Prometheus round trip.  Raises
    ``ValueError`` on any non-comment line that does not parse."""
    out: dict[str, list] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _PROM_LINE.match(line)
        if m is None:
            raise ValueError(f"unparseable Prometheus line: {line!r}")
        name, rawlabels, raw = m.groups()
        labels = dict(_PROM_LABEL.findall(rawlabels)) if rawlabels else {}
        out.setdefault(name, []).append((labels, float(raw)))
    return out


def prom_value(parsed: dict, name: str, **labels) -> float:
    """Sum of every ``name`` series whose labels include ``labels``."""
    return sum(v for ls, v in parsed.get(name, ())
               if all(ls.get(k) == str(w) for k, w in labels.items()))


# ----------------------------------------------------------------- tracing
@dataclasses.dataclass
class Span:
    """One lifecycle stage of a traced request (``t0 == t1`` marks an
    instant event, e.g. submit/resolve)."""
    name: str
    t0: float
    t1: float
    tags: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Trace:
    """One sampled request's full lifecycle.  ``tags`` accumulates the
    response stamps at finish: shard, replica, epoch, serving_version,
    status."""
    rid: int
    kind: str
    t0: float
    t1: float = 0.0
    spans: list = dataclasses.field(default_factory=list)
    tags: dict = dataclasses.field(default_factory=dict)

    def span_names(self) -> list[str]:
        return [s.name for s in self.spans]


class Tracer:
    """Deterministic sampled request tracing: every ``round(1/rate)``-th
    submitted request gets a live ``Trace``; finished traces land in a
    bounded ring (``deque(maxlen=capacity)``).  The scheduler only calls
    in through ``is_live``/``span``/``span_all``, all of which are no-ops
    (and allocation-free) for unsampled rids."""

    def __init__(self, sample_rate: float, capacity: int = 256,
                 clock: Clock | None = None):
        assert 0.0 < sample_rate <= 1.0, "tracer needs a rate in (0, 1]"
        assert capacity >= 1
        self.period = max(1, round(1.0 / sample_rate))
        self.clock = clock or CLOCK
        self.sampled = 0
        self._seen = 0
        self._live: dict[int, Trace] = {}
        self.traces: deque[Trace] = deque(maxlen=capacity)

    @property
    def live_count(self) -> int:
        return len(self._live)

    def live_rids(self) -> list[int]:
        return list(self._live)

    def is_live(self, rid: int) -> bool:
        return rid in self._live

    def begin(self, rid: int, kind: str, **tags) -> Trace | None:
        """Sampling decision + submit instant; returns the live trace or
        None (the unsampled fast path allocates nothing)."""
        self._seen += 1
        if (self._seen - 1) % self.period:
            return None
        now = self.clock()
        t = Trace(rid=rid, kind=kind, t0=now, tags=dict(tags))
        t.spans.append(Span("submit", now, now))
        self._live[rid] = t
        self.sampled += 1
        return t

    def span(self, rid: int, name: str, t0: float, t1: float,
             **tags) -> None:
        t = self._live.get(rid)
        if t is not None:
            t.spans.append(Span(name, t0, t1, dict(tags) if tags else {}))

    def span_all(self, name: str, t0: float, t1: float, **tags) -> None:
        """Attach one span to every live trace (the export/flip stages
        cover the whole epoch, not one request)."""
        for t in self._live.values():
            t.spans.append(Span(name, t0, t1, dict(tags) if tags else {}))

    def finish(self, rid: int, **tags) -> Trace | None:
        t = self._live.pop(rid, None)
        if t is None:
            return None
        now = self.clock()
        t.spans.append(Span("resolve", now, now))
        t.tags.update(tags)
        t.t1 = now
        self.traces.append(t)
        return t

    def collect(self) -> list[tuple]:
        return [("traces_sampled", "counter", self.sampled,
                 {"layer": "tracer"}),
                ("traces_retained", "gauge", len(self.traces),
                 {"layer": "tracer"}),
                ("traces_live", "gauge", len(self._live),
                 {"layer": "tracer"})]


def chrome_trace_events(traces: Iterable[Trace]) -> dict:
    """Chrome trace-event JSON (Perfetto / chrome://tracing loadable):
    one complete ("ph": "X") event per span, pid = shard, tid = rid,
    timestamps in microseconds, tags in ``args``."""
    evs = []
    for t in traces:
        for s in t.spans:
            evs.append({
                "name": s.name, "ph": "X", "cat": t.kind,
                "ts": s.t0 * 1e6, "dur": max((s.t1 - s.t0) * 1e6, 0.0),
                "pid": int(t.tags.get("shard", 0)), "tid": t.rid,
                "args": {**t.tags, **s.tags, "rid": t.rid, "kind": t.kind},
            })
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


# ------------------------------------------------------------------ bundle
class Telemetry:
    """Registry + (optional) tracer behind one handle, with the wiring
    helpers the service layer uses.  Constructed by ``HoneycombService``
    from ``ServiceConfig.telemetry`` when enabled; standalone use is one
    line: ``tm = Telemetry(); tm.wire_store(store)``."""

    def __init__(self, cfg: TelemetryConfig | None = None,
                 clock: Clock | None = None):
        self.cfg = cfg or TelemetryConfig()
        self.clock = clock or CLOCK
        self.registry = MetricsRegistry()
        self.tracer = (Tracer(self.cfg.trace_sample_rate,
                              self.cfg.trace_capacity, self.clock)
                       if self.cfg.trace_sample_rate > 0 else None)
        if self.tracer is not None:
            self.registry.register(self.tracer.collect)

    @property
    def enabled(self) -> bool:
        return self.cfg.enabled

    def histogram(self, name: str, **labels) -> Histogram:
        return self.registry.histogram(
            name, lo=self.cfg.latency_lo, hi=self.cfg.latency_hi,
            buckets_per_decade=self.cfg.buckets_per_decade, **labels)

    # ------------------------------------------------------------ wiring
    def wire_store(self, store) -> "Telemetry":
        """Register every stats surface the facade exposes.  Probes by
        meter property name, so it works across the whole facade family
        (``StoreShard``/``HoneycombStore``, ``ShardedHoneycombStore``,
        bare ``ReplicaGroup``) — absent surfaces are skipped."""
        reg = self.registry
        reg.register(lambda: store.sync_stats, src="primary")
        reg.register(lambda: store.stats)                     # TreeStats
        if hasattr(store, "pipeline_stats"):
            reg.register(lambda: store.pipeline_stats, src="store")
        if hasattr(store, "cache_stats"):
            reg.register(lambda: store.cache_stats)
        if hasattr(store, "feed_stats"):
            reg.register(lambda: store.feed_stats)
            reg.register(lambda: store.replication_stats, src="followers")
        # EpochSan meters, when the sanitizer is active (lazy import: the
        # registry must stay constructible without the analysis package)
        from ..analysis import epochsan as _epochsan
        san = _epochsan.get()
        if san is not None:
            reg.register(lambda: san.stats)
        self.wire_kernel_meter()
        return self

    def wire_scheduler(self, sched) -> "Telemetry":
        self.registry.register(lambda: sched.stats, src="scheduler")

        def _sched_meters():
            lab = {"layer": "scheduler"}
            return [
                ("scheduler_dispatched_batches", "counter",
                 sched.dispatched_batches, lab),
                ("scheduler_dispatched_requests", "counter",
                 sched.dispatched_requests, lab),
                ("scheduler_applied_writes", "counter",
                 sched.applied_writes, lab),
                ("scheduler_syncs", "counter", sched.syncs, lab),
            ]
        self.registry.register(_sched_meters)
        return self

    def wire_kernel_meter(self) -> None:
        """The READ_DISPATCHES launch counter (kernels/ops.py).  Lazy
        import at collect time: kernels may not import repro.core, and a
        registry must stay constructible without jax on the path."""
        def _kernel_samples():
            from repro.kernels import ops as kernel_ops
            return kernel_ops.collect()
        self.registry.register(_kernel_samples)

    # --------------------------------------------------------- exporters
    def collect(self) -> list[MetricSample]:
        return self.registry.collect()

    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def to_prometheus(self, prefix: str = "hc") -> str:
        return self.registry.to_prometheus(prefix)

    def traces(self) -> list[Trace]:
        return list(self.tracer.traces) if self.tracer is not None else []

    def chrome_trace(self) -> dict:
        return chrome_trace_events(self.traces())

    # ------------------------------------------------------------ lookup
    def value(self, name: str, **labels) -> float:
        """Sum of every matching counter/gauge sample — the benchmark
        table's accessor, so columns read the registry, not the layer
        dataclasses."""
        tot = 0.0
        for s in self.collect():
            if s.name == name and not isinstance(s.value, Histogram) and \
                    all(s.labels.get(k) == v for k, v in labels.items()):
                tot += s.value
        return tot

    def quantile(self, name: str, p: float, **labels) -> float:
        """Percentile ``p`` over every matching histogram (merged)."""
        merged = None
        for s in self.collect():
            if s.name == name and isinstance(s.value, Histogram) and \
                    all(s.labels.get(k) == v for k, v in labels.items()):
                if merged is None:
                    merged = Histogram(s.value.lo, s.value.hi, s.value.bpd)
                merged.merge(s.value)
        return merged.percentile(p) if merged is not None else 0.0

    def summary(self) -> dict:
        """Flat JSON-able registry view keyed ``name{labels}`` (scalars
        verbatim, histograms as quantile dicts) — what the benchmarks
        attach next to their results."""
        return self.registry.snapshot()
