"""Honeycomb store configuration.

Mirrors the paper's node geometry (Section 3.1) adapted to lane-structured
storage for the TPU (DESIGN.md Section 2).  The paper's byte budgets map to
fixed-width slots:

  paper                         here
  -----------------------------------------------------------------
  8 KB node                     ``node_cap`` sorted items + ``log_cap`` log
                                entries + ``n_shortcuts`` boundary keys
  48 B header                   SoA scalar columns (type/version/...)
  464 B shortcut block          ``n_shortcuts`` keys + segment offsets
  512 B log threshold           ``log_cap`` entries (merge when full)
  460 B max key                 ``key_words`` * 4 bytes (big-endian lanes)
  469 B max inline value        ``val_words`` * 4 bytes, larger values go
                                to the overflow heap (paper: out-of-node)
  5 B version delta             32-bit delta; wrap forces a merge, same as
                                the paper's wrap-forces-merge rule
"""
from __future__ import annotations

import dataclasses


# device-resident snapshot layouts (HoneycombConfig.layout)
LAYOUTS = ("packed", "legacy")

# device read-path backends (HoneycombConfig.read_backend):
#   "fused"     — ONE fused traversal dispatch per read batch: descend +
#                 leaf resolve + log merge + version resolution execute as a
#                 single device call against the packed node image, with the
#                 top interior levels resolved from the snapshot's VMEM-pinned
#                 cache array (kernels/fused_read.py).
#   "reference" — today's per-level jnp path (core/read_path.py), kept as the
#                 tested op-for-op oracle the fused path is checked against.
READ_BACKENDS = ("fused", "reference")


def bucket_pow2(n: int) -> int:
    """Round a batch/delta length up to a power of two (1 for n <= 1).

    THE shared bucket schedule for everything padded before a jitted
    device call — read batches (core/shard.py), delta row/page-table
    vectors (core/shard.py), and the scheduler's lane-occupancy meters —
    so the jit cache grows one compile per bucket, not per distinct
    length.  The schedule is pinned by tests/test_pipeline_engine.py.
    """
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class HoneycombConfig:
    # --- node geometry -----------------------------------------------------
    node_cap: int = 64          # max items in the sorted block
    log_cap: int = 16           # log entries before a merge is forced
    n_shortcuts: int = 8        # boundary keys in the shortcut block
    key_words: int = 8          # key lanes (uint32, big-endian) => 32 B max key
    val_words: int = 4          # inline value lanes => 16 B inline values
    min_fill: float = 0.25      # leaf underflow threshold (merge w/ sibling)
    split_fill: float = 0.5     # target fill of each half after a split

    # --- MVCC / GC ----------------------------------------------------------
    mvcc: bool = True           # paper Section 3.2; False => version 0 for all
    max_version_chain: int = 4  # bound on old-version hops a reader may take
    gc_batch: int = 64          # GC list scan granularity

    # --- read path ----------------------------------------------------------
    max_height: int = 8         # static traversal bound for the jitted reader
    max_scan_leaves: int = 4    # sibling hops a single SCAN may take
    max_scan_items: int = 32    # result slots per SCAN request

    # --- accelerator cache / load balancer (Section 5) ----------------------
    cache_slots: int = 256      # interior-node cache capacity (packed array)
    cache_ways: int = 4         # set associativity of the metadata table
    load_balance: bool = True   # route some cache hits to the slow path
    lb_fast_fraction: float = 0.75  # fraction of hits served by the cache path
    # device cache tier (kernels/fused_read.py): how many tree levels from
    # the root are packed into the snapshot's contiguous cache array (the
    # paper's SRAM root + DRAM top-interior tiers); lb_fraction is the
    # Section 5 dual-pipe knob — the fraction of cache-HIT level lookups the
    # fused kernel routes back to the heap-image pipe anyway (results are
    # identical either way; only the byte split between the pipes moves).
    cache_levels: int = 2
    lb_fraction: float = 0.0

    # --- value overflow heap -----------------------------------------------
    overflow_words: int = 128   # slot size of the out-of-node value heap

    # --- host->device sync (delta snapshots, paper Sections 3-4) ------------
    # "on_read": sync lazily before a device batch (default, paper-like);
    # "every_k": sync after every sync_every_k writes (batched sync);
    # "explicit": only export_snapshot()/scheduler.run() sync — device reads
    #             may observe a stale-but-consistent snapshot.
    sync_policy: str = "on_read"
    sync_every_k: int = 64
    # dirty-row fraction above which a delta sync would move more bytes than
    # a wholesale republish is worth; fall back to a full publish
    delta_full_threshold: float = 0.5
    # device-resident snapshot representation (core/schema.py):
    # "packed": ONE contiguous u32 node image per slot — a dirty node syncs
    #           as a single image-row DMA (the paper's 8 KB node transfer);
    # "legacy": per-field arrays — one row scatter per field, kept as the
    #           packed layout's op-for-op parity reference.
    layout: str = "packed"
    # device read-path backend (see READ_BACKENDS above); "fused" falls back
    # to the reference path automatically on legacy-layout snapshots, which
    # carry no packed image for the megakernel to traverse
    read_backend: str = "fused"

    def __post_init__(self):
        assert self.node_cap % self.n_shortcuts == 0, (
            "segments must tile the sorted block")
        assert self.log_cap <= 255, "order hints are 1 byte (paper Fig. 7)"
        assert self.node_cap <= 2 ** 15, "back pointers are 2 bytes"
        assert self.sync_policy in ("on_read", "every_k", "explicit"), (
            f"unknown sync_policy {self.sync_policy!r}")
        assert 0.0 < self.delta_full_threshold <= 1.0, (
            "delta_full_threshold is a dirty fraction in (0, 1]")
        assert self.sync_every_k >= 1, "sync_every_k must be >= 1"
        assert self.layout in LAYOUTS, (
            f"unknown snapshot layout {self.layout!r} (one of {LAYOUTS})")
        assert self.read_backend in READ_BACKENDS, (
            f"unknown read_backend {self.read_backend!r} "
            f"(one of {READ_BACKENDS})")
        assert self.cache_levels >= 1, "cache the root level at least"
        assert 0.0 <= self.lb_fraction <= 1.0, (
            "lb_fraction is a routed fraction in [0, 1]")

    @property
    def segment_items(self) -> int:
        """Items per sorted-block segment (the unit a search fetches)."""
        return self.node_cap // self.n_shortcuts

    @property
    def max_key_bytes(self) -> int:
        return self.key_words * 4

    @property
    def max_inline_val_bytes(self) -> int:
        return self.val_words * 4

    # Byte model used by benchmarks to reproduce the paper's bytes-fetched
    # accounting (Section 3.1: "a search reads at most 1.5 KB of an 8 KB
    # node").  Sizes are the packed lane widths actually gathered.
    @property
    def header_bytes(self) -> int:
        return 48

    @property
    def shortcut_bytes(self) -> int:
        return self.n_shortcuts * (self.max_key_bytes + 4)

    @property
    def segment_bytes(self) -> int:
        return self.segment_items * (self.max_key_bytes + self.val_words * 4 + 4)

    @property
    def log_bytes(self) -> int:
        return self.log_cap * (self.max_key_bytes + self.val_words * 4 + 12)

    @property
    def node_bytes(self) -> int:
        return (self.header_bytes + self.shortcut_bytes
                + self.node_cap * (self.max_key_bytes + self.val_words * 4 + 4)
                + self.log_bytes)


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """Range partition of the keyspace for ``ShardedHoneycombStore``.

    ``boundaries`` are ``shards - 1`` strictly ascending byte-string split
    points; shard ``i`` owns keys in ``[boundaries[i-1], boundaries[i])``
    (shard 0 is unbounded below, the last shard unbounded above).  ``None``
    defaults to a uniform split of the 8-byte big-endian integer keyspace.
    """
    shards: int = 1
    boundaries: tuple[bytes, ...] | None = None

    def __post_init__(self):
        assert self.shards >= 1, "need at least one shard"
        if self.boundaries is not None:
            b = self.boundaries
            assert len(b) == self.shards - 1, (
                f"{self.shards} shards need {self.shards - 1} boundaries, "
                f"got {len(b)}")
            assert all(x < y for x, y in zip(b, b[1:])), (
                "shard boundaries must be strictly ascending")


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Observability knobs (core/telemetry.py — the design doc lives
    there).

    ``enabled=False`` skips telemetry construction entirely: no registry,
    no histograms, no tracer — the scheduler hot path pays only ``is
    None`` branches, byte-identical to the pre-telemetry behaviour.
    ``trace_sample_rate`` samples per-request lifecycle traces
    deterministically (every ``round(1/rate)``-th request; 0 disables
    tracing and allocates nothing); finished traces are retained in a ring
    buffer of ``trace_capacity``.  The histogram geometry knobs pin the
    log-bucket resolution of every latency histogram the service
    records."""
    enabled: bool = True
    trace_sample_rate: float = 0.0
    trace_capacity: int = 256
    latency_lo: float = 1e-7         # histogram range floor (seconds)
    latency_hi: float = 1e3          # histogram range ceiling (seconds)
    buckets_per_decade: int = 16     # log-bucket resolution

    def __post_init__(self):
        assert 0.0 <= self.trace_sample_rate <= 1.0, (
            "trace_sample_rate is a probability in [0, 1]")
        assert self.trace_capacity >= 1, "trace ring needs >= 1 slot"
        assert 0.0 < self.latency_lo < self.latency_hi, (
            "histogram range must satisfy 0 < lo < hi")
        assert self.buckets_per_decade >= 1


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Serving-front-end knobs for ``HoneycombService`` (core/api.py).

    ``batch_size`` is the dense device-batch target the scheduler fills per
    (shard, replica, kind, cost_class) bucket; ``cost_classes`` the
    expected-work buckets SCANs are split into; ``pipeline`` the epoch
    composition (``"serial"`` models the blocking sync barrier,
    ``"pipelined"`` overlaps standby staging with read dispatch — see
    core/pipeline.py); ``telemetry`` the observability knobs
    (core/telemetry.py)."""
    batch_size: int = 256
    cost_classes: tuple[int, ...] = (1, 4, 16, 64)
    pipeline: str = "serial"
    telemetry: TelemetryConfig = TelemetryConfig()

    def __post_init__(self):
        assert self.batch_size >= 1, "batch_size must be >= 1"
        assert self.cost_classes, "need at least one cost class"
        from .pipeline import PIPELINE_MODES
        assert self.pipeline in PIPELINE_MODES, (
            f"unknown pipeline mode {self.pipeline!r} "
            f"(one of {PIPELINE_MODES})")


# follower feed paths for replicated shards (core/replica.py):
#   "log"   — ship each sync epoch's op wire stream (core/api.py codec) once
#             and replay it on device with the log_replay_scatter kernel;
#             epochs whose tree shape changed fall back to the image delta.
#   "delta" — ship the primary's dirty-row image delta to every follower
#             (the pre-log feed, kept as the byte-accounting reference).
REPLICA_FEEDS = ("log", "delta")


@dataclasses.dataclass(frozen=True)
class FeedTopology:
    """Relay tree for the replication feed (core/replica.py).

    ``depth == 0`` is the flat feed: the primary ships every staged payload
    directly to each follower, so feeder egress is O(replicas).  With
    ``depth >= 1`` followers are arranged level by level under the primary —
    up to ``fanout`` first-level relays, ``fanout**2`` second-level nodes,
    and so on, with the final level absorbing any remainder round-robin —
    so the primary's egress is O(fanout) and each relay forwards the SAME
    encoded payload downstream (the architecture of "Reliable Replication
    Protocols on SmartNICs", PAPERS.md).  A paused relay cuts off its
    subtree: descendants miss the payload, fall out of sync, and take a
    full-image catch-up from the primary once the path is live again.
    """
    fanout: int = 2
    depth: int = 0

    def __post_init__(self):
        assert self.fanout >= 1, "relay fanout must be >= 1"
        assert self.depth >= 0, "relay depth must be >= 0"

    def parents(self, n_followers: int) -> dict[int, int]:
        """Map follower replica id (1..n) -> feeding parent replica id
        (0 = primary).  Levels 1..depth-1 take ``fanout`` children per
        parent in id order; the last level absorbs every remaining
        follower, spread round-robin over the level above."""
        ids = list(range(1, n_followers + 1))
        if self.depth == 0:
            return {i: 0 for i in ids}
        parents: dict[int, int] = {}
        prev_level = [0]
        pos = 0
        for level in range(1, self.depth + 1):
            remaining = len(ids) - pos
            if remaining <= 0:
                break
            cap = len(prev_level) * self.fanout
            take = remaining if level == self.depth else min(remaining, cap)
            this_level = ids[pos:pos + take]
            for idx, i in enumerate(this_level):
                if take <= cap:
                    parents[i] = prev_level[idx // self.fanout]
                else:        # final level overflow: spread round-robin
                    parents[i] = prev_level[idx % len(prev_level)]
            prev_level = this_level
            pos += take
        return parents


# read-spreading policies for replicated shards (core/replica.py):
#   "primary_only" — every read serves from the primary (replication off the
#                    read path; the replicas=1 equivalence baseline);
#   "round_robin"  — dispatched read batches rotate over the replica set;
#   "least_loaded" — each batch goes to the replica that has served the
#                    fewest requests so far.
REPLICA_POLICIES = ("primary_only", "round_robin", "least_loaded")


@dataclasses.dataclass(frozen=True)
class ReplicationConfig:
    """Replica set for each shard of a ``ShardedHoneycombStore``.

    ``replicas`` counts SERVING copies per shard (primary + followers), so
    ``replicas=1`` means no followers — the configuration that is
    operation-for-operation identical to the unreplicated store, including
    sync byte counts (enforced by tests/test_replica.py).  Followers hold
    their own device-resident snapshot fed only by the primary's delta
    stream (core/replica.py); ``policy`` picks how the router spreads read
    batches over the replica set (writes always go to the primary).

    ``feed`` selects the follower transport: ``"log"`` (default) ships each
    epoch's encoded op stream once and replays it on device, falling back
    per-epoch to the image delta when the tree shape changed; ``"delta"``
    is the pre-log dirty-row image feed.  ``topology`` arranges followers
    into a relay tree (see ``FeedTopology``) so feeder egress scales with
    the fanout, not the replica count.
    """
    replicas: int = 1
    policy: str = "primary_only"
    feed: str = "log"
    topology: FeedTopology = FeedTopology()

    def __post_init__(self):
        assert self.replicas >= 1, "need at least the primary replica"
        assert self.policy in REPLICA_POLICIES, (
            f"unknown replica policy {self.policy!r} "
            f"(one of {REPLICA_POLICIES})")
        assert self.feed in REPLICA_FEEDS, (
            f"unknown replica feed {self.feed!r} (one of {REPLICA_FEEDS})")
        assert isinstance(self.topology, FeedTopology), (
            "topology must be a FeedTopology")


DEFAULT_CONFIG = HoneycombConfig()
