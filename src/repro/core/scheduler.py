"""Request scheduling: out-of-order batch composition (paper Section 4.1).

The FPGA avoids head-of-line blocking by letting requests complete out of
order.  In SPMD execution the whole batch advances in lock step, so the
equivalent straggler mitigation is *batch composition*: requests with similar
expected work (scan width, key size) are bucketed together so a vectorized
step is not held hostage by one expensive lane, and responses are re-ordered
back to arrival order on completion — out-of-order execution with in-order
delivery, exactly the accelerator's contract.

Writes are first-class requests too: ``run()`` applies every pending write
host-side, in submission order, then performs ONE host->device sync (the
delta snapshot export) before dispatching the read batches — the paper's
batched synchronization (Sections 3-4: many writes amortize one set of PCIe
page-table/read-version commands).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Iterable, Sequence

WRITE_KINDS = ("put", "update", "delete")


@dataclasses.dataclass
class Request:
    rid: int
    kind: str                  # "get" | "scan" | "put" | "update" | "delete"
    key: bytes = b""
    hi: bytes = b""
    value: bytes = b""
    expected_items: int = 1


class OutOfOrderScheduler:
    """Buckets read requests by cost class, queues writes in order,
    dispatches dense batches, reassembles responses in arrival order."""

    def __init__(self, batch_size: int = 256,
                 cost_classes: Sequence[int] = (1, 4, 16, 64)):
        self.batch_size = batch_size
        self.cost_classes = tuple(sorted(cost_classes))
        self._buckets: dict[tuple[str, int], list[Request]] = defaultdict(list)
        self._writes: list[Request] = []
        self._next_rid = 0
        self.dispatched_batches = 0
        self.dispatched_requests = 0
        self.applied_writes = 0
        self.syncs = 0             # host->device syncs run() triggered

    def _cost_class(self, r: Request) -> int:
        for c in self.cost_classes:
            if r.expected_items <= c:
                return c
        return self.cost_classes[-1]

    def submit(self, kind: str, key: bytes, hi: bytes = b"",
               value: bytes = b"", expected_items: int = 1) -> int:
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid, kind, key, hi, value, expected_items)
        if kind in WRITE_KINDS:
            self._writes.append(r)      # writes keep submission order
        else:
            self._buckets[(kind, self._cost_class(r))].append(r)
        return rid

    def ready_batches(self, flush: bool = False
                      ) -> Iterable[tuple[str, list[Request]]]:
        """Full read batches (or all remaining when flushing), densest
        first."""
        for (kind, _), reqs in sorted(self._buckets.items(),
                                      key=lambda kv: -len(kv[1])):
            while len(reqs) >= self.batch_size or (flush and reqs):
                batch = reqs[: self.batch_size]
                del reqs[: self.batch_size]
                yield kind, batch

    def _apply_writes(self, store) -> dict[int, Any]:
        """Host-side write phase: every queued write in submission order,
        no device sync in between (that is the whole point) — the store's
        own "every_k" policy is deferred for the duration of the burst."""
        out: dict[int, Any] = {}
        with store.deferred_sync():
            for r in self._writes:
                if r.kind == "put":
                    store.put(r.key, r.value)
                elif r.kind == "update":
                    store.update(r.key, r.value)
                else:
                    store.delete(r.key)
                out[r.rid] = None
        self.applied_writes += len(self._writes)
        self._writes.clear()
        return out

    def run(self, store, flush: bool = True) -> dict[int, Any]:
        """Drive all pending requests through the store: writes first (in
        order), one batched sync, then the batched read paths.  Returns
        {rid: response} with in-order semantics per request id."""
        out = self._apply_writes(store)
        if out:
            # ONE sync covers the whole write burst — the paper's batched
            # PCIe synchronization (delta export scales with the burst)
            store.export_snapshot()
            self.syncs += 1
        for (kind, _), reqs in list(self._buckets.items()):
            while reqs and (flush or len(reqs) >= self.batch_size):
                batch = reqs[: self.batch_size]
                del reqs[: self.batch_size]
                self.dispatched_batches += 1
                self.dispatched_requests += len(batch)
                if kind == "get":
                    res = store.get_batch([r.key for r in batch])
                else:
                    res = store.scan_batch([(r.key, r.hi) for r in batch])
                for r, v in zip(batch, res):
                    out[r.rid] = v
        return out
