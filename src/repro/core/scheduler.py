"""Request scheduling: out-of-order, shard-aware, epoch-pipelined batch
composition (paper Sections 4.1 and 3-4, lifted to the sharded stack).

The FPGA avoids head-of-line blocking by letting requests complete out of
order.  In SPMD execution the whole batch advances in lock step, so the
equivalent straggler mitigation is *batch composition*: read requests are
bucketed by ``(shard, replica, kind, cost_class)`` — owning range-shard
first, then the replica the store's read-spreading policy assigned
(core/replica.py; replica 0 — the primary — when the store is not
replicated), then expected work (scan width) — so a vectorized step is
neither held hostage by one expensive lane nor scattered across device
snapshots, and responses are re-ordered back to arrival order on
completion: out-of-order execution with in-order delivery, exactly the
accelerator's contract.

Requests are TYPED OPS (core/api.py): ``submit_op`` takes a ``Get`` /
``Scan`` / ``Put`` / ``Update`` / ``Delete`` message and the internal
``Request`` is a thin envelope — rid + op + routing pins (shard, replica).
The stringly ``submit(kind, key, ...)`` facade remains as a shim that
builds the op and delegates, so both APIs share ONE execution path
(tested op-for-op identical, including sync byte counts).  Routing comes
from the STORE — pass ``routing=store.routing()`` (the ``HoneycombService``
wires it automatically); callers no longer thread ``shard_of`` /
``replica_of`` callbacks by hand.  With no routing, everything buckets to
shard 0, which reproduces the unsharded behaviour exactly.

Writes are first-class requests too.  One ``run()`` performs the serving
stack's full cycle as three EXPLICIT pipeline stages (the design doc lives
in core/pipeline.py):

  1. ``stage_admit``   — apply every pending write host-side, in submission
     order, routed to its owning shard (automatic per-shard policy syncs
     deferred for the burst);
  2. ``stage_export``  — ONE host->device delta sync per DIRTY shard — the
     paper's batched synchronization, per device;
  3. ``stage_dispatch`` — dense per-shard read batches
     (``ready_batches()`` is the single source of dispatch order — run()
     consumes it, so the two can never disagree).

``pipeline`` selects how the stages compose:

  * ``"serial"`` (default) — the pre-pipeline sequence, op-for-op: one
    facade ``export_snapshot()`` covering every dirty shard, then reads.
    The blocking PCIe barrier the serial design implies is modeled with
    ``jax.block_until_ready`` on the synced snapshots and metered as
    ``stats.sync_stall_s``.
  * ``"pipelined"`` — double-buffered epochs: every dirty shard's delta is
    STAGED into its standby buffer (asynchronous scatter enqueue), each
    shard flips independently, and read batches dispatch immediately —
    shard A's reads execute while shard B's scatter is still in the device
    queue, and consecutive ``run()`` epochs overlap because nothing ever
    blocks.  Results and sync byte counts are identical to serial mode by
    construction (reads always execute against the flipped epoch).

``run_ops()`` resolves every request to a stamped ``Response`` (status,
value/items, the serving replica, and the read version the answering
snapshot served at — the linearizability stamp); ``run()`` is the legacy
shim that unwraps responses to bare values.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Iterable, Sequence

import jax

from .api import (NOT_FOUND, OK, OPS_BY_KIND, WRITE_KINDS, Op, Response,
                  Routing, Scan)
from .pipeline import PIPELINE_MODES, PipelineStats
from .telemetry import CLOCK
from ..analysis import epochsan as _epochsan

_now = CLOCK            # THE injectable monotonic clock (core/telemetry.py)


@dataclasses.dataclass
class Request:
    """Thin envelope around one submitted op: the sequence number plus the
    routing pins (owning shard; replica assigned at submit so batches stay
    replica-homogeneous).  The legacy field views (kind/key/hi/value/
    expected_items) read through to the op."""
    rid: int
    op: Op
    shard: int = 0
    replica: int = 0           # replica the read is pinned to (0 = primary)

    @property
    def kind(self) -> str:
        return self.op.KIND

    @property
    def key(self) -> bytes:
        return self.op.route_key

    @property
    def hi(self) -> bytes:
        return getattr(self.op, "hi", b"")

    @property
    def value(self) -> bytes:
        return getattr(self.op, "value", b"")

    @property
    def expected_items(self) -> int:
        return self.op.expected_items


class OutOfOrderScheduler:
    """Buckets read ops by (shard, replica, kind, cost class), queues
    writes in order, runs the admit/export/dispatch pipeline stages,
    reassembles stamped responses in arrival order."""

    def __init__(self, batch_size: int = 256,
                 cost_classes: Sequence[int] = (1, 4, 16, 64),
                 routing: Routing | None = None,
                 pipeline: str = "serial",
                 telemetry=None):
        assert pipeline in PIPELINE_MODES, (
            f"unknown pipeline mode {pipeline!r} (one of {PIPELINE_MODES})")
        self.batch_size = batch_size
        self.cost_classes = tuple(sorted(cost_classes))
        self.pipeline = pipeline
        self.stats = PipelineStats()
        # observability (core/telemetry.py): when wired, the scheduler
        # registers its stage meters, records per-request device-latency
        # histograms at dispatch, and drives the sampled lifecycle tracer
        # (submit -> admit -> export_stage -> flip -> dispatch -> resolve).
        # telemetry=None (or disabled) leaves only `is None` branches on
        # the hot path — behaviour is byte-identical to pre-telemetry.
        self.telemetry = (telemetry if telemetry is not None
                          and telemetry.enabled else None)
        self._tracer = (self.telemetry.tracer
                        if self.telemetry is not None else None)
        if self.telemetry is not None:
            self.telemetry.wire_scheduler(self)
            self._lat_hist = {
                "get": self.telemetry.histogram("read_get_latency_seconds",
                                                layer="scheduler"),
                "scan": self.telemetry.histogram("read_scan_latency_seconds",
                                                 layer="scheduler"),
            }
            self._req_hist = self.telemetry.histogram(
                "request_latency_seconds", layer="scheduler")
        else:
            self._lat_hist = None
            self._req_hist = None
        # store-provided wiring (store.routing() — core/api.py): key ->
        # owning shard, the replica read-spreading pick, and the response
        # stamps.  None routes everything to shard 0 and never forwards a
        # replica pin, reproducing the unsharded/unreplicated behaviour.
        self.routing = routing
        self._shard_of = routing.shard_of if routing else (lambda key: 0)
        self._replica_of = routing.replica_of if routing else None
        self._buckets: dict[tuple[int, int, str, int], list[Request]] = \
            defaultdict(list)
        self._writes: list[Request] = []
        self._next_rid = 0
        self.dispatched_batches = 0
        self.dispatched_requests = 0
        self.applied_writes = 0
        self.syncs = 0             # per-shard host->device syncs run() did

    def _cost_class(self, r: Request) -> int:
        for c in self.cost_classes:
            if r.expected_items <= c:
                return c
        return self.cost_classes[-1]

    def _resolve_routing(self, store) -> Routing | None:
        """Routing for the response stamps: the wired one, else ask the
        store (every Honeycomb facade provides ``routing()``; a store
        without one gets unstamped responses)."""
        if self.routing is not None:
            return self.routing
        rt = getattr(store, "routing", None)
        return rt() if callable(rt) else None

    # --------------------------------------------------------- submission
    def submit_op(self, op: Op) -> int:
        """Submit one typed op (core/api.py); returns its sequence number.
        Reads are pinned to (shard, replica) NOW so batches stay shard- and
        replica-homogeneous; writes keep submission order."""
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid, op, shard=self._shard_of(op.route_key))
        if op.IS_WRITE:
            self._writes.append(r)      # writes keep submission order
        else:
            if self._replica_of is not None:
                r.replica = self._replica_of(r.shard)
            self._buckets[(r.shard, r.replica, op.KIND,
                           self._cost_class(r))].append(r)
        if self._tracer is not None:
            self._tracer.begin(rid, op.KIND, shard=r.shard,
                               replica=r.replica)
        return rid

    def submit(self, kind: str, key: bytes, hi: bytes = b"",
               value: bytes = b"", expected_items: int = 1) -> int:
        """Legacy stringly facade — builds the typed op and delegates to
        ``submit_op`` (ONE execution path; tested op-for-op identical)."""
        cls = OPS_BY_KIND.get(kind)
        assert cls is not None, f"unknown request kind {kind!r}"
        if cls is Scan:
            return self.submit_op(Scan(key, hi, expected_items))
        if cls.IS_WRITE and kind != "delete":
            return self.submit_op(cls(key, value))
        return self.submit_op(cls(key))

    def ready_batches(self, flush: bool = False
                      ) -> Iterable[tuple[str, list[Request]]]:
        """Full read batches (or all remaining when flushing), densest
        first.  Every batch is shard-, replica- and cost-homogeneous.  This
        is THE dispatch order — run() consumes it."""
        for (_, _, kind, _), reqs in sorted(self._buckets.items(),
                                            key=lambda kv: -len(kv[1])):
            while len(reqs) >= self.batch_size or (flush and reqs):
                batch = reqs[: self.batch_size]
                del reqs[: self.batch_size]
                yield kind, batch

    # -------------------------------------------------------------- stages
    def stage_admit(self, store) -> dict[int, Response]:
        """Stage 1 — host-side write phase: every queued write in submission
        order, applied by its op and routed by the store facade, no device
        sync in between (that is the whole point) — each shard's own
        "every_k" policy is deferred for the duration of the burst.  Write
        responses are stamped with the host-tree version at which the
        write became visible."""
        t0 = _now()
        out: dict[int, Response] = {}
        rt = self._resolve_routing(store) if self._writes else None
        tr = self._tracer
        with store.deferred_sync():
            for r in self._writes:
                if tr is not None and tr.is_live(r.rid):
                    a0 = _now()
                    r.op.apply(store)
                    tr.span(r.rid, "admit", a0, _now(), shard=r.shard)
                else:
                    r.op.apply(store)
                out[r.rid] = Response(
                    status=OK, shard=r.shard,
                    serving_version=(rt.live_version(r.shard) if rt else 0))
        self.applied_writes += len(self._writes)
        self._writes.clear()
        self.stats.admit_s += _now() - t0
        return out

    def stage_export(self, store) -> None:
        """Stage 2 — one delta sync per DIRTY shard, covering the whole
        write burst (the paper's batched PCIe synchronization; clean shards
        are untouched).

        Serial mode exports and publishes through the facade's
        ``export_snapshot()`` and then BLOCKS until the scatters complete
        (the modeled sync barrier: reads may not be issued until the DMA is
        done); the wait is metered as ``sync_stall_s``.  Pipelined mode
        stages every dirty shard's standby buffer — the scatters are only
        ENQUEUED, and a replicated shard's group hook enqueues one scatter
        per replica lane CONCURRENTLY before any flip — then flips each
        shard independently; read batches dispatch while the scatters
        drain, so the only stall is host staging time."""
        before = store.sync_stats.snapshots
        t0 = _now()
        if self.pipeline == "serial":
            snaps = store.export_snapshot()
            t_mid = _now()
            jax.block_until_ready(snaps)
        else:
            store.begin_export()
            t_mid = _now()
            store.flip()
        t1 = _now()
        dt = t1 - t0
        self.stats.sync_stall_s += dt   # no reads dispatched yet this epoch
        self.stats.export_s += dt
        self.syncs += store.sync_stats.snapshots - before
        if self._tracer is not None and self._tracer.live_count:
            # the export covers the whole epoch, so attach both stage
            # spans to every in-flight trace.  Serial: export_stage is
            # the staging+publish, flip the modeled blocking barrier
            # (block_until_ready); pipelined: export_stage stages the
            # standby, flip is the atomic per-shard publish.
            self._tracer.span_all("export_stage", t0, t_mid)
            self._tracer.span_all("flip", t_mid, t1)
        san = _epochsan.get()
        if san is not None:   # stage_export's contract: staged => flipped
            san.check_exported(store)

    def stage_dispatch(self, store, flush: bool = True
                       ) -> dict[int, Response]:
        """Stage 3 — consume ``ready_batches()``: dense, shard- and
        cost-homogeneous device batches, responses reassembled to arrival
        order and stamped from the store's serving report (the replica lane
        that actually answered — a lagging-follower pin redirects to the
        primary — and the read version of its snapshot).  Device-lane
        occupancy is accumulated from the STORE's meters (the shard is
        where ``bucket_pow2`` padding actually happens, including the
        router's per-shard sub-batches and floor back-fill probes), so it
        reflects real device lanes, not the scheduler-level batch sizes."""
        t0 = _now()
        ps = store.pipeline_stats
        lanes0, padded0 = ps.dispatched_lanes, ps.padded_lanes
        rt = self._resolve_routing(store)
        out: dict[int, Response] = {}
        tm, tr = self.telemetry, self._tracer
        for kind, batch in self.ready_batches(flush=flush):
            self.dispatched_batches += 1
            self.dispatched_requests += len(batch)
            shard = batch[0].shard
            # batches are replica-homogeneous; forward the pin only when a
            # read-spreading policy is wired (plain stores take no replica)
            kw = ({"replica": batch[0].replica}
                  if self._replica_of is not None else {})
            b0 = _now() if tm is not None else 0.0
            if kind == "get":
                res = store.get_batch([r.key for r in batch], **kw)
            else:
                res = store.scan_batch([(r.key, r.hi) for r in batch], **kw)
            served, rv = (rt.report(shard) if rt is not None
                          else (batch[0].replica, 0))
            if tm is not None:
                b1 = _now()
                # spread the batch's device time over its requests: one
                # weighted record per batch keeps the histogram O(1)
                self._lat_hist[kind].record((b1 - b0) / len(batch),
                                            n=len(batch))
                if tr is not None and tr.live_count:
                    for r in batch:
                        if tr.is_live(r.rid):
                            tr.span(r.rid, "dispatch", b0, b1, shard=shard,
                                    replica=served, serving_version=rv)
            for r, v in zip(batch, res):
                if kind == "get":
                    out[r.rid] = Response(
                        status=OK if v is not None else NOT_FOUND,
                        value=v, serving_version=rv, shard=shard,
                        replica=served)
                else:
                    out[r.rid] = Response(
                        status=OK, items=v, serving_version=rv,
                        shard=shard, replica=served)
        ps = store.pipeline_stats
        self.stats.dispatched_lanes += ps.dispatched_lanes - lanes0
        self.stats.padded_lanes += ps.padded_lanes - padded0
        self.stats.dispatch_s += _now() - t0
        return out

    # ---------------------------------------------------------- the epoch
    def run_ops(self, store, flush: bool = True) -> dict[int, Response]:
        """Drive all pending ops through the store: one full pipeline epoch
        — admit writes (in order), sync each dirty shard, dispatch the
        batched read paths.  Returns {rid: Response} with in-order
        semantics per sequence number."""
        out = self.stage_admit(store)
        if out:
            self.stage_export(store)
        out.update(self.stage_dispatch(store, flush=flush))
        self.stats.runs += 1
        if self._tracer is not None and self._tracer.live_count:
            self._finish_traces(store, out)
        return out

    def _finish_traces(self, store,
                       out: dict[int, Response]) -> None:
        """Resolve every live trace whose response landed this epoch:
        stamp it with the response's (shard, replica, serving_version)
        plus the serving shard's snapshot epoch, append the resolve
        instant, and record the submit->resolve request latency."""
        tr = self._tracer
        epochs = getattr(store, "per_shard_epochs", None)
        for rid in tr.live_rids():
            resp = out.get(rid)
            if resp is None:
                continue        # not resolved this epoch (flush=False)
            epoch = (epochs[resp.shard] if epochs is not None
                     else getattr(store, "epoch", 0))
            t = tr.finish(rid, shard=resp.shard, replica=resp.replica,
                          serving_version=resp.serving_version,
                          epoch=epoch, status=resp.status)
            if t is not None:
                self._req_hist.record(max(t.t1 - t.t0, 0.0))

    def run(self, store, flush: bool = True) -> dict[int, Any]:
        """Legacy shim over ``run_ops``: same epoch, responses unwrapped to
        bare values ({rid: value | items | None}) — byte-for-byte the
        pre-service behaviour."""
        return {rid: resp.unwrap()
                for rid, resp in self.run_ops(store, flush=flush).items()}
