"""Request scheduling: out-of-order, shard-aware batch composition
(paper Section 4.1, lifted to the sharded serving stack).

The FPGA avoids head-of-line blocking by letting requests complete out of
order.  In SPMD execution the whole batch advances in lock step, so the
equivalent straggler mitigation is *batch composition*: read requests are
bucketed by ``(shard, kind, cost_class)`` — owning range-shard first, then
expected work (scan width) — so a vectorized step is neither held hostage by
one expensive lane nor scattered across device snapshots, and responses are
re-ordered back to arrival order on completion: out-of-order execution with
in-order delivery, exactly the accelerator's contract.

Writes are first-class requests too.  One ``run()`` performs the sharded
serving stack's full cycle:

  1. apply every pending write host-side, in submission order, routed to
     its owning shard (automatic per-shard policy syncs deferred);
  2. ONE host->device delta sync per DIRTY shard — the paper's batched
     synchronization (Sections 3-4), per device;
  3. dispatch dense per-shard read batches (``ready_batches()`` is the
     single source of dispatch order — run() consumes it, so the two can
     never disagree).

Bucketing by shard requires a routing function: pass
``shard_of=router.shard_for_key`` when driving a ``ShardedHoneycombStore``;
the default routes everything to shard 0, which reproduces the unsharded
behaviour exactly.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Iterable, Sequence

WRITE_KINDS = ("put", "update", "delete")


@dataclasses.dataclass
class Request:
    rid: int
    kind: str                  # "get" | "scan" | "put" | "update" | "delete"
    key: bytes = b""
    hi: bytes = b""
    value: bytes = b""
    expected_items: int = 1


class OutOfOrderScheduler:
    """Buckets read requests by (shard, kind, cost class), queues writes in
    order, dispatches dense per-shard batches, reassembles responses in
    arrival order."""

    def __init__(self, batch_size: int = 256,
                 cost_classes: Sequence[int] = (1, 4, 16, 64),
                 shard_of: Callable[[bytes], int] | None = None):
        self.batch_size = batch_size
        self.cost_classes = tuple(sorted(cost_classes))
        # routing function key -> owning shard; SCANs bucket by their lo key
        # (the store facade still decomposes any cross-shard tail)
        self._shard_of = shard_of or (lambda key: 0)
        self._buckets: dict[tuple[int, str, int], list[Request]] = \
            defaultdict(list)
        self._writes: list[Request] = []
        self._next_rid = 0
        self.dispatched_batches = 0
        self.dispatched_requests = 0
        self.applied_writes = 0
        self.syncs = 0             # per-shard host->device syncs run() did

    def _cost_class(self, r: Request) -> int:
        for c in self.cost_classes:
            if r.expected_items <= c:
                return c
        return self.cost_classes[-1]

    def submit(self, kind: str, key: bytes, hi: bytes = b"",
               value: bytes = b"", expected_items: int = 1) -> int:
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid, kind, key, hi, value, expected_items)
        if kind in WRITE_KINDS:
            self._writes.append(r)      # writes keep submission order
        else:
            self._buckets[(self._shard_of(key), kind,
                           self._cost_class(r))].append(r)
        return rid

    def ready_batches(self, flush: bool = False
                      ) -> Iterable[tuple[str, list[Request]]]:
        """Full read batches (or all remaining when flushing), densest
        first.  Every batch is shard- and cost-homogeneous.  This is THE
        dispatch order — run() consumes it."""
        for (_, kind, _), reqs in sorted(self._buckets.items(),
                                         key=lambda kv: -len(kv[1])):
            while len(reqs) >= self.batch_size or (flush and reqs):
                batch = reqs[: self.batch_size]
                del reqs[: self.batch_size]
                yield kind, batch

    def _apply_writes(self, store) -> dict[int, Any]:
        """Host-side write phase: every queued write in submission order,
        routed by the store facade, no device sync in between (that is the
        whole point) — each shard's own "every_k" policy is deferred for
        the duration of the burst."""
        out: dict[int, Any] = {}
        with store.deferred_sync():
            for r in self._writes:
                if r.kind == "put":
                    store.put(r.key, r.value)
                elif r.kind == "update":
                    store.update(r.key, r.value)
                else:
                    store.delete(r.key)
                out[r.rid] = None
        self.applied_writes += len(self._writes)
        self._writes.clear()
        return out

    def run(self, store, flush: bool = True) -> dict[int, Any]:
        """Drive all pending requests through the store: writes first (in
        order), one batched sync per dirty shard, then the batched read
        paths.  Returns {rid: response} with in-order semantics per request
        id."""
        out = self._apply_writes(store)
        if out:
            # ONE sync per dirty shard covers the whole write burst — the
            # paper's batched PCIe synchronization (delta export scales
            # with the burst); clean shards are untouched
            before = store.sync_stats.snapshots
            store.export_snapshot()
            self.syncs += store.sync_stats.snapshots - before
        for kind, batch in self.ready_batches(flush=flush):
            self.dispatched_batches += 1
            self.dispatched_requests += len(batch)
            if kind == "get":
                res = store.get_batch([r.key for r in batch])
            else:
                res = store.scan_batch([(r.key, r.hi) for r in batch])
            for r, v in zip(batch, res):
                out[r.rid] = v
        return out
