"""Request scheduling: out-of-order, shard-aware, epoch-pipelined batch
composition (paper Sections 4.1 and 3-4, lifted to the sharded stack).

The FPGA avoids head-of-line blocking by letting requests complete out of
order.  In SPMD execution the whole batch advances in lock step, so the
equivalent straggler mitigation is *batch composition*: read requests are
bucketed by ``(shard, replica, kind, cost_class)`` — owning range-shard
first, then the replica the router's read-spreading policy assigned
(core/replica.py; replica 0 — the primary — when the store is not
replicated), then expected work (scan width) — so a vectorized step is
neither held hostage by one expensive lane nor scattered across device
snapshots, and responses are re-ordered back to arrival order on
completion: out-of-order execution with in-order delivery, exactly the
accelerator's contract.

Writes are first-class requests too.  One ``run()`` performs the serving
stack's full cycle as three EXPLICIT pipeline stages (the design doc lives
in core/pipeline.py):

  1. ``stage_admit``   — apply every pending write host-side, in submission
     order, routed to its owning shard (automatic per-shard policy syncs
     deferred for the burst);
  2. ``stage_export``  — ONE host->device delta sync per DIRTY shard — the
     paper's batched synchronization, per device;
  3. ``stage_dispatch`` — dense per-shard read batches
     (``ready_batches()`` is the single source of dispatch order — run()
     consumes it, so the two can never disagree).

``pipeline`` selects how the stages compose:

  * ``"serial"`` (default) — the pre-pipeline sequence, op-for-op: one
    facade ``export_snapshot()`` covering every dirty shard, then reads.
    The blocking PCIe barrier the serial design implies is modeled with
    ``jax.block_until_ready`` on the synced snapshots and metered as
    ``stats.sync_stall_s``.
  * ``"pipelined"`` — double-buffered epochs: every dirty shard's delta is
    STAGED into its standby buffer (asynchronous scatter enqueue), each
    shard flips independently, and read batches dispatch immediately —
    shard A's reads execute while shard B's scatter is still in the device
    queue, and consecutive ``run()`` epochs overlap because nothing ever
    blocks.  Results and sync byte counts are identical to serial mode by
    construction (reads always execute against the flipped epoch).

Bucketing by shard requires a routing function: pass
``shard_of=router.shard_for_key`` when driving a ``ShardedHoneycombStore``;
the default routes everything to shard 0, which reproduces the unsharded
behaviour exactly.  Read spreading over replicas likewise: pass
``replica_of=router.replica_for_dispatch`` and each read is pinned to a
replica AT SUBMIT (so batches stay replica-homogeneous); dispatch forwards
the pin to the store, whose replica group still enforces the freshness
rule (a lagging follower is skipped, never served stale).  In
``pipeline="pipelined"`` mode ``stage_export`` stages all replicas of a
dirty shard concurrently — the group's ``begin_export`` hook enqueues one
standby scatter per replica lane before any flip.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Callable, Iterable, Sequence

import jax

from .pipeline import PIPELINE_MODES, PipelineStats

WRITE_KINDS = ("put", "update", "delete")

_now = time.perf_counter


@dataclasses.dataclass
class Request:
    rid: int
    kind: str                  # "get" | "scan" | "put" | "update" | "delete"
    key: bytes = b""
    hi: bytes = b""
    value: bytes = b""
    expected_items: int = 1
    replica: int = 0           # replica the read is pinned to (0 = primary)


class OutOfOrderScheduler:
    """Buckets read requests by (shard, replica, kind, cost class), queues
    writes in order, runs the admit/export/dispatch pipeline stages,
    reassembles responses in arrival order."""

    def __init__(self, batch_size: int = 256,
                 cost_classes: Sequence[int] = (1, 4, 16, 64),
                 shard_of: Callable[[bytes], int] | None = None,
                 replica_of: Callable[[int], int] | None = None,
                 pipeline: str = "serial"):
        assert pipeline in PIPELINE_MODES, (
            f"unknown pipeline mode {pipeline!r} (one of {PIPELINE_MODES})")
        self.batch_size = batch_size
        self.cost_classes = tuple(sorted(cost_classes))
        self.pipeline = pipeline
        self.stats = PipelineStats()
        # routing function key -> owning shard; SCANs bucket by their lo key
        # (the store facade still decomposes any cross-shard tail)
        self._shard_of = shard_of or (lambda key: 0)
        # read-spreading assignment shard -> replica (the router's policy);
        # None pins everything to the primary and never forwards a pin, so
        # stores without a replica parameter keep working unchanged
        self._replica_of = replica_of
        self._buckets: dict[tuple[int, int, str, int], list[Request]] = \
            defaultdict(list)
        self._writes: list[Request] = []
        self._next_rid = 0
        self.dispatched_batches = 0
        self.dispatched_requests = 0
        self.applied_writes = 0
        self.syncs = 0             # per-shard host->device syncs run() did

    def _cost_class(self, r: Request) -> int:
        for c in self.cost_classes:
            if r.expected_items <= c:
                return c
        return self.cost_classes[-1]

    def submit(self, kind: str, key: bytes, hi: bytes = b"",
               value: bytes = b"", expected_items: int = 1) -> int:
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid, kind, key, hi, value, expected_items)
        if kind in WRITE_KINDS:
            self._writes.append(r)      # writes keep submission order
        else:
            shard = self._shard_of(key)
            if self._replica_of is not None:
                r.replica = self._replica_of(shard)
            self._buckets[(shard, r.replica, kind,
                           self._cost_class(r))].append(r)
        return rid

    def ready_batches(self, flush: bool = False
                      ) -> Iterable[tuple[str, list[Request]]]:
        """Full read batches (or all remaining when flushing), densest
        first.  Every batch is shard-, replica- and cost-homogeneous.  This
        is THE dispatch order — run() consumes it."""
        for (_, _, kind, _), reqs in sorted(self._buckets.items(),
                                            key=lambda kv: -len(kv[1])):
            while len(reqs) >= self.batch_size or (flush and reqs):
                batch = reqs[: self.batch_size]
                del reqs[: self.batch_size]
                yield kind, batch

    # -------------------------------------------------------------- stages
    def stage_admit(self, store) -> dict[int, Any]:
        """Stage 1 — host-side write phase: every queued write in submission
        order, routed by the store facade, no device sync in between (that
        is the whole point) — each shard's own "every_k" policy is deferred
        for the duration of the burst."""
        t0 = _now()
        out: dict[int, Any] = {}
        with store.deferred_sync():
            for r in self._writes:
                if r.kind == "put":
                    store.put(r.key, r.value)
                elif r.kind == "update":
                    store.update(r.key, r.value)
                else:
                    store.delete(r.key)
                out[r.rid] = None
        self.applied_writes += len(self._writes)
        self._writes.clear()
        self.stats.admit_s += _now() - t0
        return out

    def stage_export(self, store) -> None:
        """Stage 2 — one delta sync per DIRTY shard, covering the whole
        write burst (the paper's batched PCIe synchronization; clean shards
        are untouched).

        Serial mode exports and publishes through the facade's
        ``export_snapshot()`` and then BLOCKS until the scatters complete
        (the modeled sync barrier: reads may not be issued until the DMA is
        done); the wait is metered as ``sync_stall_s``.  Pipelined mode
        stages every dirty shard's standby buffer — the scatters are only
        ENQUEUED, and a replicated shard's group hook enqueues one scatter
        per replica lane CONCURRENTLY before any flip — then flips each
        shard independently; read batches dispatch while the scatters
        drain, so the only stall is host staging time."""
        before = store.sync_stats.snapshots
        t0 = _now()
        if self.pipeline == "serial":
            snaps = store.export_snapshot()
            jax.block_until_ready(snaps)
        else:
            store.begin_export()
            store.flip()
        dt = _now() - t0
        self.stats.sync_stall_s += dt   # no reads dispatched yet this epoch
        self.stats.export_s += dt
        self.syncs += store.sync_stats.snapshots - before

    def stage_dispatch(self, store, flush: bool = True) -> dict[int, Any]:
        """Stage 3 — consume ``ready_batches()``: dense, shard- and
        cost-homogeneous device batches, responses reassembled to arrival
        order.  Device-lane occupancy is accumulated from the STORE's
        meters (the shard is where ``bucket_pow2`` padding actually
        happens, including the router's per-shard sub-batches and floor
        back-fill probes), so it reflects real device lanes, not the
        scheduler-level batch sizes."""
        t0 = _now()
        ps = store.pipeline_stats
        lanes0, padded0 = ps.dispatched_lanes, ps.padded_lanes
        out: dict[int, Any] = {}
        for kind, batch in self.ready_batches(flush=flush):
            self.dispatched_batches += 1
            self.dispatched_requests += len(batch)
            # batches are replica-homogeneous; forward the pin only when a
            # read-spreading policy is wired (plain stores take no replica)
            kw = ({"replica": batch[0].replica}
                  if self._replica_of is not None else {})
            if kind == "get":
                res = store.get_batch([r.key for r in batch], **kw)
            else:
                res = store.scan_batch([(r.key, r.hi) for r in batch], **kw)
            for r, v in zip(batch, res):
                out[r.rid] = v
        ps = store.pipeline_stats
        self.stats.dispatched_lanes += ps.dispatched_lanes - lanes0
        self.stats.padded_lanes += ps.padded_lanes - padded0
        self.stats.dispatch_s += _now() - t0
        return out

    def run(self, store, flush: bool = True) -> dict[int, Any]:
        """Drive all pending requests through the store: one full pipeline
        epoch — admit writes (in order), sync each dirty shard, dispatch the
        batched read paths.  Returns {rid: response} with in-order semantics
        per request id."""
        out = self.stage_admit(store)
        if out:
            self.stage_export(store)
        out.update(self.stage_dispatch(store, flush=flush))
        self.stats.runs += 1
        return out
