"""Request scheduling: out-of-order batch composition (paper Section 4.1).

The FPGA avoids head-of-line blocking by letting requests complete out of
order.  In SPMD execution the whole batch advances in lock step, so the
equivalent straggler mitigation is *batch composition*: requests with similar
expected work (scan width, key size) are bucketed together so a vectorized
step is not held hostage by one expensive lane, and responses are re-ordered
back to arrival order on completion — out-of-order execution with in-order
delivery, exactly the accelerator's contract.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Iterable, Sequence


@dataclasses.dataclass
class Request:
    rid: int
    kind: str                  # "get" | "scan"
    key: bytes = b""
    hi: bytes = b""
    expected_items: int = 1


class OutOfOrderScheduler:
    """Buckets requests by cost class, dispatches dense batches, reassembles
    responses in arrival order."""

    def __init__(self, batch_size: int = 256,
                 cost_classes: Sequence[int] = (1, 4, 16, 64)):
        self.batch_size = batch_size
        self.cost_classes = tuple(sorted(cost_classes))
        self._buckets: dict[tuple[str, int], list[Request]] = defaultdict(list)
        self._next_rid = 0
        self.dispatched_batches = 0
        self.dispatched_requests = 0

    def _cost_class(self, r: Request) -> int:
        for c in self.cost_classes:
            if r.expected_items <= c:
                return c
        return self.cost_classes[-1]

    def submit(self, kind: str, key: bytes, hi: bytes = b"",
               expected_items: int = 1) -> int:
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid, kind, key, hi, expected_items)
        self._buckets[(kind, self._cost_class(r))].append(r)
        return rid

    def ready_batches(self, flush: bool = False
                      ) -> Iterable[tuple[str, list[Request]]]:
        """Full batches (or all remaining when flushing), densest first."""
        for (kind, _), reqs in sorted(self._buckets.items(),
                                      key=lambda kv: -len(kv[1])):
            while len(reqs) >= self.batch_size or (flush and reqs):
                batch = reqs[: self.batch_size]
                del reqs[: self.batch_size]
                yield kind, batch

    def run(self, store, flush: bool = True) -> dict[int, Any]:
        """Drive all pending requests through the store's batched paths and
        return {rid: response} with in-order semantics per request id."""
        out: dict[int, Any] = {}
        for (kind, _), reqs in list(self._buckets.items()):
            while reqs and (flush or len(reqs) >= self.batch_size):
                batch = reqs[: self.batch_size]
                del reqs[: self.batch_size]
                self.dispatched_batches += 1
                self.dispatched_requests += len(batch)
                if kind == "get":
                    res = store.get_batch([r.key for r in batch])
                else:
                    res = store.scan_batch([(r.key, r.hi) for r in batch])
                for r, v in zip(batch, res):
                    out[r.rid] = v
        return out
