"""Host-side B+Tree writer (paper Sections 3.1, 3.4, 3.5).

All mutations run here, on the CPU, in numpy — PUT/UPDATE/DELETE fast paths
(log append), sorted+log merges, node splits/merges, and tree growth.  The
accelerator (the batched JAX/Pallas read path) only ever *reads* the arrays
this module maintains.

Protocol fidelity notes:
  * fast path:   lock leaf via CAS on (lock|seqno), append to the log block
                 with back pointer + order hint + version delta, publish via
                 a single packed (size|seqno|lock) store.
  * merge:       new buffer, same LID; version = wv; oldptr -> old buffer;
                 one page-table remap (the per-merge "PCIe command").
  * split:       new LIDs + buffers for both halves of every split node; new
                 buffer, same LID, for the root of the split; in-place sibling
                 pointer updates on the (locked) adjacent leaves; old-version
                 pointers stamped so old-read-version scans traverse the old
                 subtree (linearizable scans, Section 3.4).
  * delete:      delete markers in the log; space reclaimed at merge; leaf
                 underflow merges with its right sibling under the same
                 parent (Section 3.5: "similar techniques ... omit details").

Back-pointer convention (Section 3.1): a log entry points at the sorted-block
item with an equal key if one exists, else at the first sorted item with a
greater key.  The merged enumeration therefore emits log entries immediately
before the sorted item their back pointer names, which keeps the emission
key-ordered; equal keys come out adjacent, newest version first (the order
hints place later equal inserts earlier), so readers resolve duplicates by
taking the maximum visible version (Section 3.3).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .config import HoneycombConfig
from .gc import EpochManager, GarbageCollector
from .heap import (INTERIOR, LEAF, LOG_DELETE, LOG_INSERT, LOG_UPDATE, NULL,
                   NodeHeap, OverflowHeap)
from .keys import key_cmp, pack_key
from .mvcc import VersionManager
from .pagetable import PageTable
from .telemetry import samples_from

MAX_RESTARTS = 64


class _Restart(Exception):
    """Lock acquisition failed against a changed seqno — retry the op."""


@dataclasses.dataclass
class TreeStats:
    puts: int = 0
    updates: int = 0
    deletes: int = 0
    fast_path: int = 0
    merges: int = 0
    splits: int = 0
    node_merges: int = 0
    restarts: int = 0
    grows: int = 0

    def collect(self):
        """Registry samples (core/telemetry.py collect protocol):
        ``tree_*`` counters for the host writer's op/maintenance mix."""
        return samples_from(self, "tree", "btree")


@dataclasses.dataclass
class _PathEntry:
    lid: int
    phys: int
    seqno: int
    slot_in_parent: int  # -1 => reached via parent's left_child


class HoneycombTree:
    def __init__(self, cfg: HoneycombConfig | None = None,
                 heap_capacity: int = 1024):
        self.cfg = cfg or HoneycombConfig()
        self.heap = NodeHeap(self.cfg, heap_capacity)
        self.overflow = OverflowHeap(self.cfg)
        self.pt = PageTable(heap_capacity)
        self.versions = VersionManager(self.cfg.mvcc)
        self.epochs = EpochManager()
        self.gc = GarbageCollector(
            self.epochs, self.heap.free, self.pt.free_lid, self.overflow.free)
        self.stats = TreeStats()
        self.last_placement = None   # set per write; see _write

        # bootstrap: the tree is a single empty leaf
        root_phys = self.heap.alloc()
        self.heap.ntype[root_phys] = LEAF
        self.root_lid = self.pt.alloc_lid(root_phys)
        self.height = 1  # levels; a leaf-only tree has height 1

    # ------------------------------------------------------------------ util
    def _pack(self, key: bytes) -> tuple[np.ndarray, int]:
        return pack_key(key, self.cfg.key_words), len(key)

    @staticmethod
    def _key_bytes(lanes: np.ndarray, length: int) -> bytes:
        return lanes.astype(">u4").tobytes()[:length]

    def _store_value(self, val: bytes, out_lanes: np.ndarray) -> int:
        """Inline a value or place it in the overflow heap (paper: values
        above the inline limit live out of node).  Returns byte length."""
        out_lanes[:] = 0
        if len(val) <= self.cfg.max_inline_val_bytes:
            buf = val + b"\x00" * (-len(val) % 4)
            lanes = np.frombuffer(buf, dtype=">u4").astype(np.uint32)
            out_lanes[: len(lanes)] = lanes
        else:
            out_lanes[0] = self.overflow.alloc(val)
        return len(val)

    def _load_value(self, lanes: np.ndarray, length: int) -> bytes:
        if length <= self.cfg.max_inline_val_bytes:
            return lanes.astype(">u4").tobytes()[:length]
        return self.overflow.read(int(lanes[0]))

    def _defer_value(self, lanes, length):
        """GC the overflow slot behind a value that left the live tree."""
        if length > self.cfg.max_inline_val_bytes:
            self.gc.defer(overflow=(int(lanes[0]),))

    # ------------------------------------------------------- node inspection
    def _floor_in_sorted(self, phys: int, klanes, klen) -> int:
        """Largest sorted-block index with key <= query, or -1."""
        h = self.heap
        lo, hi, ans = 0, int(h.nitems[phys]) - 1, -1
        while lo <= hi:
            mid = (lo + hi) // 2
            if key_cmp(h.skeys[phys, mid], int(h.skeylen[phys, mid]),
                       klanes, klen) <= 0:
                ans, lo = mid, mid + 1
            else:
                hi = mid - 1
        return ans

    def _log_backptr(self, phys: int, klanes, klen) -> int:
        """Exact-match index if present, else the upper bound."""
        i = self._floor_in_sorted(phys, klanes, klen)
        h = self.heap
        if i >= 0 and key_cmp(h.skeys[phys, i], int(h.skeylen[phys, i]),
                              klanes, klen) == 0:
            return i
        return i + 1

    def _interior_child(self, phys: int, klanes, klen) -> tuple[int, int]:
        """(child LID, slot index or -1 for left_child)."""
        i = self._floor_in_sorted(phys, klanes, klen)
        if i < 0:
            return int(self.heap.left_child[phys]), -1
        return int(self.heap.svals[phys, i, 0]), i

    # ------------------------------------------------------------- traversal
    def _traverse(self, klanes, klen) -> list[_PathEntry]:
        """Root->leaf walk on the latest versions (writer semantics),
        recording (lid, phys, seqno) for later lock validation."""
        path: list[_PathEntry] = []
        lid, slot = self.root_lid, -1
        for _ in range(self.cfg.max_height + 1):
            phys = self.pt.lookup(lid)
            path.append(_PathEntry(lid=lid, phys=phys,
                                   seqno=self.heap.seqno(phys),
                                   slot_in_parent=slot))
            if int(self.heap.ntype[phys]) == LEAF:
                return path
            lid, slot = self._interior_child(phys, klanes, klen)
        raise RuntimeError("tree height exceeded max_height")

    # --------------------------------------------------------------- reading
    def _resolve_version(self, phys: int, rv: int | None) -> int:
        """Follow the old-version chain until version <= rv (Section 3.2)."""
        h = self.heap
        hops = 0
        while rv is not None and int(h.version[phys]) > rv:
            phys = int(h.oldptr[phys])
            hops += 1
            if phys == NULL or hops > self.cfg.max_version_chain:
                raise RuntimeError("version chain exhausted under reader")
        return phys

    def _resolved_leaf_items(self, phys: int, rv: int | None,
                             with_lanes: bool = False) -> list:
        """Live (key, value) pairs of one leaf at read version rv, sorted.
        This is the reference semantics of the sorted+log merge."""
        h = self.heap
        nv = int(h.version[phys])
        by_key: dict[bytes, tuple[int, int, object, int]] = {}
        for i in range(int(h.nitems[phys])):
            k = self._key_bytes(h.skeys[phys, i], int(h.skeylen[phys, i]))
            by_key[k] = (nv, 0, h.svals[phys, i], int(h.svallen[phys, i]))
        for j in range(int(h.nlog[phys])):
            ver = nv + int(h.log_vdelta[phys, j])
            if rv is not None and ver > rv:
                continue
            k = self._key_bytes(h.log_keys[phys, j],
                                int(h.log_keylen[phys, j]))
            prev = by_key.get(k)
            if prev is not None and (ver, j + 1) < (prev[0], prev[1]):
                continue
            if int(h.log_op[phys, j]) == LOG_DELETE:
                by_key[k] = (ver, j + 1, None, 0)
            else:
                by_key[k] = (ver, j + 1, h.log_vals[phys, j],
                             int(h.log_vallen[phys, j]))
        out = []
        for k in sorted(by_key):
            ver, _, lanes, ln = by_key[k]
            if lanes is None:
                continue
            if with_lanes:
                out.append((k, np.array(lanes, np.uint32), ln))
            else:
                out.append((k, self._load_value(np.asarray(lanes), ln)))
        return out

    def get(self, key: bytes, read_version: int | None = None,
            latest: bool = False) -> bytes | None:
        """Host-side GET.  Readers run at the released read version
        (linearizable); writers pass ``latest`` to see their own effects."""
        rv = None if latest else (
            self.versions.read_version() if read_version is None
            else read_version)
        klanes, klen = self._pack(key)
        h = self.heap
        lid = self.root_lid
        for _ in range(self.cfg.max_height + 1):
            phys = self._resolve_version(self.pt.lookup(lid), rv)
            if int(h.ntype[phys]) == LEAF:
                nv = int(h.version[phys])
                best: tuple[int, int] | None = None   # (version, tag)
                i = self._floor_in_sorted(phys, klanes, klen)
                if i >= 0 and key_cmp(h.skeys[phys, i],
                                      int(h.skeylen[phys, i]),
                                      klanes, klen) == 0:
                    best = (nv, -i - 1)
                for j in range(int(h.nlog[phys])):
                    ver = nv + int(h.log_vdelta[phys, j])
                    if rv is not None and ver > rv:
                        continue
                    if key_cmp(h.log_keys[phys, j],
                               int(h.log_keylen[phys, j]),
                               klanes, klen) != 0:
                        continue
                    if best is None or (ver, j + 1) >= best:
                        best = (ver, j + 1)
                if best is None:
                    return None
                _, tag = best
                if tag < 0:
                    si = -tag - 1
                    return self._load_value(h.svals[phys, si],
                                            int(h.svallen[phys, si]))
                j = tag - 1
                if int(h.log_op[phys, j]) == LOG_DELETE:
                    return None
                return self._load_value(h.log_vals[phys, j],
                                        int(h.log_vallen[phys, j]))
            lid, _ = self._interior_child(phys, klanes, klen)
        raise RuntimeError("tree height exceeded max_height")

    def scan(self, lo: bytes, hi: bytes, max_items: int | None = None,
             read_version: int | None = None) -> list[tuple[bytes, bytes]]:
        """SCAN(K_l, K_u) with the paper's floor-start semantics: begins at
        the largest key <= K_l if one exists (Section 3.3)."""
        rv = (self.versions.read_version() if read_version is None
              else read_version)
        if not self.cfg.mvcc:
            rv = None
        h = self.heap
        lolanes, lolen = self._pack(lo)
        lid = self.root_lid
        phys = self._resolve_version(self.pt.lookup(lid), rv)
        while int(h.ntype[phys]) == INTERIOR:
            lid, _ = self._interior_child(phys, lolanes, lolen)
            phys = self._resolve_version(self.pt.lookup(lid), rv)

        # locate the floor: walk left while this leaf holds nothing <= lo
        floor: tuple[bytes, bytes] | None = None
        start_phys = phys
        for _ in range(64):
            items = self._resolved_leaf_items(start_phys, rv)
            below = [kv for kv in items if kv[0] <= lo]
            if below:
                floor = below[-1]
                break
            nxt = int(h.lsib[start_phys])
            if nxt == NULL:
                break
            start_phys = self._resolve_version(self.pt.lookup(nxt), rv)

        out: list[tuple[bytes, bytes]] = []
        if floor is not None:
            out.append(floor)
            if floor[0] > hi or (max_items and len(out) >= max_items):
                return [kv for kv in out if kv[0] <= hi]
        # forward scan from the descent leaf
        hops = 0
        while phys != NULL and hops < 1024:
            hops += 1
            for k, v in self._resolved_leaf_items(phys, rv):
                if k > hi:
                    return out
                if k <= lo:
                    continue  # floor already emitted
                out.append((k, v))
                if max_items and len(out) >= max_items:
                    return out
            nxt = int(h.rsib[phys])
            phys = (self._resolve_version(self.pt.lookup(nxt), rv)
                    if nxt != NULL else NULL)
        return out

    # ------------------------------------------------------------ write ops
    def put(self, key: bytes, value: bytes, thread: int = 0):
        self.stats.puts += 1
        self._write(key, value, LOG_INSERT, thread)

    def update(self, key: bytes, value: bytes, thread: int = 0):
        self.stats.updates += 1
        self._write(key, value, LOG_UPDATE, thread)

    def delete(self, key: bytes, thread: int = 0):
        self.stats.deletes += 1
        self._write(key, b"", LOG_DELETE, thread)

    def _write(self, key: bytes, value: bytes, op: int, thread: int = 0):
        klanes, klen = self._pack(key)
        # placement record of THIS write if (and only if) it takes the log
        # fast path — (phys, slot, backptr, hint, vdelta), the sidecar the
        # log-shipped replication feed (core/replica.py) needs to replay
        # the wire entry on a follower image.  Merge/split/underflow paths
        # leave it None: those epochs are not replayable.
        self.last_placement = None
        self.epochs.cpu_begin(thread)
        for _ in range(MAX_RESTARTS):
            path = self._traverse(klanes, klen)
            leaf = path[-1]
            if not self.heap.try_lock(leaf.phys, leaf.seqno):
                self.stats.restarts += 1
                continue
            try:
                if int(self.heap.nlog[leaf.phys]) < self.cfg.log_cap:
                    self._fast_path(leaf.phys, klanes, klen, value, op)
                    self.stats.fast_path += 1
                else:
                    self._merge_path(path, klanes, klen, value, op)
                return
            except _Restart:
                continue
        raise RuntimeError("write restarted too many times")

    def _fast_path(self, phys: int, klanes, klen, value: bytes, op: int):
        """Append to the log block of a published leaf (Section 3.4).
        Readers ignore the entry until its version is released."""
        h = self.heap
        j = int(h.nlog[phys])
        nv = int(h.version[phys])
        wv = self.versions.acquire_write_version()
        hint = 0   # rank among current log entries (strictly smaller keys)
        for e in range(j):
            if key_cmp(h.log_keys[phys, e], int(h.log_keylen[phys, e]),
                       klanes, klen) < 0:
                hint += 1
        h.log_keys[phys, j] = klanes
        h.log_keylen[phys, j] = klen
        h.log_vallen[phys, j] = self._store_value(value, h.log_vals[phys, j])
        h.log_op[phys, j] = op
        h.log_backptr[phys, j] = self._log_backptr(phys, klanes, klen)
        h.log_hint[phys, j] = hint
        h.log_vdelta[phys, j] = wv - nv
        self.last_placement = (phys, j, int(h.log_backptr[phys, j]),
                               hint, wv - nv)
        # publish: the paper packs (size | seqno | lock) into one word so the
        # count bump, seqno bump and unlock are a single store
        h.nlog[phys] = j + 1
        h.mark_dirty(phys)         # in-place append -> delta sync this row
        h.unlock_bump(phys)
        self.versions.release(wv)

    # ------------------------------------------------------------ merge path
    def _merge_path(self, path: list[_PathEntry], klanes, klen,
                    value: bytes, op: int):
        """Log merge (Fig. 3), escalating to a split (Fig. 4) on overflow or
        to a sibling merge on underflow.  Leaf lock is held on entry; every
        exit path unlocks."""
        leaf = path[-1]
        # resolve current leaf contents (latest versions — writer view)
        resolved = self._resolved_leaf_items(leaf.phys, rv=None,
                                             with_lanes=True)
        ent = {k: (lanes, ln) for k, lanes, ln in resolved}
        key = self._key_bytes(klanes, klen)
        if key in ent:
            self._defer_value(*ent[key])
        if op == LOG_DELETE:
            ent.pop(key, None)
        else:
            vlanes = np.zeros(self.cfg.val_words, np.uint32)
            vlen = self._store_value(value, vlanes)
            ent[key] = (vlanes, vlen)
        items = [(k, *ent[k]) for k in sorted(ent)]

        if len(items) > self.cfg.node_cap:
            self._split(path, items)
        elif (len(items) < self.cfg.min_fill * self.cfg.node_cap
              and len(path) > 1):
            self._underflow(path, items)
        else:
            self._rebuild_leaf(path, items)

    # ------------------------------------------------------------ node fills
    def _fill_leaf(self, phys: int, items, wv: int):
        """Fresh leaf buffer: sorted block + shortcut selection (Fig. 3)."""
        c, h = self.cfg, self.heap
        h.ntype[phys] = LEAF
        n = len(items)
        h.nitems[phys] = n
        h.version[phys] = wv if c.mvcc else 0
        h.nlog[phys] = 0
        for i, (k, vlanes, vlen) in enumerate(items):
            h.skeys[phys, i] = pack_key(k, c.key_words)
            h.skeylen[phys, i] = len(k)
            h.svals[phys, i] = vlanes
            h.svallen[phys, i] = vlen
        self._fill_shortcuts(phys, [k for k, _, _ in items])

    def _fill_interior(self, phys: int, left_child: int, items, wv: int):
        """items: [(key_bytes, child_lid)]"""
        c, h = self.cfg, self.heap
        h.ntype[phys] = INTERIOR
        h.left_child[phys] = left_child
        n = len(items)
        h.nitems[phys] = n
        h.version[phys] = wv if c.mvcc else 0
        h.nlog[phys] = 0
        for i, (k, child) in enumerate(items):
            h.skeys[phys, i] = pack_key(k, c.key_words)
            h.skeylen[phys, i] = len(k)
            h.svals[phys, i] = 0
            h.svals[phys, i, 0] = child
            h.svallen[phys, i] = 4
        self._fill_shortcuts(phys, [k for k, _ in items])

    def _fill_shortcuts(self, phys: int, keys: list[bytes]):
        """Shortcut selection (Section 3.4): the paper balances segment
        bytes; with fixed-width slots the item count is the byte proxy."""
        c, h = self.cfg, self.heap
        n = len(keys)
        nsc = max(1, min(c.n_shortcuts, -(-n // c.segment_items)))
        h.n_shortcuts[phys] = nsc
        h.sc_keylen[phys, :] = 0
        for s in range(nsc):
            pos = s * c.segment_items
            h.sc_pos[phys, s] = pos
            if pos < n:
                h.sc_keys[phys, s] = pack_key(keys[pos], c.key_words)
                h.sc_keylen[phys, s] = len(keys[pos])

    def _interior_items(self, phys: int) -> list[tuple[bytes, int]]:
        h = self.heap
        return [(self._key_bytes(h.skeys[phys, i], int(h.skeylen[phys, i])),
                 int(h.svals[phys, i, 0]))
                for i in range(int(h.nitems[phys]))]

    # -------------------------------------------------------------- rebuild
    def _rebuild_leaf(self, path: list[_PathEntry], items):
        """Merge of sorted and log blocks (Fig. 3): new buffer, same LID."""
        leaf = path[-1]
        wv = self.versions.acquire_write_version()
        h = self.heap
        new_phys = h.alloc()
        self._fill_leaf(new_phys, items, wv)
        h.lsib[new_phys] = h.lsib[leaf.phys]
        h.rsib[new_phys] = h.rsib[leaf.phys]
        h.oldptr[new_phys] = leaf.phys if self.cfg.mvcc else NULL
        self.pt.remap(leaf.lid, new_phys)          # Fig. 3c
        h.unlock_bump(leaf.phys)                   # old buffer retires
        self.gc.defer(slots=(leaf.phys,))
        self.versions.release(wv)
        self.stats.merges += 1

    # ------------------------------------------------------------------ split
    def _split(self, path: list[_PathEntry], items):
        """Split the leaf (and full ancestors) — Fig. 4.  ``items`` is the
        merged item list that overflows the leaf; the leaf lock is held."""
        c, h = self.cfg, self.heap
        # the split cascades through every full ancestor
        split_levels = [path[-1]]
        k = len(path) - 2
        while k >= 0 and int(h.nitems[path[k].phys]) >= c.node_cap:
            split_levels.append(path[k])
            k -= 1
        root_of_split = path[k] if k >= 0 else None

        # paper: lock all interior nodes to split plus the root of the split
        to_lock = split_levels[1:] + ([root_of_split] if root_of_split else [])
        got = []
        for e in to_lock:
            if not self.heap.try_lock(e.phys, self.heap.seqno(e.phys)):
                for g in got:
                    h.unlock(g.phys)
                h.unlock(path[-1].phys)
                self.stats.restarts += 1
                raise _Restart()
            got.append(e)

        wv = self.versions.acquire_write_version()
        gc_slots: list[int] = []
        gc_lids: list[int] = []

        # --- leaf level -----------------------------------------------------
        leaf = path[-1]
        mid = len(items) // 2
        lphys, rphys = h.alloc(), h.alloc()
        self._fill_leaf(lphys, items[:mid], wv)
        self._fill_leaf(rphys, items[mid:], wv)
        llid, rlid = self.pt.alloc_lid(lphys), self.pt.alloc_lid(rphys)
        h.lsib[lphys] = h.lsib[leaf.phys]
        h.rsib[lphys] = rlid
        h.lsib[rphys] = llid
        h.rsib[rphys] = h.rsib[leaf.phys]
        if c.mvcc:   # old-read-version scans reach the old leaf (Section 3.4)
            h.oldptr[lphys] = leaf.phys
            h.oldptr[rphys] = leaf.phys
        self._relink_sibling(int(h.lsib[leaf.phys]), rsib=llid)
        self._relink_sibling(int(h.rsib[leaf.phys]), lsib=rlid)
        gc_slots.append(leaf.phys)
        gc_lids.append(leaf.lid)
        promoted = (items[mid][0], rlid)
        new_left_lid = llid
        child = leaf

        # --- full interior ancestors ----------------------------------------
        for e in split_levels[1:]:
            it = self._patch_child(self._interior_items(e.phys),
                                   child.slot_in_parent, new_left_lid,
                                   promoted)
            left0 = (new_left_lid if child.slot_in_parent == -1
                     else int(h.left_child[e.phys]))
            # after patching, items may start with the promoted entry when the
            # child came via left_child; recompute cleanly:
            mid_i = len(it) // 2
            mk, mchild = it[mid_i]
            lp, rp = h.alloc(), h.alloc()
            self._fill_interior(lp, left0, it[:mid_i], wv)
            self._fill_interior(rp, mchild, it[mid_i + 1:], wv)
            llid2, rlid2 = self.pt.alloc_lid(lp), self.pt.alloc_lid(rp)
            gc_slots.append(e.phys)
            gc_lids.append(e.lid)
            promoted = (mk, rlid2)
            new_left_lid = llid2
            child = e

        # --- root of the split ------------------------------------------------
        if root_of_split is None:
            new_root = h.alloc()   # grow the tree
            self._fill_interior(new_root, new_left_lid, [promoted], wv)
            if c.mvcc:  # old-read-version walks enter the pre-growth subtree
                h.oldptr[new_root] = child.phys
            self.root_lid = self.pt.alloc_lid(new_root)
            self.height += 1
            self.stats.grows += 1
        else:
            e = root_of_split
            it = self._patch_child(self._interior_items(e.phys),
                                   child.slot_in_parent, new_left_lid,
                                   promoted)
            left0 = (new_left_lid if child.slot_in_parent == -1
                     else int(h.left_child[e.phys]))
            swap = h.alloc()       # N_swap: new buffer, same LID (Fig. 4b)
            self._fill_interior(swap, left0, it, wv)
            if c.mvcc:
                h.oldptr[swap] = e.phys
            self.pt.remap(e.lid, swap)   # Fig. 4c: atomic subtree swap
            gc_slots.append(e.phys)
            h.unlock_bump(e.phys)

        for e in split_levels[1:]:
            h.unlock_bump(e.phys)
        h.unlock_bump(leaf.phys)
        self.gc.defer(slots=gc_slots, lids=gc_lids)
        self.versions.release(wv)
        self.stats.splits += 1

    @staticmethod
    def _patch_child(items: list[tuple[bytes, int]], slot: int,
                     new_left_lid: int,
                     promoted: tuple[bytes, int]) -> list[tuple[bytes, int]]:
        """Re-point the split child's entry at the left half and insert the
        promoted (boundary key, right half) item after it."""
        out = list(items)
        if slot >= 0:
            out[slot] = (out[slot][0], new_left_lid)
            out.insert(slot + 1, promoted)
        else:
            # child was the left_child; caller re-points left_child
            out.insert(0, promoted)
        return out

    def _relink_sibling(self, lid: int, lsib: int | None = None,
                        rsib: int | None = None):
        """Paper: lock the adjacent leaf and update its sibling pointer in
        place (the only in-place mutation besides the log fast path)."""
        if lid == NULL:
            return
        phys = self.pt.lookup(lid)
        ok = self.heap.try_lock(phys, self.heap.seqno(phys))
        assert ok, "sibling lock contention impossible on one host thread"
        if lsib is not None:
            self.heap.lsib[phys] = lsib
        if rsib is not None:
            self.heap.rsib[phys] = rsib
        self.heap.mark_dirty(phys)
        self.heap.unlock_bump(phys)

    # -------------------------------------------------------- underflow merge
    def _underflow(self, path: list[_PathEntry], items):
        """Merge an underfull leaf with its right sibling under the same
        parent when the result fits; otherwise plain rebuild."""
        c, h = self.cfg, self.heap
        leaf, parent = path[-1], path[-2]
        right_slot = leaf.slot_in_parent + 1
        if right_slot >= int(h.nitems[parent.phys]):
            self._rebuild_leaf(path, items)
            return
        rlid = int(h.svals[parent.phys, right_slot, 0])
        rphys = self.pt.lookup(rlid)
        if (int(h.nlog[rphys]) > 0
                or len(items) + int(h.nitems[rphys]) > c.node_cap):
            self._rebuild_leaf(path, items)
            return
        locked = []
        for p, s in ((parent.phys, parent.seqno),
                     (rphys, self.heap.seqno(rphys))):
            if not self.heap.try_lock(p, s):
                for q in locked:
                    h.unlock(q)
                h.unlock(leaf.phys)
                self.stats.restarts += 1
                raise _Restart()
            locked.append(p)

        wv = self.versions.acquire_write_version()
        r_items = [(self._key_bytes(h.skeys[rphys, i],
                                    int(h.skeylen[rphys, i])),
                    h.svals[rphys, i].copy(), int(h.svallen[rphys, i]))
                   for i in range(int(h.nitems[rphys]))]
        newp = h.alloc()
        self._fill_leaf(newp, items + r_items, wv)
        h.lsib[newp] = h.lsib[leaf.phys]
        h.rsib[newp] = h.rsib[rphys]
        if c.mvcc:
            h.oldptr[newp] = leaf.phys
        # the parent loses the separator of the right sibling
        it = self._interior_items(parent.phys)
        del it[right_slot]
        swap = h.alloc()
        self._fill_interior(swap, int(h.left_child[parent.phys]), it, wv)
        if c.mvcc:
            h.oldptr[swap] = parent.phys
        self.pt.remap(leaf.lid, newp)
        self.pt.remap(parent.lid, swap)
        self._relink_sibling(int(h.rsib[rphys]), lsib=leaf.lid)
        h.unlock_bump(rphys)
        h.unlock_bump(parent.phys)
        h.unlock_bump(leaf.phys)
        self.gc.defer(slots=(leaf.phys, rphys, parent.phys), lids=(rlid,))
        self.versions.release(wv)
        self.stats.node_merges += 1

    # ------------------------------------------------------------- validation
    def check_invariants(self):
        """Structural invariants exercised by property tests."""
        leaves: list[int] = []
        self._check_node(self.root_lid, None, None, self.height, leaves)
        # leaf sibling chain is consistent left-to-right
        for a, b in zip(leaves, leaves[1:]):
            pa, pb = self.pt.lookup(a), self.pt.lookup(b)
            assert int(self.heap.rsib[pa]) == b, "broken rsib chain"
            assert int(self.heap.lsib[pb]) == a, "broken lsib chain"

    def _check_node(self, lid: int, lo, hi, levels_left: int, leaves: list):
        h = self.heap
        phys = self.pt.lookup(lid)
        assert phys != NULL, f"dangling LID {lid}"
        n = int(h.nitems[phys])
        keys = [self._key_bytes(h.skeys[phys, i], int(h.skeylen[phys, i]))
                for i in range(n)]
        assert keys == sorted(keys), "sorted block out of order"
        for k in keys:
            assert lo is None or k >= lo, "key below subtree bound"
            assert hi is None or k < hi, "key above subtree bound"
        if int(h.ntype[phys]) == INTERIOR:
            assert levels_left > 1, "interior node at leaf level"
            children = [(int(h.left_child[phys]), lo, keys[0] if n else hi)]
            for i in range(n):
                children.append((int(h.svals[phys, i, 0]), keys[i],
                                 keys[i + 1] if i + 1 < n else hi))
            for child, clo, chi in children:
                self._check_node(child, clo, chi, levels_left - 1, leaves)
        else:
            assert levels_left == 1, "leaf above leaf level"
            assert not self.heap.is_locked(phys), "leaf left locked"
            leaves.append(lid)

    def __len__(self):
        """Live item count (full scan) — test helper."""
        return len(self.scan(b"", b"\xff" * self.cfg.max_key_bytes,
                             read_version=self.versions.global_write_version))
