"""Typed request/response service API — ops as first-class wire messages.

Honeycomb's NIC interface is message-shaped (paper Section 3): requests
arrive as parsed wire messages carrying sequence numbers, execute out of
order on the accelerator, and responses are reassembled in arrival order
and stamped so clients can observe linearizability.  This module makes that
contract explicit in the software stack:

  * **Ops** — frozen dataclasses ``Get`` / ``Scan`` / ``Put`` / ``Update``
    / ``Delete``: the five wire messages of the store protocol.  Each op
    knows its own wire encoding (``encode_wire``/``decode_wire``): the
    append-only log-entry format ``SyncStats.log_wire_bytes`` has metered
    since PR 2 — op byte + u16 key length + u16 value length + payload —
    now produced by ONE shared encoder (``wire_entry_nbytes`` is the exact
    size shared with the store's write meter), the substrate the
    log-structured delta wire encoding and the replica log-replay feed
    build on (ROADMAP open items).
  * **Response** — every completed op resolves to
    ``Response(status, value|items, serving_version, shard, replica)``.
    Read responses are stamped with the read version of the snapshot that
    answered (and which replica lane served), so tests and clients can
    assert monotone, linearizable reads end-to-end; write responses carry
    the host-tree version at which the write became visible.
  * **Ticket** — the future ``HoneycombService.submit`` returns:
    ``.result()`` drains the service's pipeline epoch if the response is
    not in yet and returns the ``Response``.
  * **Routing** — the store-provided wiring the scheduler consumes
    (``HoneycombStore.routing()`` / ``ShardedHoneycombStore.routing()`` /
    ``ReplicaGroup.routing()``): key->shard ownership, the replica
    read-spreading pick, the per-dispatch serving report and the live host
    version.  Callers no longer thread ``shard_of``/``replica_of``
    callbacks by hand — the store IS the routing authority.
  * **HoneycombService** — the one serving front end: wraps ANY facade
    (plain / sharded / replicated), self-wires routing from the store, and
    drives the out-of-order scheduler's admit/export/dispatch epochs —
    ``submit(op) -> Ticket``, ``submit_many(ops)``, ``drain()`` runs one
    pipeline epoch and resolves every pending ticket.

The legacy interfaces remain as thin shims over this op path — stringly
``OutOfOrderScheduler.submit(kind, ...)`` builds the op and delegates
(tested op-for-op identical, including sync byte counts) — so there is ONE
execution path from either API.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Callable, Iterable

# ---------------------------------------------------------------- wire codec
# Append-only log-entry wire format (paper's log-block encoding, PR 2's
# SyncStats.log_wire_bytes accounting made exact): one fixed header of
# op byte + u16 key length + u16 value length, then the key and value
# bytes.  SCAN carries its upper bound in the value slot and appends a u16
# expected-items hint (reads are never metered as log traffic, so the
# extra field does not disturb the write-byte accounting).
WIRE_ENTRY_OVERHEAD = 5
_WIRE_HEADER = struct.Struct(">BHH")
_WIRE_U16 = struct.Struct(">H")


class WireDecodeError(ValueError):
    """A wire buffer failed to decode: truncated header or payload, or an
    unknown op code.  Decoding is all-or-nothing — a stream that raises
    has applied NOTHING, so a replication feed can fall back to a full
    resync instead of replaying a silently partial epoch."""


def wire_entry_nbytes(key: bytes, value: bytes = b"") -> int:
    """Exact wire size of one log entry — THE shared accounting between the
    op encoder below and the store's ``SyncStats.log_wire_bytes`` meter
    (core/shard.py), so the meter and the encoder can never drift."""
    return WIRE_ENTRY_OVERHEAD + len(key) + len(value)


def _encode(code: int, a: bytes, b: bytes = b"", tail: bytes = b"") -> bytes:
    assert len(a) <= 0xFFFF and len(b) <= 0xFFFF, (
        f"wire entry field over the u16 length limit "
        f"({len(a)}/{len(b)} bytes)")
    return _WIRE_HEADER.pack(code, len(a), len(b)) + a + b + tail


# ----------------------------------------------------------------------- ops
@dataclasses.dataclass(frozen=True)
class Get:
    """Point lookup: resolves to the value at ``key`` (or not_found)."""
    key: bytes

    KIND = "get"
    IS_WRITE = False
    OP_CODE = 1

    @property
    def route_key(self) -> bytes:
        return self.key

    @property
    def expected_items(self) -> int:
        return 1

    def encode_wire(self) -> bytes:
        return _encode(self.OP_CODE, self.key)


@dataclasses.dataclass(frozen=True)
class Scan:
    """Ordered range read over ``[lo, hi]`` (floor-start semantics, paper
    Section 3.3); ``expected_items`` is the cost hint the scheduler buckets
    by."""
    lo: bytes
    hi: bytes
    expected_items: int = 1

    KIND = "scan"
    IS_WRITE = False
    OP_CODE = 2

    @property
    def route_key(self) -> bytes:
        return self.lo   # the owning shard of the range start; the store
        # facade decomposes any cross-shard tail

    def encode_wire(self) -> bytes:
        assert 0 <= self.expected_items <= 0xFFFF, (
            f"expected_items {self.expected_items} over the u16 limit")
        return _encode(self.OP_CODE, self.lo, self.hi,
                       _WIRE_U16.pack(self.expected_items))


@dataclasses.dataclass(frozen=True)
class Put:
    """Blind insert/overwrite of ``key`` with ``value``."""
    key: bytes
    value: bytes

    KIND = "put"
    IS_WRITE = True
    OP_CODE = 3

    @property
    def route_key(self) -> bytes:
        return self.key

    @property
    def expected_items(self) -> int:
        return 1

    def encode_wire(self) -> bytes:
        return _encode(self.OP_CODE, self.key, self.value)

    def apply(self, store) -> None:
        store.put(self.key, self.value)


@dataclasses.dataclass(frozen=True)
class Update:
    """In-place update of an existing ``key``."""
    key: bytes
    value: bytes

    KIND = "update"
    IS_WRITE = True
    OP_CODE = 4

    @property
    def route_key(self) -> bytes:
        return self.key

    @property
    def expected_items(self) -> int:
        return 1

    def encode_wire(self) -> bytes:
        return _encode(self.OP_CODE, self.key, self.value)

    def apply(self, store) -> None:
        store.update(self.key, self.value)


@dataclasses.dataclass(frozen=True)
class Delete:
    """Tombstone ``key``."""
    key: bytes

    KIND = "delete"
    IS_WRITE = True
    OP_CODE = 5

    @property
    def route_key(self) -> bytes:
        return self.key

    @property
    def expected_items(self) -> int:
        return 1

    def encode_wire(self) -> bytes:
        return _encode(self.OP_CODE, self.key)

    def apply(self, store) -> None:
        store.delete(self.key)


Op = Get | Scan | Put | Update | Delete
OPS_BY_CODE: dict[int, type] = {c.OP_CODE: c
                                for c in (Get, Scan, Put, Update, Delete)}
OPS_BY_KIND: dict[str, type] = {c.KIND: c
                                for c in (Get, Scan, Put, Update, Delete)}
WRITE_KINDS = tuple(k for k, c in OPS_BY_KIND.items() if c.IS_WRITE)


def decode_wire(data: bytes, offset: int = 0) -> tuple[Op, int]:
    """Decode one op from ``data`` at ``offset``; returns (op, next_offset)
    so a log-structured stream of entries decodes by chaining offsets.
    Raises :class:`WireDecodeError` on a truncated or garbage buffer."""
    if offset + WIRE_ENTRY_OVERHEAD > len(data):
        raise WireDecodeError(
            f"truncated wire header at offset {offset}: need "
            f"{WIRE_ENTRY_OVERHEAD} bytes, {len(data) - offset} remain")
    code, alen, blen = _WIRE_HEADER.unpack_from(data, offset)
    cls = OPS_BY_CODE.get(code)
    if cls is None:
        raise WireDecodeError(
            f"unknown wire op code {code} at offset {offset}")
    p = offset + WIRE_ENTRY_OVERHEAD
    if p + alen + blen > len(data):
        raise WireDecodeError(
            f"truncated wire entry at offset {offset}: header promises "
            f"{alen}+{blen} payload bytes, {len(data) - p} remain")
    a, b = bytes(data[p: p + alen]), bytes(data[p + alen: p + alen + blen])
    p += alen + blen
    if cls is Get:
        return Get(a), p
    if cls is Scan:
        if p + _WIRE_U16.size > len(data):
            raise WireDecodeError(
                f"truncated SCAN entry at offset {offset}: the u16 "
                f"expected-items tail is missing")
        (expected,) = _WIRE_U16.unpack_from(data, p)
        return Scan(a, b, expected), p + _WIRE_U16.size
    if cls is Delete:
        return Delete(a), p
    return cls(a, b), p


def decode_wire_stream(data: bytes) -> list[Op]:
    """Decode a whole append-only entry stream (the replica log-replay feed
    shape: deltas as a byte stream of ops instead of node rows)."""
    ops, offset = [], 0
    while offset < len(data):
        op, offset = decode_wire(data, offset)
        ops.append(op)
    return ops


# ----------------------------------------------------------------- responses
OK = "ok"
NOT_FOUND = "not_found"


@dataclasses.dataclass(frozen=True)
class Response:
    """One completed op, reassembled in arrival order and stamped for
    linearizability checks.

    ``serving_version`` is the read version of the snapshot a read answered
    from (the host-tree version at which a write became visible, for
    writes); ``shard`` is the owning range-shard and ``replica`` the lane
    that actually served (0 = primary — also when a lagging follower pin
    was redirected by the freshness rule)."""
    status: str
    value: bytes | None = None        # GET result
    items: list | None = None         # SCAN result (key, value) pairs
    serving_version: int = 0
    shard: int = 0
    replica: int = 0

    @property
    def ok(self) -> bool:
        return self.status == OK

    def unwrap(self):
        """The legacy bare result: SCAN items, GET value (None when
        not_found), None for writes — what pre-service callers got from
        ``scheduler.run()``."""
        return self.items if self.items is not None else self.value


class Ticket:
    """Future for one submitted op: resolved by the service's next
    ``drain()`` (``result()`` drains on demand)."""
    __slots__ = ("rid", "op", "_service", "_response")

    def __init__(self, rid: int, op: Op, service: "HoneycombService"):
        self.rid = rid
        self.op = op
        self._service = service
        self._response: Response | None = None

    @property
    def done(self) -> bool:
        return self._response is not None

    def result(self) -> Response:
        if self._response is None:
            self._service.drain()       # one pipeline epoch resolves us
        assert self._response is not None, "drain() did not resolve ticket"
        return self._response

    def _resolve(self, response: Response) -> None:
        self._response = response

    def __repr__(self) -> str:
        state = self._response if self.done else "pending"
        return f"Ticket(rid={self.rid}, op={self.op!r}, {state})"


# ------------------------------------------------------------------- routing
@dataclasses.dataclass(frozen=True)
class Routing:
    """Store-provided request wiring — what ``store.routing()`` returns and
    the scheduler consumes, replacing caller-threaded ``shard_of`` /
    ``replica_of`` callbacks.

    ``shard_of`` maps a route key to its owning shard; ``replica_of`` is
    the read-spreading pick (None when the store takes no replica pin — the
    unreplicated facade); ``report`` returns, for a shard that just served
    a device batch, ``(replica_served, serving_version)`` — the stamp for
    read responses; ``live_version`` returns the shard's current host-tree
    read version — the stamp for write responses."""
    shard_of: Callable[[bytes], int]
    replica_of: Callable[[int], int] | None
    report: Callable[[int], tuple[int, int]]
    live_version: Callable[[int], int]


# ------------------------------------------------------------------- service
class HoneycombService:
    """The typed serving front end: wraps ANY store facade (plain
    ``HoneycombStore``, ``ShardedHoneycombStore``, bare ``ReplicaGroup``),
    self-wires routing from ``store.routing()``, and drives the
    out-of-order scheduler's admit/export/dispatch pipeline.

    ``submit(op)`` returns a ``Ticket``; ``drain()`` runs ONE pipeline
    epoch (writes admitted in order, one delta sync per dirty shard, dense
    replica-pinned read batches) and resolves every pending ticket with a
    stamped ``Response``."""

    def __init__(self, store, cfg: "ServiceConfig | None" = None, **over):
        from .config import ServiceConfig
        from .scheduler import OutOfOrderScheduler
        from .telemetry import Telemetry
        self.cfg = dataclasses.replace(cfg or ServiceConfig(), **over)
        self.store = store
        self.routing: Routing = store.routing()
        # observability (core/telemetry.py): one registry per service,
        # every stats surface the store facade exposes registered as a
        # live collect() source, the scheduler wired for latency
        # histograms + sampled lifecycle traces.  Disabled => None and
        # nothing is constructed (the zero-overhead contract).
        tcfg = self.cfg.telemetry
        self.telemetry = (Telemetry(tcfg).wire_store(store)
                          if tcfg.enabled else None)
        self.scheduler = OutOfOrderScheduler(
            batch_size=self.cfg.batch_size,
            cost_classes=self.cfg.cost_classes,
            routing=self.routing, pipeline=self.cfg.pipeline,
            telemetry=self.telemetry)
        self._pending: dict[int, Ticket] = {}

    # ---------------------------------------------------------- submission
    def submit(self, op: Op) -> Ticket:
        rid = self.scheduler.submit_op(op)
        ticket = Ticket(rid, op, self)
        self._pending[rid] = ticket
        return ticket

    def submit_many(self, ops: Iterable[Op]) -> list[Ticket]:
        return [self.submit(op) for op in ops]

    def drain(self, flush: bool = True) -> dict[int, Response]:
        """Run one pipeline epoch over everything submitted so far and
        resolve the pending tickets; returns {rid: Response}."""
        out = self.scheduler.run_ops(self.store, flush=flush)
        for rid, response in out.items():
            ticket = self._pending.pop(rid, None)
            if ticket is not None:
                ticket._resolve(response)
        return out

    # ------------------------------------------------------------- meters
    @property
    def stats(self):
        """The scheduler's per-stage pipeline meters."""
        return self.scheduler.stats

    # -------------------------------------------------------- telemetry
    #   (all None-safe: a disabled service answers with empty exports)
    def metrics_snapshot(self) -> dict:
        """Flat JSON-able registry snapshot (core/telemetry.py)."""
        return self.telemetry.snapshot() if self.telemetry else {}

    def prometheus(self) -> str:
        """Prometheus text exposition of the registry."""
        return self.telemetry.to_prometheus() if self.telemetry else ""

    def traces(self):
        """Finished sampled lifecycle traces (oldest first)."""
        return self.telemetry.traces() if self.telemetry else []

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON of the sampled traces (Perfetto)."""
        return (self.telemetry.chrome_trace() if self.telemetry
                else {"traceEvents": []})

    @property
    def syncs(self) -> int:
        return self.scheduler.syncs

    @property
    def pending(self) -> int:
        return len(self._pending)
