"""Packed node-image layout: the ONE schema for per-node snapshot fields.

The paper transfers whole B-Tree nodes as single contiguous 8 KB buffers
over PCIe (Section 3.1); the reproduction's heap is structure-of-arrays on
the host (fast columnar writes, 64-bit MVCC authority), but what crosses
the host->accelerator "bus" — and what the device keeps resident — is ONE
packed ``(node_cap, image_words)`` u32 image: every per-node field maps to
a static ``(word_offset, width)`` column slice of its node's image row.
A dirty node then syncs as a single contiguous row DMA instead of one row
scatter per field, and every consumer that used to re-enumerate the field
list (heap allocation, snapshot publish, device narrowing, scatter
callers, the dry-run's abstract shapes) derives it from ``NODE_SCHEMA``
here — adding a field is a one-line change.

Layout contract (pinned by tests/test_layout.py golden offsets):
  * fields are laid out in ``NODE_SCHEMA`` order, no padding, 4-byte words;
  * every device field is exactly one u32 word per element.  Wider host
    types (the 64-bit version counters, the byte-wide log op/hint codes)
    narrow to int32 on the way in — the same narrowing the per-field
    legacy snapshot always performed (the host keeps 64-bit authority);
  * signed fields cross as their int32 bit pattern and are decoded with a
    bitcast (NULL = -1 survives), unsigned key/value lanes pass through.

With the paper's geometry (64-cap nodes, 16 log entries, 8 shortcuts,
32 B keys / 16 B inline values) the image row is 1273 words = 5092 B —
the reproduction's analogue of the paper's 8 KB node buffer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np

from .config import HoneycombConfig
from .keys import pack_key

_NULL = -1   # matches heap.NULL: "no slot / no sibling / no old version"


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """One per-node field: its host storage and its device representation.

    ``dims`` name the per-node trailing shape via ``HoneycombConfig``
    attributes (the leading node-capacity dim is implicit).  ``host``
    is the heap's numpy dtype; ``device`` (uint32/int32 only — one image
    word per element) is what crosses the bus and lives in the image.
    """
    name: str
    dims: tuple[str, ...] = ()
    host: str = "int32"
    device: str = "int32"
    fill: int = 0

    def shape(self, cfg: HoneycombConfig) -> tuple[int, ...]:
        return tuple(getattr(cfg, d) for d in self.dims)

    @property
    def narrowed(self) -> bool:
        """True when the device image narrows the host dtype (the host
        keeps the wide authority; the snapshot carries int32)."""
        return self.host != self.device


# THE per-node field list, in image/layout order.  NodeHeap allocation,
# TreeSnapshot publishing, the device-narrowing table, the delta scatter
# and the dry-run's abstract shapes all derive from this tuple.
NODE_SCHEMA: tuple[FieldSpec, ...] = (
    FieldSpec("ntype"),
    FieldSpec("nitems"),
    FieldSpec("version", host="int64"),
    FieldSpec("oldptr", fill=_NULL),      # previous-version phys slot
    FieldSpec("left_child", fill=_NULL),  # interior: leftmost child LID
    FieldSpec("lsib", fill=_NULL),        # leaf: sibling LIDs
    FieldSpec("rsib", fill=_NULL),
    FieldSpec("skeys", ("node_cap", "key_words"), "uint32", "uint32"),
    FieldSpec("skeylen", ("node_cap",)),
    FieldSpec("svals", ("node_cap", "val_words"), "uint32", "uint32"),
    FieldSpec("svallen", ("node_cap",)),
    FieldSpec("n_shortcuts"),
    FieldSpec("sc_keys", ("n_shortcuts", "key_words"), "uint32", "uint32"),
    FieldSpec("sc_keylen", ("n_shortcuts",)),
    FieldSpec("sc_pos", ("n_shortcuts",)),
    FieldSpec("nlog"),
    FieldSpec("log_keys", ("log_cap", "key_words"), "uint32", "uint32"),
    FieldSpec("log_keylen", ("log_cap",)),
    FieldSpec("log_vals", ("log_cap", "val_words"), "uint32", "uint32"),
    FieldSpec("log_vallen", ("log_cap",)),
    FieldSpec("log_op", ("log_cap",), host="int8"),
    FieldSpec("log_backptr", ("log_cap",)),
    FieldSpec("log_hint", ("log_cap",), host="uint8"),
    FieldSpec("log_vdelta", ("log_cap",), host="int64"),
)

FIELD_NAMES: tuple[str, ...] = tuple(f.name for f in NODE_SCHEMA)

# fields the device image narrows to int32 (host keeps 64-bit authority) —
# derived, not re-enumerated (was shard.py's hand-kept _I32_FIELDS)
NARROWED_FIELDS: frozenset[str] = frozenset(
    f.name for f in NODE_SCHEMA if f.narrowed)


@dataclasses.dataclass(frozen=True)
class FieldSlot:
    """Resolved placement of one field inside the image row."""
    spec: FieldSpec
    offset: int                 # first u32 word of the field's column slice
    words: int                  # u32 words per node
    shape: tuple[int, ...]      # per-node trailing shape


class NodeImageLayout:
    """Field -> (word_offset, width) map of the packed node image for one
    config, plus host pack / device view / host unpack helpers.

    Design: the image is purely a *transfer and residency* format.  The
    host heap stays structure-of-arrays (columnar writes, wide dtypes);
    ``pack()`` is the DMA marshalling step — it gathers the dirty rows of
    every field into contiguous image rows, so one dirty node is one
    contiguous ``image_words * 4``-byte buffer on the bus.  On device,
    ``view()`` reinterprets a static column slice of the image, so the
    read path and the kernels address fields by layout offset with no
    per-field arrays materialized.
    """

    def __init__(self, cfg: HoneycombConfig):
        self.cfg = cfg
        slots: dict[str, FieldSlot] = {}
        off = 0
        for spec in NODE_SCHEMA:
            shape = spec.shape(cfg)
            words = int(np.prod(shape, dtype=np.int64)) if shape else 1
            slots[spec.name] = FieldSlot(spec, off, words, shape)
            off += words
        self.slots = slots
        self.image_words = off          # u32 words per node image row

    @staticmethod
    @functools.lru_cache(maxsize=None)
    def for_config(cfg: HoneycombConfig) -> "NodeImageLayout":
        return NodeImageLayout(cfg)

    @property
    def node_image_bytes(self) -> int:
        """Bytes of one node's contiguous image row (the DMA unit — the
        reproduction's analogue of the paper's 8 KB node buffer)."""
        return self.image_words * 4

    def offsets(self) -> dict[str, tuple[int, int]]:
        """{field: (word_offset, words)} — what the golden test pins."""
        return {n: (s.offset, s.words) for n, s in self.slots.items()}

    # ---------------------------------------------------------- host side
    def pack(self, heap, rows: np.ndarray | None = None) -> np.ndarray:
        """Marshal heap rows into contiguous node images: [D, image_words]
        u32 (D = all rows when ``rows`` is None).  Narrows wide host dtypes
        to int32 and bit-preserves signedness, exactly like the per-field
        legacy publish; the result is a fresh buffer, so later host
        mutations can never reach a staged snapshot."""
        n = heap.capacity if rows is None else len(rows)
        img = np.empty((n, self.image_words), np.uint32)
        for name, slot in self.slots.items():
            arr = getattr(heap, name)
            arr = arr if rows is None else arr[rows]
            dev = np.ascontiguousarray(arr.astype(slot.spec.device,
                                                  copy=False))
            img[:, slot.offset:slot.offset + slot.words] = \
                dev.view(np.uint32).reshape(n, slot.words)
        return img

    def unpack(self, img: np.ndarray) -> dict[str, np.ndarray]:
        """Host-side inverse of ``pack`` (tests / debugging): image rows
        back to per-field arrays in their DEVICE dtypes."""
        out = {}
        for name, slot in self.slots.items():
            col = np.ascontiguousarray(
                img[:, slot.offset:slot.offset + slot.words])
            out[name] = col.view(np.dtype(slot.spec.device)) \
                .reshape((len(img), *slot.shape))
        return out

    # -------------------------------------------------------- device side
    def view(self, image, name: str):
        """Decode one field from a device image: a static column slice
        reinterpreted to the field's device dtype.  Signed fields bitcast
        (NULL = -1 survives the u32 transit); unsigned lanes pass through."""
        import jax
        import jax.numpy as jnp
        slot = self.slots[name]
        col = image[:, slot.offset:slot.offset + slot.words]
        if slot.spec.device != "uint32":
            col = jax.lax.bitcast_convert_type(col, jnp.int32)
        return col.reshape((image.shape[0], *slot.shape))

    def field_views(self, image) -> dict[str, "object"]:
        """All field views of a device image (snapshot adapter)."""
        return {name: self.view(image, name) for name in self.slots}

    # -------------------------------------------- log-replay addressing
    # One decoded wire op + its placement sidecar marshal into a dense
    # LOG_ENTRY_WORDS-u32 record; the log_replay_scatter kernel
    # (kernels/delta_scatter.py) scatters each record into its node's
    # image row at these static offsets + slot * field width — the
    # entry->row address map of the log-shipped replication feed.

    @property
    def log_entry_words(self) -> int:
        """u32 words per marshalled log entry: key lanes + keylen + value
        lanes + vallen + op + backptr + hint + vdelta."""
        return self.cfg.key_words + self.cfg.val_words + 6

    def log_replay_offsets(self) -> "LogReplayOffsets":
        """Static image-row word offsets the replay kernel scatters to —
        hashable, so it rides as a jit static argument."""
        s = self.slots
        return LogReplayOffsets(
            key_words=self.cfg.key_words,
            val_words=self.cfg.val_words,
            nlog=s["nlog"].offset,
            log_keys=s["log_keys"].offset,
            log_keylen=s["log_keylen"].offset,
            log_vals=s["log_vals"].offset,
            log_vallen=s["log_vallen"].offset,
            log_op=s["log_op"].offset,
            log_backptr=s["log_backptr"].offset,
            log_hint=s["log_hint"].offset,
            log_vdelta=s["log_vdelta"].offset)

    def pack_log_entries(self, ops, op_codes, backptrs, hints,
                         vdeltas) -> np.ndarray:
        """Marshal decoded wire ops + placement sidecar into the dense
        ``[E, log_entry_words]`` u32 block the replay kernel consumes.

        Key and inline-value lanes are packed exactly like the host write
        path (big-endian u32 lanes, zero padded; ``core/keys.pack_key`` /
        ``HoneycombTree._store_value``), and the narrow int sidecar fields
        cross as their int32 bit pattern — the same narrowing ``pack()``
        applies — so a replayed row is bit-identical to the primary's
        packed row.  Values longer than the inline budget never reach
        here: such epochs are not replayable (core/shard.py falls back to
        the image delta)."""
        cfg = self.cfg
        kw, vw = cfg.key_words, cfg.val_words
        blk = np.zeros((len(ops), self.log_entry_words), np.uint32)
        for i, op in enumerate(ops):
            key = op.key
            val = getattr(op, "value", b"")
            assert len(val) <= cfg.max_inline_val_bytes, (
                "overflow-length value in a log-replay payload")
            blk[i, 0:kw] = pack_key(key, kw)
            blk[i, kw] = len(key)
            if val:
                buf = val + b"\x00" * (-len(val) % 4)
                lanes = np.frombuffer(buf, dtype=">u4")
                blk[i, kw + 1:kw + 1 + len(lanes)] = lanes
            blk[i, kw + 1 + vw] = len(val)
        blk[:, kw + vw + 2] = np.asarray(op_codes, np.int64) \
            .astype(np.int32).view(np.uint32)
        blk[:, kw + vw + 3] = np.asarray(backptrs, np.int64) \
            .astype(np.int32).view(np.uint32)
        blk[:, kw + vw + 4] = np.asarray(hints, np.int64) \
            .astype(np.int32).view(np.uint32)
        blk[:, kw + vw + 5] = np.asarray(vdeltas, np.int64) \
            .astype(np.int32).view(np.uint32)
        return blk


class LogReplayOffsets(NamedTuple):
    """Static layout constants of one log-replay scatter (all ints, so the
    tuple is hashable and jit-static).  ``log_*``/``nlog`` are image-row
    word offsets; per-slot fields advance by their width per log slot."""
    key_words: int
    val_words: int
    nlog: int
    log_keys: int
    log_keylen: int
    log_vals: int
    log_vallen: int
    log_op: int
    log_backptr: int
    log_hint: int
    log_vdelta: int
