"""Epoch-pipelined execution engine — design notes and stage meters.

Honeycomb's throughput comes from keeping every stage of the serving path
busy at once: the FPGA answers reads from a resident snapshot while the
host batches writes and streams the next delta over PCIe (request
parallelism + batched synchronization, paper Sections 3-4).  The original
``OutOfOrderScheduler.run()`` modeled that cycle *serially* — apply all
writes, one blocking sync, then dispatch reads — so the device sat idle
for the whole sync and the host sat idle for the whole read phase.  This
module defines the pipelined replacement.

Design
======

**Double-buffered resident snapshots (core/shard.py).**  Each
``StoreShard`` keeps an *active* snapshot (the epoch in-flight read
batches execute against, pinned at its read version) and stages the next
epoch into a *standby* buffer:

  * ``begin_export()`` — the staging half of the old ``export_snapshot()``:
    meter the sync, gather the dirty rows + page-table commands on the
    host, and enqueue the delta scatter into the standby buffer.  The
    scatter is dispatched asynchronously; nothing blocks, and the active
    snapshot keeps answering untouched.
  * ``flip()`` — the publish half: an atomic epoch advance that makes the
    standby the new active.  The old active's arrays are functional device
    copies, so batches already in flight finish at their pinned read
    version; under ``sync_policy="explicit"`` the accelerator-epoch pin
    (acquired at staging time, when the standby's read version was
    captured) rolls forward here so GC keeps old-version chains walkable
    for host fallbacks — two flips plus a ``collect_garbage()`` later, an
    old-epoch snapshot still answers at its read version (tested).
  * ``export_snapshot()`` ≡ ``begin_export(); flip()`` — the serial
    composition, byte-for-byte identical to the pre-pipeline behavior.

**Explicit scheduler stages (core/scheduler.py).**  ``run()`` is now a
composition of three public stages — ``stage_admit`` (apply host writes in
submission order, per-shard policy syncs deferred), ``stage_export``
(stage per-shard deltas into standby buffers and flip each dirty shard
independently), ``stage_dispatch`` (consume ``ready_batches()``) — so
callers can interleave stages of consecutive epochs (admit epoch N+1
while epoch N's scatters drain on the device queue).

**Two pipeline modes.**

  * ``pipeline="serial"`` reproduces the pre-refactor sequence op-for-op
    (same results, same ``SyncStats`` byte counts — tested): one facade
    ``export_snapshot()`` covering every dirty shard, then reads.  It also
    models the blocking PCIe barrier the serial design implies —
    ``jax.block_until_ready`` on the freshly synced snapshots before any
    read dispatches — and meters that wait as ``sync_stall_s``.
  * ``pipeline="pipelined"`` stages every dirty shard's standby
    (asynchronous scatter enqueue), flips each shard independently, and
    dispatches read batches immediately: shard A's reads execute while
    shard B's scatter is still in the device queue, and the only stall is
    the host-side staging time.  Results and sync byte counts are
    identical to serial mode by construction (reads always execute
    against the flipped epoch); only the overlap differs.

Sanitizer seams
===============

The stage boundaries above are exactly where the epoch protocol can be
violated, so they double as EpochSan interposition points
(repro/analysis/epochsan.py, enabled via ``HONEYCOMB_EPOCHSAN=1``):
``begin_export`` tags the staged standby and audits the interior-cache
frontier against PageTable remaps, ``flip`` retags the published
snapshot, ``_device_get``/``_device_scan`` reject dispatches against an
unflipped standby, the scheduler's ``stage_export`` asserts every staged
standby was published before reads dispatch, ``collect_garbage`` audits
reclamation against the pinned epoch window, and the replica group's
dispatch re-derives the follower freshness rule.  Off, each seam costs
one module call returning None.

Meters
======

``PipelineStats`` carries per-stage wall time and occupancy:
``sync_stall_s`` (host time blocked on sync completion before the first
read dispatch — the quantity pipelining exists to remove),
``admit_s``/``export_s``/``dispatch_s`` stage timings, flip/stage counts,
and device-lane occupancy (real requests vs ``bucket_pow2``-padded lanes).
Shards meter their staging/flip side, the router aggregates them, and the
scheduler meters the stage loop; benchmarks report both
(``benchmarks/ycsb.py --pipeline``, ``benchmarks/latency.py``).
"""
from __future__ import annotations

import dataclasses

PIPELINE_MODES = ("serial", "pipelined")


@dataclasses.dataclass
class PipelineStats:
    """Per-stage timing/occupancy meters for the epoch pipeline."""
    runs: int = 0               # scheduler run() epochs completed
    admit_s: float = 0.0        # host write-apply stage wall time
    export_s: float = 0.0       # standby staging wall time (host side)
    dispatch_s: float = 0.0     # read-batch dispatch stage wall time
    sync_stall_s: float = 0.0   # time blocked on sync completion before
    #   any read of the epoch could dispatch (serial barrier; ~0 pipelined)
    staged_exports: int = 0     # begin_export calls that staged a standby
    flips: int = 0              # epoch publishes
    dispatched_lanes: int = 0   # real requests inside device batches
    padded_lanes: int = 0       # bucket_pow2 device lanes those occupied

    def merge(self, other: "PipelineStats"):
        """Accumulate another meter (router aggregation over shards)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))

    @property
    def lane_occupancy(self) -> float:
        """Real requests / padded device lanes (1.0 = no padding waste)."""
        return (self.dispatched_lanes / self.padded_lanes
                if self.padded_lanes else 0.0)

    @property
    def stall_fraction(self) -> float:
        """sync_stall_s over total staged wall time — the serial barrier's
        share of the epoch; pipelining drives it toward zero."""
        busy = self.admit_s + self.export_s + self.dispatch_s
        return self.sync_stall_s / busy if busy > 0 else 0.0

    def collect(self):
        """Registry samples (core/telemetry.py collect protocol):
        ``pipeline_*`` counters plus the two derived-ratio gauges.  The
        registering layer labels which surface this is (``src="store"``
        for the shard-side staging meters, ``src="scheduler"`` for the
        epoch-stage meters)."""
        from .telemetry import samples_from
        return samples_from(self, "pipeline", "pipeline",
                            derived=("lane_occupancy", "stall_fraction"))
