"""Interior-node cache + load balancer (paper Section 5).

On the FPGA the cache moves interior-node reads from PCIe (slow) to on-board
DRAM (fast), the root lives in on-chip SRAM, and a load balancer sends some
cache *hits* back to PCIe when DRAM is saturated so that the two off-chip
pipes are both busy.

TPU translation: all tree arrays live in HBM, so the tiers become
  SRAM root cache        ->  root (+ top levels) packed into a small
                             contiguous array that a Pallas kernel pins in
                             VMEM via its BlockSpec (no HBM gather for the
                             first levels of every request)
  on-board DRAM cache    ->  the packed cache array itself: contiguous,
                             sequential reads (vs. the random gathers the
                             heap path costs)
  PCIe path              ->  random gathers against the full heap arrays
  load balancer          ->  routes a fraction of cache-hit level lookups to
                             the heap path to keep both gather pipelines busy

The cache is software-managed on the host: a 4-way set-associative metadata
table keyed by LID, refreshed at snapshot export, invalidated when the page
table remaps a LID (Section 5: "the cache entry for the node with that LID
is invalidated").  Benchmarks meter hit rates and the two paths' byte flows
to reproduce Fig. 16.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .config import HoneycombConfig
from .heap import INTERIOR, NULL


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    invalidations: int = 0
    fast_path_reads: int = 0     # served from the packed cache ("DRAM")
    slow_path_reads: int = 0     # routed to the heap ("PCIe")
    fast_bytes: int = 0
    slow_bytes: int = 0

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0


class InteriorCache:
    """4-way set-associative cache of interior nodes, indexed by LID."""

    def __init__(self, cfg: HoneycombConfig):
        self.cfg = cfg
        self.sets = max(1, cfg.cache_slots // cfg.cache_ways)
        self.tag = np.full((self.sets, cfg.cache_ways), NULL, np.int64)
        self.phys = np.full((self.sets, cfg.cache_ways), NULL, np.int64)
        self.tick = np.zeros((self.sets, cfg.cache_ways), np.int64)
        self._clock = 0
        self._rng = np.random.default_rng(0)
        self.stats = CacheStats()
        # packed top-level image: lids present, order = packed slot index
        self.packed_lids: np.ndarray = np.zeros((0,), np.int64)

    def _set_of(self, lid: int) -> int:
        return lid % self.sets

    def lookup(self, lid: int, phys: int) -> bool:
        """Metadata-table probe (Section 5).  A hit requires the cached
        physical address to match the live page table (the NAT check);
        mismatches count as misses and invalidate the way."""
        s = self._set_of(lid)
        self._clock += 1
        for w in range(self.cfg.cache_ways):
            if self.tag[s, w] == lid:
                if self.phys[s, w] != phys:
                    self.tag[s, w] = NULL
                    self.stats.invalidations += 1
                    break
                self.tick[s, w] = self._clock
                self.stats.hits += 1
                return True
        self.stats.misses += 1
        self._fill(lid, phys)
        return False

    def _fill(self, lid: int, phys: int):
        """Write-back on miss; random eviction within the set (the paper
        leaves smarter policies to future work)."""
        s = self._set_of(lid)
        for w in range(self.cfg.cache_ways):
            if self.tag[s, w] == NULL:
                self.tag[s, w], self.phys[s, w] = lid, phys
                self.tick[s, w] = self._clock
                return
        w = int(self._rng.integers(self.cfg.cache_ways))
        self.tag[s, w], self.phys[s, w] = lid, phys
        self.tick[s, w] = self._clock

    def invalidate(self, lid: int):
        s = self._set_of(lid)
        for w in range(self.cfg.cache_ways):
            if self.tag[s, w] == lid:
                self.tag[s, w] = NULL
                self.stats.invalidations += 1

    # ------------------------------------------------------- top-level pack
    def refresh(self, tree):
        """Rebuild the packed top-level image (root in 'SRAM', next level in
        'DRAM') at snapshot export; the Pallas read kernel receives it as a
        VMEM-resident block."""
        lids = [tree.root_lid]
        phys = tree.pt.lookup(tree.root_lid)
        if int(tree.heap.ntype[phys]) == INTERIOR:
            lids.append(int(tree.heap.left_child[phys]))
            for i in range(int(tree.heap.nitems[phys])):
                lids.append(int(tree.heap.svals[phys, i, 0]))
        self.packed_lids = np.asarray(lids[: self.cfg.cache_slots], np.int64)
        for lid in self.packed_lids:
            self.lookup(int(lid), tree.pt.lookup(int(lid)))

    # ----------------------------------------------------- load balancer
    def route(self, lid: int, phys: int, nbytes: int,
              fast_inflight: int = 0, slow_inflight: int = 0) -> str:
        """Load-balanced read routing (Section 5).  Returns 'fast' (cache)
        or 'slow' (heap/PCIe).  Balances by inflight bytes when telemetry is
        supplied, else by the configured fraction."""
        hit = self.lookup(lid, phys)
        if not hit:
            path = "slow"
        elif not self.cfg.load_balance:
            path = "fast"
        elif fast_inflight or slow_inflight:
            path = "fast" if fast_inflight <= slow_inflight else "slow"
        else:
            path = "fast" if self._rng.random() < self.cfg.lb_fast_fraction \
                else "slow"
        if path == "fast":
            self.stats.fast_path_reads += 1
            self.stats.fast_bytes += nbytes
        else:
            self.stats.slow_path_reads += 1
            self.stats.slow_bytes += nbytes
        return path
