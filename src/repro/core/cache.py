"""Interior-node cache + load balancer (paper Section 5), as built.

On the FPGA the cache moves interior-node reads from PCIe (slow) to on-board
DRAM (fast), the root lives in on-chip SRAM, and a load balancer sends some
cache *hits* back to PCIe when DRAM is saturated so that the two off-chip
pipes are both busy.

Here that tiering runs on device, end to end.  At every snapshot export
``refresh`` walks the root + top ``cfg.cache_levels`` interior levels
breadth-first and ``device_lids`` emits them as a NULL-padded LID vector
that rides on ``TreeSnapshot.cache_lids`` (~KB on the sync feeds);
``attach_cache_image`` (core/read_path.py) rebuilds the contiguous
``[cache_slots, image_words]`` cache array from the resident heap image
wherever a snapshot is staged — primary export, follower delta apply and
log replay alike.  The fused read megakernels (kernels/fused_read.py) pin
that array in VMEM via its BlockSpec and resolve every cached level with
zero heap-image gathers and no pagetable/MVCC walk; levels below the
cached frontier fall through to the heap path, and ``cfg.lb_fraction``
deterministically routes a slice of cache-HIT lanes down the heap pipe
anyway (the Section 5 dual-pipe trick — identical results, different byte
split).  The device pipes are metered on ``CacheStats`` as
``vmem_hits`` / ``heap_gathers`` / ``lb_routed``.

The host side of the structure remains: a set-associative metadata table
keyed by LID, refreshed at export, invalidated when the page table remaps
or frees a LID (Section 5: "the cache entry for the node with that LID is
invalidated" — wired via ``PageTable.on_remap``), plus the host ``route``
model benchmarks use for the Fig. 16 hit-rate/byte-split curves.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .config import HoneycombConfig
from .heap import INTERIOR, NULL
from .telemetry import samples_from
from ..analysis import epochsan as _epochsan


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    invalidations: int = 0
    fast_path_reads: int = 0     # served from the packed cache ("DRAM")
    slow_path_reads: int = 0     # routed to the heap ("PCIe")
    fast_bytes: int = 0
    slow_bytes: int = 0
    # device read-path meters (fused megakernels, kernels/fused_read.py):
    # per-level lookups resolved from the VMEM-pinned cache array, from the
    # heap image, and the cache HITS the lb_fraction balancer routed down
    # the heap pipe anyway (lb_routed is a subset of heap_gathers)
    vmem_hits: int = 0
    heap_gathers: int = 0
    lb_routed: int = 0

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0

    @property
    def device_hit_rate(self) -> float:
        t = self.vmem_hits + self.heap_gathers
        return self.vmem_hits / t if t else 0.0

    def collect(self):
        """Registry samples (core/telemetry.py collect protocol):
        ``cache_*`` counters plus the two hit-rate gauges."""
        return samples_from(self, "cache", "cache",
                            derived=("hit_rate", "device_hit_rate"))


class InteriorCache:
    """4-way set-associative cache of interior nodes, indexed by LID."""

    def __init__(self, cfg: HoneycombConfig):
        self.cfg = cfg
        self.sets = max(1, cfg.cache_slots // cfg.cache_ways)
        self.tag = np.full((self.sets, cfg.cache_ways), NULL, np.int64)
        self.phys = np.full((self.sets, cfg.cache_ways), NULL, np.int64)
        self.tick = np.zeros((self.sets, cfg.cache_ways), np.int64)
        self._clock = 0
        self._rng = np.random.default_rng(0)
        self.stats = CacheStats()
        # packed top-level image: lids present, order = packed slot index
        self.packed_lids: np.ndarray = np.zeros((0,), np.int64)

    def _set_of(self, lid: int) -> int:
        return lid % self.sets

    def lookup(self, lid: int, phys: int) -> bool:
        """Metadata-table probe (Section 5).  A hit requires the cached
        physical address to match the live page table (the NAT check);
        mismatches count as misses and invalidate the way."""
        s = self._set_of(lid)
        self._clock += 1
        for w in range(self.cfg.cache_ways):
            if self.tag[s, w] == lid:
                if self.phys[s, w] != phys:
                    self.tag[s, w] = NULL
                    self.stats.invalidations += 1
                    break
                self.tick[s, w] = self._clock
                self.stats.hits += 1
                return True
        self.stats.misses += 1
        self._fill(lid, phys)
        return False

    def _fill(self, lid: int, phys: int):
        """Write-back on miss; random eviction within the set (the paper
        leaves smarter policies to future work)."""
        s = self._set_of(lid)
        for w in range(self.cfg.cache_ways):
            if self.tag[s, w] == NULL:
                self.tag[s, w], self.phys[s, w] = lid, phys
                self.tick[s, w] = self._clock
                return
        w = int(self._rng.integers(self.cfg.cache_ways))
        self.tag[s, w], self.phys[s, w] = lid, phys
        self.tick[s, w] = self._clock

    def invalidate(self, lid: int):
        san = _epochsan.get()
        if san is not None:   # a remap happened: the NEXT staging must
            san.note_cache_invalidate(self)   # refresh before it ships
        s = self._set_of(lid)
        for w in range(self.cfg.cache_ways):
            if self.tag[s, w] == lid:
                self.tag[s, w] = NULL
                self.stats.invalidations += 1

    # ------------------------------------------------------- top-level pack
    def frontier_lids(self, tree) -> list[int]:
        """Breadth-first LIDs of the root + top ``cfg.cache_levels`` tree
        levels (level 0 = the root — the paper's SRAM tier; deeper levels
        the DRAM tier), capped at ``cache_slots``.  Trees shorter than the
        level budget just yield every node they have down to the leaves."""
        cap = self.cfg.cache_slots
        lids = [tree.root_lid]
        level = [tree.root_lid]
        for _ in range(self.cfg.cache_levels - 1):
            nxt: list[int] = []
            for lid in level:
                phys = tree.pt.lookup(lid)
                if int(tree.heap.ntype[phys]) != INTERIOR:
                    continue
                nxt.append(int(tree.heap.left_child[phys]))
                for i in range(int(tree.heap.nitems[phys])):
                    nxt.append(int(tree.heap.svals[phys, i, 0]))
            if not nxt or len(lids) + len(nxt) > cap:
                break       # never cache a partial level: membership must
            lids.extend(nxt)  # be decidable from the LID vector alone
            level = nxt
        return lids[:cap]

    def refresh(self, tree):
        """Rebuild the packed top-level frontier at snapshot export; the
        fused Pallas read kernels receive its image rows as a VMEM-resident
        block (``TreeSnapshot.cache_lids`` / ``cache_image``)."""
        self.packed_lids = np.asarray(self.frontier_lids(tree), np.int64)
        for lid in self.packed_lids:
            self.lookup(int(lid), tree.pt.lookup(int(lid)))
        san = _epochsan.get()
        if san is not None:
            san.note_cache_refresh(self)

    def device_lids(self, tree=None) -> np.ndarray:
        """The packed frontier as the fixed-shape i32 vector that rides on
        ``TreeSnapshot.cache_lids``: ``refresh``'s LIDs, NULL-padded to
        ``cache_slots`` (refreshes the frontier first when a tree is
        given)."""
        if tree is not None:
            self.refresh(tree)
        out = np.full((self.cfg.cache_slots,), NULL, np.int32)
        out[: len(self.packed_lids)] = self.packed_lids
        return out

    # ----------------------------------------------------- load balancer
    def route(self, lid: int, phys: int, nbytes: int,
              fast_inflight: int = 0, slow_inflight: int = 0) -> str:
        """Load-balanced read routing (Section 5).  Returns 'fast' (cache)
        or 'slow' (heap/PCIe).  Balances by inflight bytes when telemetry is
        supplied, else by the configured fraction."""
        hit = self.lookup(lid, phys)
        if not hit:
            path = "slow"
        elif not self.cfg.load_balance:
            path = "fast"
        elif fast_inflight or slow_inflight:
            path = "fast" if fast_inflight <= slow_inflight else "slow"
        else:
            path = "fast" if self._rng.random() < self.cfg.lb_fast_fraction \
                else "slow"
        if path == "fast":
            self.stats.fast_path_reads += 1
            self.stats.fast_bytes += nbytes
        else:
            self.stats.slow_path_reads += 1
            self.stats.slow_bytes += nbytes
        return path
