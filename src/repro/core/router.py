"""ShardedHoneycombStore — range-sharded serving stack (scale-out layer).

The paper serves one NIC; its cost-performance argument (Section 7) is about
scale-out.  This module lifts the single-device ``StoreShard`` into the
standard scale-out deployment for ordered stores (the same split
``launch/store_dryrun.py`` models for the 256-chip mesh): the keyspace is
range-partitioned across N shards — each with its OWN tree, resident device
snapshot, incremental delta sync and ``SyncStats`` — behind the same
``put/get/scan/get_batch/scan_batch/export_snapshot`` facade, with a request
router in front:

  * writes route to the owning shard; each shard syncs independently (a
    write burst confined to one shard delta-syncs only that shard), and
    under the epoch pipeline (core/pipeline.py) each dirty shard STAGES its
    delta into a standby buffer (``begin_export``) and FLIPS independently
    (``flip``) — per-stage timing/occupancy meters are exposed as
    ``pipeline_stats`` alongside the aggregated ``SyncStats``.
  * ``get_batch`` splits by owning shard and dispatches one dense device
    batch per shard; responses scatter back to arrival order.
  * cross-shard SCANs decompose into per-shard sub-ranges — sub-range s >
    first starts at the shard's lower boundary, so per-shard floor-start
    semantics compose exactly — and results stitch in key order.  When the
    first shard holds no key <= lo, the global floor item (largest key <=
    lo, Section 3.3) is back-filled from the nearest non-empty shard to the
    left, so a cross-shard SCAN returns byte-for-byte what the unsharded
    store would.
  * the read path is collective-free: no shard ever talks to another; the
    router stitches on the host, which is the serving-layer split the
    dry-run's roofline assumes.
  * every shard slot is a ``ReplicaGroup`` (core/replica.py): one primary
    plus the ``ReplicationConfig``-configured follower replicas, each a
    device-resident snapshot fed only by the primary's delta stream.  The
    router's read-spreading policy (``replica_for_dispatch``: primary_only /
    round_robin / least_loaded) pins each dispatched GET/SCAN batch to a
    replica; all writes go to the primary, and the group skips any follower
    whose published read version lags the serving version (never stale).

``ShardedHoneycombStore(shards=1)`` is operation-for-operation equivalent to
``HoneycombStore`` — same results, same sync byte counts (enforced by
tests/test_router.py) — and likewise ``replicas=1, policy="primary_only"``
is op-for-op the unreplicated store (tests/test_replica.py) — so every
higher layer can hold a single handle and scale by configuration.
"""
from __future__ import annotations

import bisect
import contextlib
from typing import Sequence

from .api import Routing
from .btree import TreeStats
from .config import HoneycombConfig, ReplicationConfig, ShardingConfig
from .keys import int_key
from .pipeline import PipelineStats
from .replica import ReplicaGroup
from .shard import StoreShard, SyncStats
from .telemetry import merge_stats


def uniform_int_boundaries(n_items: int, shards: int,
                           width: int = 8) -> tuple[bytes, ...]:
    """Split points that spread ``int_key(0..n_items)`` evenly over
    ``shards`` ranges (benchmarks' default partitioning)."""
    return tuple(int_key(n_items * i // shards, width)
                 for i in range(1, shards))


# THE aggregation helper now lives beside the collect() protocol it feeds
# (core/telemetry.py merge_stats); this name remains as the historical
# import path — every layer still aggregates the same way.
aggregate_stats = merge_stats


class ShardedHoneycombStore:
    """Range-sharded store: N independent ``StoreShard``s behind one
    facade, requests pre-partitioned by a router."""

    def __init__(self, cfg: HoneycombConfig | None = None,
                 heap_capacity: int = 1024,
                 shards: int | ShardingConfig = 1,
                 boundaries: Sequence[bytes] | None = None,
                 replication: ReplicationConfig | None = None):
        self.cfg = cfg or HoneycombConfig()
        if isinstance(shards, ShardingConfig):
            sharding = shards
        else:
            sharding = ShardingConfig(
                shards=shards,
                boundaries=tuple(boundaries) if boundaries is not None
                else None)
        self.sharding = sharding
        self.replication = replication or ReplicationConfig()
        n = sharding.shards
        if sharding.boundaries is not None:
            self.boundaries = list(sharding.boundaries)
        else:  # uniform split of the 8-byte integer keyspace
            self.boundaries = list(uniform_int_boundaries(2 ** 64, n))
        # every shard slot is a ReplicaGroup (pure primary delegation when
        # replicas=1 — the tested op-for-op equivalence): one primary
        # StoreShard plus the configured follower replicas
        self.shards = [
            ReplicaGroup(StoreShard(self.cfg, heap_capacity, shard_id=i),
                         self.replication)
            for i in range(n)]
        self.shard_ops = [0] * n    # routed requests per shard (imbalance)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    # ------------------------------------------------------------- routing
    def shard_for_key(self, key: bytes) -> int:
        """Owning shard: i such that boundaries[i-1] <= key < boundaries[i]."""
        return bisect.bisect_right(self.boundaries, key)

    def _shard_span(self, lo: bytes, hi: bytes) -> tuple[int, int]:
        s_lo = self.shard_for_key(lo)
        return s_lo, max(s_lo, self.shard_for_key(hi))

    def _sub_lo(self, s: int, s_lo: int, lo: bytes) -> bytes:
        """Sub-range start for shard s of a scan beginning at lo: the scan's
        own lo on the owning shard, the shard's lower boundary after it (the
        boundary key itself belongs to the shard, so per-shard floor-start
        returns exactly the keys in [boundary, hi])."""
        return lo if s == s_lo else self.boundaries[s - 1]

    def replica_for_dispatch(self, shard: int) -> int:
        """Read-spreading policy pick for ``shard``'s next read batch —
        delegated to the shard's ``ReplicaGroup`` (the cursor/assignment
        state is per group, so a batch spanning N shards rotates EVERY
        shard's assignment instead of freezing on cursor parity).  The pick
        is a ROUTING decision only; the group still enforces the freshness
        rule at dispatch (a lagging follower is skipped, never stale)."""
        return self.shards[shard].replica_for_dispatch()

    def routing(self) -> Routing:
        """The routed-store wiring for the service/scheduler (core/api.py):
        range ownership, per-shard replica spreading, and read-response
        stamps from the serving group's latest dispatch."""
        return Routing(
            shard_of=self.shard_for_key,
            replica_of=self.replica_for_dispatch,
            report=lambda shard: self.shards[shard].last_dispatch,
            live_version=lambda shard: int(
                self.shards[shard].tree.versions.read_version()))

    # ------------------------------------------------------------- writes
    def put(self, key: bytes, value: bytes, thread: int = 0):
        s = self.shard_for_key(key)
        self.shard_ops[s] += 1
        self.shards[s].put(key, value, thread)

    def update(self, key: bytes, value: bytes, thread: int = 0):
        s = self.shard_for_key(key)
        self.shard_ops[s] += 1
        self.shards[s].update(key, value, thread)

    def delete(self, key: bytes, thread: int = 0):
        s = self.shard_for_key(key)
        self.shard_ops[s] += 1
        self.shards[s].delete(key, thread)

    @contextlib.contextmanager
    def deferred_sync(self):
        """Suspend every shard's automatic policy syncs for a write burst
        the caller closes with one export (scheduler.run)."""
        with contextlib.ExitStack() as stack:
            for sh in self.shards:
                stack.enter_context(sh.deferred_sync())
            yield

    # ---------------------------------------------------- host-side reads
    def get(self, key: bytes) -> bytes | None:
        s = self.shard_for_key(key)
        self.shard_ops[s] += 1
        return self.shards[s].get(key)

    def scan(self, lo: bytes, hi: bytes,
             max_items: int | None = None) -> list[tuple[bytes, bytes]]:
        """Host-side cross-shard SCAN: per-shard sub-scans stitched in key
        order, global floor back-filled from the left when needed."""
        s_lo, s_hi = self._shard_span(lo, hi)
        items: list[tuple[bytes, bytes]] = []
        for s in range(s_lo, s_hi + 1):
            self.shard_ops[s] += 1
            items.extend(self.shards[s].scan(
                self._sub_lo(s, s_lo, lo), hi, max_items))
            if max_items and len(items) >= max_items:
                break
        if lo <= hi and s_lo > 0 and not (items and items[0][0] <= lo):
            for s in range(s_lo - 1, -1, -1):    # nearest non-empty left shard
                self.shard_ops[s] += 1
                floor = self.shards[s].scan(lo, lo)
                if floor:
                    items = floor + items
                    break
        return items[:max_items] if max_items else items

    # ------------------------------------------------- snapshot mechanics
    def export_snapshot(self, force: bool = False, full: bool = False):
        """Sync every DIRTY shard (clean shards return their resident
        snapshot untouched — per-shard delta independence).  Returns the
        per-shard snapshot list."""
        return [sh.export_snapshot(force=force, full=full)
                for sh in self.shards]

    def begin_export(self, force: bool = False,
                     full: bool = False) -> list[int]:
        """Pipelined sync, staging half: enqueue every DIRTY shard's delta
        scatter into its standby buffer (asynchronous — active snapshots
        keep answering untouched).  Returns the staged shard ids."""
        return [i for i, sh in enumerate(self.shards)
                if sh.begin_export(force=force, full=full)]

    def flip(self):
        """Pipelined sync, publish half: flip every shard with a staged
        standby — each shard advances its epoch INDEPENDENTLY (a clean
        shard's active snapshot and epoch are untouched).  Returns the
        per-shard snapshot list."""
        return [sh.flip() for sh in self.shards]

    # ------------------------------------------------- accelerated reads
    def _pick(self, s: int, replica: int | None) -> int:
        """Replica for one per-shard sub-dispatch: the caller's pin (the
        scheduler's replica-homogeneous batches) or a fresh policy pick."""
        return replica if replica is not None else self.replica_for_dispatch(s)

    def get_batch(self, keys: Sequence[bytes],
                  replica: int | None = None) -> list[bytes | None]:
        """Batched GET: split by owning shard, one dense device batch per
        shard — each pinned to a replica by the read-spreading policy (or
        the caller's explicit pin) — responses scattered back to arrival
        order."""
        keys = list(keys)
        out: list[bytes | None] = [None] * len(keys)
        by_shard: dict[int, list[int]] = {}
        for i, k in enumerate(keys):
            by_shard.setdefault(self.shard_for_key(k), []).append(i)
        for s, idxs in sorted(by_shard.items()):
            self.shard_ops[s] += len(idxs)
            res = self.shards[s].get_batch([keys[i] for i in idxs],
                                           replica=self._pick(s, replica))
            for i, v in zip(idxs, res):
                out[i] = v
        return out

    def scan_batch(self, ranges: Sequence[tuple[bytes, bytes]],
                   replica: int | None = None
                   ) -> list[list[tuple[bytes, bytes]]]:
        """Batched SCAN: decompose each range into per-shard sub-ranges,
        dispatch one dense batch per shard (replica-pinned like get_batch),
        stitch per request in key order (shard order IS key order), then
        back-fill missing global floors."""
        ranges = list(ranges)
        if not ranges:
            return []
        spans = [self._shard_span(lo, hi) for lo, hi in ranges]
        per_shard: dict[int, list[tuple[int, bytes, bytes]]] = {}
        for i, (lo, hi) in enumerate(ranges):
            s_lo, s_hi = spans[i]
            for s in range(s_lo, s_hi + 1):
                per_shard.setdefault(s, []).append(
                    (i, self._sub_lo(s, s_lo, lo), hi))
        parts: dict[int, list[list[tuple[bytes, bytes]]]] = {
            i: [] for i in range(len(ranges))}
        for s, subs in sorted(per_shard.items()):
            self.shard_ops[s] += len(subs)
            res = self.shards[s].scan_batch([(a, b) for _, a, b in subs],
                                            replica=self._pick(s, replica))
            for (i, _, _), sub_items in zip(subs, res):
                parts[i].append(sub_items)   # shards visited in key order
        out = [[kv for chunk in parts[i] for kv in chunk]
               for i in range(len(ranges))]
        # floor back-fill: requests whose owning shard held no key <= lo
        pending = [(i, spans[i][0] - 1, lo)
                   for i, (lo, hi) in enumerate(ranges)
                   if spans[i][0] > 0 and lo <= hi
                   and not (out[i] and out[i][0][0] <= lo)]
        while pending:
            probe: dict[int, list[tuple[int, bytes]]] = {}
            for i, s, lo in pending:
                probe.setdefault(s, []).append((i, lo))
            pending = []
            for s, reqs in sorted(probe.items()):
                self.shard_ops[s] += len(reqs)
                res = self.shards[s].scan_batch(
                    [(lo, lo) for _, lo in reqs],
                    replica=self._pick(s, replica))
                for (i, lo), floor in zip(reqs, res):
                    if floor:
                        out[i] = floor + out[i]
                    elif s > 0:
                        pending.append((i, s - 1, lo))
        return out

    # ------------------------------------------------------------- meters
    @property
    def sync_stats(self) -> SyncStats:
        """Aggregate SyncStats across shards (counters sum; delta_fraction
        reports the worst shard)."""
        return aggregate_stats((sh.sync_stats for sh in self.shards),
                               SyncStats)

    @property
    def per_shard_sync_stats(self) -> list[SyncStats]:
        return [sh.sync_stats for sh in self.shards]

    @property
    def pipeline_stats(self) -> PipelineStats:
        """Aggregate per-stage pipeline meters across shards (staging wall
        time, staged exports, flips)."""
        return aggregate_stats((sh.pipeline_stats for sh in self.shards),
                               PipelineStats)

    @property
    def per_shard_epochs(self) -> list[int]:
        """Snapshot epoch (flip count) per shard — dirty shards advance
        independently."""
        return [sh.epoch for sh in self.shards]

    @property
    def stats(self) -> TreeStats:
        """Aggregate tree stats across shards."""
        return aggregate_stats((sh.stats for sh in self.shards), TreeStats)

    @property
    def per_shard_stats(self) -> list[TreeStats]:
        return [sh.stats for sh in self.shards]

    @property
    def cache_stats(self):
        """Aggregate interior-cache meters across shards (a replicated
        shard's group reaches its primary's cache through the
        fallthrough; follower-served fused batches are already folded in
        by the dispatching shard — see ``StoreShard._note_read_meters``)."""
        from .cache import CacheStats
        return aggregate_stats((sh.cache_stats for sh in self.shards),
                               CacheStats)

    # ------------------------------------------------ replication meters
    @property
    def replication_stats(self) -> SyncStats:
        """Aggregate follower SyncStats across every shard's replica group
        — the delta-feed amplification on top of the primary sync traffic."""
        return aggregate_stats((sh.replication_stats for sh in self.shards),
                               SyncStats)

    @property
    def replication_bytes(self) -> int:
        """Total bytes the follower delta feed moved (replica-amplification
        traffic; 0 when replicas=1)."""
        return sum(sh.replication_bytes for sh in self.shards)

    @property
    def feed_stats(self):
        """Aggregate replication-transport meters (``replica.FeedStats``)
        across every shard's replica group: feed bytes split by edge class
        (primary egress vs relay hops), epochs split by feed kind (log /
        fallback / delta / full), and catch-up traffic."""
        from .replica import FeedStats
        return aggregate_stats((sh.feed_stats for sh in self.shards),
                               FeedStats)

    @property
    def feed_bytes(self) -> int:
        """Total bytes over all replication feed edges (the per-follower
        transport the log feed shrinks to O(log_wire_bytes))."""
        return sum(sh.feed_stats.feed_bytes for sh in self.shards)

    @property
    def relay_hop_bytes(self) -> int:
        """Feed bytes carried by relay->child edges (0 on the flat feed)."""
        return sum(sh.feed_stats.relay_hop_bytes for sh in self.shards)

    @property
    def primary_egress_bytes(self) -> int:
        """Feed bytes leaving the primaries themselves — what the relay
        tree bounds at O(fanout) instead of O(replicas)."""
        return sum(sh.feed_stats.primary_egress_bytes for sh in self.shards)

    @property
    def log_fallback_epochs(self) -> int:
        """Log-feed stagings that shipped the image delta because the
        epoch was not replayable (tree shape changed / GC / overflow)."""
        return sum(sh.feed_stats.log_fallback_epochs for sh in self.shards)

    @property
    def replica_lag_epochs(self) -> list[list[int]]:
        """Per shard, each follower's epoch lag behind its primary."""
        return [sh.replica_lag_epochs for sh in self.shards]

    @property
    def replica_staleness(self) -> list[list[int]]:
        """Per shard, each follower's read-version staleness."""
        return [sh.replica_staleness for sh in self.shards]

    @property
    def per_shard_replica_ops(self) -> list[list[int]]:
        """Requests served per replica (primary first), per shard — the
        read-spread twin of ``shard_ops``."""
        return [list(sh.replica_ops) for sh in self.shards]

    @property
    def lagging_skips(self) -> int:
        """Read batches redirected off a stale follower (freshness rule)."""
        return sum(sh.lagging_skips for sh in self.shards)

    @property
    def replica_load_imbalance(self) -> float:
        """max/mean requests served per replica lane across the whole store
        (1.0 = perfectly spread; 0.0 = no device traffic yet)."""
        ops = [o for sh in self.shards for o in sh.replica_ops]
        total = sum(ops)
        if not total:
            return 0.0
        return max(ops) / (total / len(ops))

    @property
    def load_imbalance(self) -> float:
        """max/mean routed requests per shard (1.0 = perfectly balanced,
        0.0 = no traffic yet)."""
        total = sum(self.shard_ops)
        if not total:
            return 0.0
        return max(self.shard_ops) / (total / len(self.shard_ops))

    # ------------------------------------------------------------- misc
    def collect_garbage(self) -> int:
        return sum(sh.collect_garbage() for sh in self.shards)

    def check_invariants(self):
        for sh in self.shards:
            sh.tree.check_invariants()
