"""HoneycombStore — the single-device facade (paper Section 2).

The store stack is layered for scale-out:

  * ``StoreShard`` (core/shard.py) — the per-device unit: one host B+Tree
    writer, one resident device snapshot kept fresh by the incremental
    delta-sync subsystem, one ``SyncStats`` meter.  All snapshot/delta
    mechanics live there.
  * ``HoneycombStore`` (this module) — the paper's deployment: ONE shard
    serving the whole keyspace behind the public
    ``put/get/scan/get_batch/scan_batch/export_snapshot`` facade.  It is
    ``StoreShard`` under its service name; everything documented on the
    shard (sync policies, epoch-stamped wait-free reads, host SCAN
    fallbacks pinned to the snapshot read version under "explicit") holds
    here unchanged.
  * ``ShardedHoneycombStore`` (core/router.py) — the scale-out deployment:
    the keyspace range-partitioned across N shards behind the SAME facade,
    with a router that splits batches by owning shard, decomposes
    cross-shard SCANs and stitches results in key order, and syncs each
    dirty shard independently.  Each shard slot is a ``ReplicaGroup``
    (core/replica.py): a primary plus optional follower replicas fed by
    the primary's delta stream, with policy-driven read spreading.

``ShardedHoneycombStore(shards=1)`` is operation-for-operation equivalent
to ``HoneycombStore`` (same results, same sync byte counts), which is the
refactor's invariant and is enforced by tests/test_router.py.

Every layer exposes the same ``routing()`` accessor, so the typed service
front end (``HoneycombService``, core/api.py) can wrap ANY of them and
self-wire the scheduler — callers submit ``Get``/``Scan``/``Put``/
``Update``/``Delete`` ops and receive stamped ``Response``s.
"""
from __future__ import annotations

from .shard import StoreShard, SyncStats, WIRE_ENTRY_OVERHEAD  # noqa: F401
#   (WIRE_ENTRY_OVERHEAD now lives in core/api.py — the op wire codec —
#    and is re-exported here for the historical import path)

__all__ = ["HoneycombStore", "StoreShard", "SyncStats",
           "WIRE_ENTRY_OVERHEAD"]


class HoneycombStore(StoreShard):
    """The paper's single-NIC deployment: one ``StoreShard`` owning the
    entire keyspace.  See the class and module docs in core/shard.py for
    the snapshot/delta-sync semantics."""
