"""HoneycombStore — the system facade (paper Section 2).

Ties the host-side writer (``HoneycombTree``), the MVCC/epoch machinery and
the accelerator read path together:

  * ``export_snapshot()`` — the host->accelerator synchronization point.  It
    plays the role of the PCIe DMA + page-table update commands: the packed
    heap arrays and the accelerator's copies of the page table and global
    read version are refreshed.  Sync traffic is metered so benchmarks can
    reproduce the paper's PCIe-amortization results (log blocks exist to
    make this cheap).
  * ``get_batch()/scan_batch()`` — wait-free accelerated reads.  Each batch
    is stamped with epoch sequence numbers (Section 4.1: S_old/S_new) so the
    host GC never reclaims a buffer a batch might still read.
  * host fallbacks — the paper runs SCANs on CPU cores too when beneficial
    (Section 6.3); ``get()``/``scan()`` mirror that path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .btree import HoneycombTree
from .cache import InteriorCache
from .config import HoneycombConfig
from .keys import pack_keys
from .read_path import (GetResult, ScanResult, TreeSnapshot, batched_get,
                        batched_scan)

# jit the accelerator entry points once per (config, snapshot-shape): the
# eager op-by-op dispatch otherwise accumulates thousands of tiny LLVM JIT
# dylibs across a benchmark run (vm.max_map_count exhaustion)
_jit_get = jax.jit(batched_get, static_argnames="cfg")
_jit_scan = jax.jit(batched_scan, static_argnames="cfg")


@dataclasses.dataclass
class SyncStats:
    snapshots: int = 0
    bytes_synced: int = 0
    pagetable_commands: int = 0
    read_version_updates: int = 0


class HoneycombStore:
    def __init__(self, cfg: HoneycombConfig | None = None,
                 heap_capacity: int = 1024):
        self.cfg = cfg or HoneycombConfig()
        self.tree = HoneycombTree(self.cfg, heap_capacity)
        self.cache = InteriorCache(self.cfg)
        self.sync_stats = SyncStats()
        self._snapshot: TreeSnapshot | None = None
        self._snapshot_dirty = True

    # ------------------------------------------------------------- writes
    def put(self, key: bytes, value: bytes, thread: int = 0):
        self.tree.put(key, value, thread)
        self._snapshot_dirty = True

    def update(self, key: bytes, value: bytes, thread: int = 0):
        self.tree.update(key, value, thread)
        self._snapshot_dirty = True

    def delete(self, key: bytes, thread: int = 0):
        self.tree.delete(key, thread)
        self._snapshot_dirty = True

    # ---------------------------------------------------- host-side reads
    def get(self, key: bytes) -> bytes | None:
        return self.tree.get(key)

    def scan(self, lo: bytes, hi: bytes, max_items: int | None = None):
        return self.tree.scan(lo, hi, max_items)

    # ------------------------------------------------- snapshot mechanics
    def export_snapshot(self, force: bool = False) -> TreeSnapshot:
        """Host -> accelerator sync (the PCIe analogue).

        Real hardware DMA-reads node buffers on demand; here the packed
        arrays are republished wholesale and the page-table/read-version
        commands are counted with paper-equivalent granularity."""
        if self._snapshot is not None and not self._snapshot_dirty and not force:
            return self._snapshot
        t = self.tree
        h = t.heap
        pt_image = t.pt.flush_to_device()
        self.sync_stats.pagetable_commands = t.pt.sync_commands
        self.sync_stats.read_version_updates = t.versions.device_updates
        self.sync_stats.snapshots += 1

        def dev(a, dtype=None):
            arr = np.asarray(a)
            if dtype is not None:
                arr = arr.astype(dtype)
            self.sync_stats.bytes_synced += arr.nbytes
            return jnp.asarray(arr)

        snap = TreeSnapshot(
            ntype=dev(h.ntype), nitems=dev(h.nitems),
            version=dev(h.version, np.int32), oldptr=dev(h.oldptr),
            left_child=dev(h.left_child), lsib=dev(h.lsib), rsib=dev(h.rsib),
            skeys=dev(h.skeys), skeylen=dev(h.skeylen),
            svals=dev(h.svals), svallen=dev(h.svallen),
            n_shortcuts=dev(h.n_shortcuts), sc_keys=dev(h.sc_keys),
            sc_keylen=dev(h.sc_keylen), sc_pos=dev(h.sc_pos),
            nlog=dev(h.nlog), log_keys=dev(h.log_keys),
            log_keylen=dev(h.log_keylen), log_vals=dev(h.log_vals),
            log_vallen=dev(h.log_vallen), log_op=dev(h.log_op, np.int32),
            log_backptr=dev(h.log_backptr),
            log_hint=dev(h.log_hint, np.int32),
            log_vdelta=dev(h.log_vdelta, np.int32),
            pagetable=dev(pt_image),
            root_lid=jnp.int32(t.root_lid),
            read_version=jnp.int32(t.versions.read_version()),
        )
        self.cache.refresh(t)
        self._snapshot = snap
        self._snapshot_dirty = False
        return snap

    # ------------------------------------------------- accelerated reads
    def get_batch(self, keys: Sequence[bytes]) -> list[bytes | None]:
        """Batched GET on the accelerator path, epoch-stamped."""
        snap = self.export_snapshot()
        lanes, lens = pack_keys(list(keys), self.cfg.key_words)
        lo, hi = self.tree.epochs.accel_begin_batch(len(keys))
        try:
            res: GetResult = _jit_get(
                snap, jnp.asarray(lanes), jnp.asarray(lens), cfg=self.cfg)
            found = np.asarray(res.found)
            vals = np.asarray(res.vals)
            vlens = np.asarray(res.vallens)
        finally:
            self.tree.epochs.accel_complete_batch(lo, hi)
        out: list[bytes | None] = []
        for i in range(len(keys)):
            if not found[i]:
                out.append(None)
            else:
                out.append(self._decode_value(vals[i], int(vlens[i])))
        return out

    def scan_batch(self, ranges: Sequence[tuple[bytes, bytes]]
                   ) -> list[list[tuple[bytes, bytes]]]:
        """Batched SCAN on the accelerator path.  Requests the device path
        could not complete (leaf budget/slots) fall back to the host — the
        paper likewise executes some SCANs on CPU cores (Section 6.3)."""
        snap = self.export_snapshot()
        lo_l, lo_n = pack_keys([r[0] for r in ranges], self.cfg.key_words)
        hi_l, hi_n = pack_keys([r[1] for r in ranges], self.cfg.key_words)
        slo, shi = self.tree.epochs.accel_begin_batch(len(ranges))
        try:
            res: ScanResult = _jit_scan(
                snap, jnp.asarray(lo_l), jnp.asarray(lo_n),
                jnp.asarray(hi_l), jnp.asarray(hi_n), cfg=self.cfg)
            count = np.asarray(res.count)
            keys = np.asarray(res.keys)
            klens = np.asarray(res.keylens)
            vals = np.asarray(res.vals)
            vlens = np.asarray(res.vallens)
            trunc = np.asarray(res.truncated)
        finally:
            self.tree.epochs.accel_complete_batch(slo, shi)
        out = []
        for b, (lo, hi) in enumerate(ranges):
            if trunc[b]:
                out.append(self.tree.scan(lo, hi))   # host fallback
                continue
            items = []
            for j in range(int(count[b])):
                k = keys[b, j].astype(">u4").tobytes()[: int(klens[b, j])]
                items.append((k, self._decode_value(vals[b, j],
                                                    int(vlens[b, j]))))
            out.append(items)
        return out

    def _decode_value(self, lanes: np.ndarray, length: int) -> bytes:
        if length <= self.cfg.max_inline_val_bytes:
            return lanes.astype(">u4").tobytes()[:length]
        return self.tree.overflow.read(int(lanes[0]))

    # ------------------------------------------------------------- misc
    def collect_garbage(self) -> int:
        return self.tree.gc.collect()

    @property
    def stats(self):
        return self.tree.stats
