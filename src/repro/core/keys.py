"""Key packing and comparison.

The paper's KSU compares variable-size keys in 16-byte fragments through a
barrel-shifter-fed pipeline (Section 4.2).  The TPU-native equivalent packs
keys big-endian into uint32 lanes so that lexicographic *byte* order equals
lexicographic *lane* order (unsigned), with key length as the tie break for
prefix relationships.  A comparison is then a vectorized lane compare plus a
first-difference select — no byte loops, VPU friendly.

Host-side helpers use numpy; `jax_key_*` are the jit-compatible twins used by
the batched read path and the Pallas kernel reference oracles.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def pack_key(key: bytes, key_words: int) -> np.ndarray:
    """Pack bytes big-endian into uint32 lanes, zero padded."""
    if len(key) > key_words * 4:
        raise ValueError(f"key of {len(key)} bytes exceeds {key_words * 4}")
    buf = key + b"\x00" * (key_words * 4 - len(key))
    return np.frombuffer(buf, dtype=">u4").astype(np.uint32)


def unpack_key(lanes: np.ndarray, length: int) -> bytes:
    buf = np.asarray(lanes, dtype=np.uint32).astype(">u4").tobytes()
    return buf[:length]


def pack_keys(keys: list[bytes], key_words: int) -> tuple[np.ndarray, np.ndarray]:
    """Pack a batch of keys -> (lanes [B, KW] uint32, lengths [B] int32)."""
    lanes = np.stack([pack_key(k, key_words) for k in keys]) if keys else \
        np.zeros((0, key_words), np.uint32)
    lens = np.array([len(k) for k in keys], np.int32)
    return lanes, lens


# --- host comparisons (numpy scalars) ---------------------------------------

def key_cmp(a: np.ndarray, alen: int, b: np.ndarray, blen: int) -> int:
    """memcmp semantics over packed lanes: -1 / 0 / +1."""
    a = np.asarray(a, np.uint32)
    b = np.asarray(b, np.uint32)
    neq = a != b
    if neq.any():
        i = int(np.argmax(neq))
        return -1 if a[i] < b[i] else 1
    # identical padded lanes: shorter key is a strict prefix => smaller
    return (alen > blen) - (alen < blen)


def key_less(a, alen, b, blen) -> bool:
    return key_cmp(a, alen, b, blen) < 0


def key_leq(a, alen, b, blen) -> bool:
    return key_cmp(a, alen, b, blen) <= 0


# --- jax comparisons (broadcastable) -----------------------------------------

def jax_key_cmp(a, alen, b, blen):
    """Vectorized memcmp: sign of comparison, broadcasting over leading dims.

    a: [..., KW] uint32, alen: [...] int32 (same for b).  Returns [...] int32
    in {-1, 0, 1}.
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    neq = a != b
    any_neq = jnp.any(neq, axis=-1)
    first = jnp.argmax(neq, axis=-1)  # first differing lane (0 if none)
    av = jnp.take_along_axis(a, first[..., None], axis=-1)[..., 0]
    bv = jnp.take_along_axis(b, first[..., None], axis=-1)[..., 0]
    lane_sign = jnp.where(av < bv, -1, 1).astype(jnp.int32)
    len_sign = jnp.sign(alen - blen).astype(jnp.int32)
    return jnp.where(any_neq, lane_sign, len_sign)


def jax_key_less(a, alen, b, blen):
    return jax_key_cmp(a, alen, b, blen) < 0


def jax_key_leq(a, alen, b, blen):
    return jax_key_cmp(a, alen, b, blen) <= 0


def int_key(x: int, width: int = 8) -> bytes:
    """Fixed-width big-endian integer key (sorts numerically)."""
    return int(x).to_bytes(width, "big")
