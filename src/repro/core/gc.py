"""Epoch-based memory reclamation (paper Section 3.2).

Writers that replace node buffers push the old physical slots onto a garbage
list tagged with a *vector timestamp*: the current operation sequence number
of every CPU thread plus the newest inflight sequence number on the
accelerator (S_new).  A slot is reclaimable once every CPU thread has moved
past its entry and the accelerator's *oldest* inflight operation (S_old) is
newer than the accelerator entry.

The accelerator epoch window [S_old, S_new] maps to batched execution: a
batch of reads stamped with sequence numbers [s, s+B) holds the epoch open
until the batch completes (the snapshot it executed against may reference the
old slots).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable


@dataclasses.dataclass
class GarbageEntry:
    slots: tuple[int, ...]          # physical node slots to reclaim
    lids: tuple[int, ...]           # LIDs to recycle (split/merge leftovers)
    overflow: tuple[int, ...]       # overflow-heap slots
    cpu_stamp: dict[int, int]       # thread id -> op seqno at enqueue
    accel_stamp: int                # accelerator S_new at enqueue


class EpochManager:
    """Tracks per-thread CPU op sequence numbers and the accelerator's
    [S_old, S_new] inflight window (paper Section 4.1)."""

    def __init__(self):
        self.cpu_seq: dict[int, int] = {}
        self.accel_s_new = 0
        self._accel_inflight: dict[int, bool] = {}  # seqno -> done?

    def cpu_begin(self, thread: int) -> int:
        self.cpu_seq[thread] = self.cpu_seq.get(thread, 0) + 1
        return self.cpu_seq[thread]

    def accel_begin_batch(self, n: int) -> tuple[int, int]:
        """Assign sequence numbers to a batch of accelerator requests."""
        lo = self.accel_s_new + 1
        self.accel_s_new += n
        for s in range(lo, self.accel_s_new + 1):
            self._accel_inflight[s] = False
        return lo, self.accel_s_new

    def accel_complete_batch(self, lo: int, hi: int):
        for s in range(lo, hi + 1):
            self._accel_inflight[s] = True
        # retire the completed prefix
        for s in sorted(self._accel_inflight):
            if self._accel_inflight[s]:
                del self._accel_inflight[s]
            else:
                break

    @property
    def accel_s_old(self) -> int:
        """Oldest inflight accelerator op (== S_new + 1 when idle)."""
        if self._accel_inflight:
            return min(self._accel_inflight)
        return self.accel_s_new + 1


class GarbageCollector:
    def __init__(self, epochs: EpochManager,
                 free_slot: Callable[[int], None],
                 free_lid: Callable[[int], None],
                 free_overflow: Callable[[int], None]):
        self.epochs = epochs
        self.list: deque[GarbageEntry] = deque()
        self._free_slot = free_slot
        self._free_lid = free_lid
        self._free_overflow = free_overflow
        self.reclaimed = 0

    def defer(self, slots=(), lids=(), overflow=()):
        self.list.append(GarbageEntry(
            slots=tuple(slots), lids=tuple(lids), overflow=tuple(overflow),
            cpu_stamp=dict(self.epochs.cpu_seq),
            accel_stamp=self.epochs.accel_s_new))

    def _reclaimable(self, e: GarbageEntry) -> bool:
        for t, s in e.cpu_stamp.items():
            if self.epochs.cpu_seq.get(t, 0) <= s:
                return False
        return self.epochs.accel_s_old > e.accel_stamp

    def collect(self) -> int:
        """Scan the garbage list and reclaim everything unreachable."""
        kept: deque[GarbageEntry] = deque()
        n = 0
        while self.list:
            e = self.list.popleft()
            if self._reclaimable(e):
                for s in e.slots:
                    self._free_slot(s)
                for lid in e.lids:
                    self._free_lid(lid)
                for o in e.overflow:
                    self._free_overflow(o)
                n += 1
            else:
                kept.append(e)
        self.list = kept
        self.reclaimed += n
        return n

    def __len__(self):
        return len(self.list)
