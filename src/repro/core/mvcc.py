"""MVCC version management (paper Section 3.2).

Two shared 64-bit counters: the *global write version* (fetch-and-add by
writers) and the *global read version* (released in version order).  The
accelerator holds a copy of the read version, updated "over PCIe"; responses
to writes are delayed until that update completes — modeled by
``release()`` returning only after the device copy advances.

The release protocol supports multiple logical writers: a writer becomes
releasable when it is the writer with the smallest outstanding write
version; releases cascade in version order.
"""
from __future__ import annotations

import heapq


class VersionManager:
    def __init__(self, mvcc: bool = True):
        self.mvcc = mvcc
        self.global_write_version = 0
        self.global_read_version = 0
        # accelerator's copy, updated over "PCIe"
        self.device_read_version = 0
        self.device_updates = 0          # PCIe writes of the read version
        self._inflight: set[int] = set()  # acquired but unreleased versions
        self._done: list[int] = []        # finished, awaiting in-order release

    def acquire_write_version(self) -> int:
        """fetch_and_add on the global write version."""
        if not self.mvcc:
            return 0
        self.global_write_version += 1
        wv = self.global_write_version
        self._inflight.add(wv)
        return wv

    def release(self, wv: int):
        """Release changes to readers in version order (Section 3.2): set the
        global read version when this writer is the smallest outstanding one,
        then propagate to the accelerator copy."""
        if not self.mvcc:
            return
        self._inflight.discard(wv)
        heapq.heappush(self._done, wv)
        advanced = False
        while self._done and (not self._inflight
                              or self._done[0] < min(self._inflight)):
            self.global_read_version = heapq.heappop(self._done)
            advanced = True
        if advanced:
            # the PCIe update the paper waits on before acking the write
            self.device_read_version = self.global_read_version
            self.device_updates += 1

    def abort(self, wv: int):
        """A writer that restarts must still release its version number so
        later versions can be published."""
        self.release(wv)

    def read_version(self) -> int:
        """What the accelerator stamps onto incoming requests."""
        return self.device_read_version if self.mvcc else 0
