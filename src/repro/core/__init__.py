"""Honeycomb core: the paper's contribution as a composable JAX module."""
from .config import HoneycombConfig, DEFAULT_CONFIG
from .btree import HoneycombTree
from .store import HoneycombStore, SyncStats
from .read_path import (TreeSnapshot, SnapshotDelta, ScanResult, GetResult,
                        apply_snapshot_delta, batched_get, batched_scan,
                        descend, log_sort_positions)
from .scheduler import OutOfOrderScheduler, Request
from .cache import InteriorCache

__all__ = [
    "HoneycombConfig", "DEFAULT_CONFIG", "HoneycombTree", "HoneycombStore",
    "TreeSnapshot", "SnapshotDelta", "ScanResult", "GetResult",
    "apply_snapshot_delta", "batched_get", "batched_scan",
    "descend", "log_sort_positions", "OutOfOrderScheduler", "Request",
    "InteriorCache", "SyncStats",
]
