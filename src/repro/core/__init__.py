"""Honeycomb core: the paper's contribution as a composable JAX module."""
from .config import (HoneycombConfig, DEFAULT_CONFIG, ShardingConfig,
                     bucket_pow2)
from .btree import HoneycombTree
from .pipeline import PIPELINE_MODES, PipelineStats
from .shard import StoreShard
from .store import HoneycombStore, SyncStats
from .router import ShardedHoneycombStore, uniform_int_boundaries
from .read_path import (TreeSnapshot, SnapshotDelta, ScanResult, GetResult,
                        apply_snapshot_delta, batched_get, batched_scan,
                        descend, log_sort_positions)
from .scheduler import OutOfOrderScheduler, Request
from .cache import InteriorCache

__all__ = [
    "HoneycombConfig", "DEFAULT_CONFIG", "ShardingConfig", "HoneycombTree",
    "HoneycombStore", "StoreShard", "ShardedHoneycombStore",
    "uniform_int_boundaries", "bucket_pow2",
    "PIPELINE_MODES", "PipelineStats",
    "TreeSnapshot", "SnapshotDelta", "ScanResult", "GetResult",
    "apply_snapshot_delta", "batched_get", "batched_scan",
    "descend", "log_sort_positions", "OutOfOrderScheduler", "Request",
    "InteriorCache", "SyncStats",
]
