"""Honeycomb core: the paper's contribution as a composable JAX module."""
from .config import HoneycombConfig, DEFAULT_CONFIG
from .btree import HoneycombTree
from .store import HoneycombStore
from .read_path import (TreeSnapshot, ScanResult, GetResult, batched_get,
                        batched_scan, descend, log_sort_positions)
from .scheduler import OutOfOrderScheduler, Request
from .cache import InteriorCache

__all__ = [
    "HoneycombConfig", "DEFAULT_CONFIG", "HoneycombTree", "HoneycombStore",
    "TreeSnapshot", "ScanResult", "GetResult", "batched_get", "batched_scan",
    "descend", "log_sort_positions", "OutOfOrderScheduler", "Request",
    "InteriorCache",
]
