"""Honeycomb core: the paper's contribution as a composable JAX module."""
from .config import (HoneycombConfig, DEFAULT_CONFIG, FeedTopology,
                     REPLICA_FEEDS, REPLICA_POLICIES, ReplicationConfig,
                     ServiceConfig, ShardingConfig, TelemetryConfig,
                     bucket_pow2)
from .telemetry import (CLOCK, Clock, Histogram, MetricSample,
                        MetricsRegistry, Span, Telemetry, Trace, Tracer,
                        chrome_trace_events, merge_stats, parse_prometheus,
                        prom_value)
from .api import (Delete, Get, HoneycombService, Put, Response, Routing,
                  Scan, Ticket, Update, WIRE_ENTRY_OVERHEAD, WireDecodeError,
                  decode_wire, decode_wire_stream, wire_entry_nbytes)
from .btree import HoneycombTree
from .pipeline import PIPELINE_MODES, PipelineStats
from .shard import StagedSync, StoreShard
from .store import HoneycombStore, SyncStats
from .replica import FeedStats, FollowerReplica, ReplicaGroup
from .router import (ShardedHoneycombStore, aggregate_stats,
                     uniform_int_boundaries)
from .read_path import (TreeSnapshot, SnapshotDelta, LegacyTreeSnapshot,
                        LegacySnapshotDelta, ScanResult, GetResult,
                        apply_snapshot_delta, batched_get, batched_scan,
                        descend, log_sort_positions, snapshot_fields)
from .schema import (FIELD_NAMES, NARROWED_FIELDS, NODE_SCHEMA, FieldSpec,
                     NodeImageLayout)
from .scheduler import OutOfOrderScheduler, Request
from .cache import InteriorCache

__all__ = [
    "HoneycombConfig", "DEFAULT_CONFIG", "ServiceConfig", "ShardingConfig",
    "ReplicationConfig", "REPLICA_POLICIES", "REPLICA_FEEDS",
    "FeedTopology", "HoneycombTree",
    "HoneycombStore", "StoreShard", "StagedSync", "ShardedHoneycombStore",
    "ReplicaGroup", "FollowerReplica", "FeedStats", "aggregate_stats",
    "uniform_int_boundaries", "bucket_pow2",
    "PIPELINE_MODES", "PipelineStats",
    "Get", "Scan", "Put", "Update", "Delete", "Response", "Ticket",
    "Routing", "HoneycombService", "decode_wire", "decode_wire_stream",
    "wire_entry_nbytes", "WIRE_ENTRY_OVERHEAD", "WireDecodeError",
    "TreeSnapshot", "SnapshotDelta", "LegacyTreeSnapshot",
    "LegacySnapshotDelta", "ScanResult", "GetResult",
    "apply_snapshot_delta", "batched_get", "batched_scan",
    "descend", "log_sort_positions", "snapshot_fields",
    "FieldSpec", "NODE_SCHEMA", "FIELD_NAMES", "NARROWED_FIELDS",
    "NodeImageLayout", "OutOfOrderScheduler", "Request",
    "InteriorCache", "SyncStats",
    "TelemetryConfig", "Telemetry", "MetricsRegistry", "MetricSample",
    "Histogram", "Tracer", "Trace", "Span", "Clock", "CLOCK",
    "chrome_trace_events", "merge_stats", "parse_prometheus", "prom_value",
]
