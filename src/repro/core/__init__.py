"""Honeycomb core: the paper's contribution as a composable JAX module."""
from .config import (HoneycombConfig, DEFAULT_CONFIG, REPLICA_POLICIES,
                     ReplicationConfig, ShardingConfig, bucket_pow2)
from .btree import HoneycombTree
from .pipeline import PIPELINE_MODES, PipelineStats
from .shard import StagedSync, StoreShard
from .store import HoneycombStore, SyncStats
from .replica import FollowerReplica, ReplicaGroup
from .router import (ShardedHoneycombStore, aggregate_stats,
                     uniform_int_boundaries)
from .read_path import (TreeSnapshot, SnapshotDelta, ScanResult, GetResult,
                        apply_snapshot_delta, batched_get, batched_scan,
                        descend, log_sort_positions)
from .scheduler import OutOfOrderScheduler, Request
from .cache import InteriorCache

__all__ = [
    "HoneycombConfig", "DEFAULT_CONFIG", "ShardingConfig",
    "ReplicationConfig", "REPLICA_POLICIES", "HoneycombTree",
    "HoneycombStore", "StoreShard", "StagedSync", "ShardedHoneycombStore",
    "ReplicaGroup", "FollowerReplica", "aggregate_stats",
    "uniform_int_boundaries", "bucket_pow2",
    "PIPELINE_MODES", "PipelineStats",
    "TreeSnapshot", "SnapshotDelta", "ScanResult", "GetResult",
    "apply_snapshot_delta", "batched_get", "batched_scan",
    "descend", "log_sort_positions", "OutOfOrderScheduler", "Request",
    "InteriorCache", "SyncStats",
]
