"""Node heap: structure-of-arrays storage for B+Tree node buffers.

The paper allocates fixed 8 KB node buffers in pinned host memory and
addresses them physically (Section 3.1).  Here a *physical slot* is a row
across a set of packed numpy arrays — the layout the TPU read path and the
Pallas kernels consume directly.  Buffers are never mutated after they are
published to readers except for the leaf fast path (log append), exactly
mirroring the paper: structural changes allocate fresh slots and swap a LID
mapping (Section 3.4); the in-place log append is made safe by MVCC version
filtering (Section 3.2).

The 64-bit packed (size, lock, seqno) word of the paper's header is kept as
``lockword``: bit 63 = lock bit, bits 32..62 = sequence number, low 32 bits =
bytes-used stand-in (item count).  ``try_lock`` implements the
compare-and-swap-with-expected-seqno protocol of Section 3.4.
"""
from __future__ import annotations

import numpy as np

from .config import HoneycombConfig
from .schema import FIELD_NAMES, NODE_SCHEMA

INTERIOR, LEAF = 0, 1
NULL = -1

# log entry op codes (paper Section 3.1: inserted/updated items or delete
# markers)
LOG_INSERT, LOG_UPDATE, LOG_DELETE = 0, 1, 2

_LOCK_BIT = np.int64(1) << np.int64(63)
_SEQ_SHIFT = np.int64(32)
_SEQ_MASK = (np.int64(1) << np.int64(31)) - np.int64(1)


class NodeHeap:
    """Slab of node buffers with a free list."""

    def __init__(self, cfg: HoneycombConfig, capacity: int = 1024):
        self.cfg = cfg
        self.capacity = 0
        self._free: list[int] = []
        # rows whose packed arrays changed since the last device sync — the
        # unit of host->accelerator delta transfer (paper: one node buffer)
        self.dirty: set[int] = set()
        # bumped when the arrays are reallocated (growth): resident device
        # snapshots have the old shapes and need a full republish
        self.generation = 0
        self._alloc_arrays(capacity)

    # -- storage -------------------------------------------------------------
    def _alloc_arrays(self, capacity: int):
        c = self.cfg
        old = self.capacity

        def grow(name, shape, dtype, fill=0):
            new = np.full((capacity, *shape), fill, dtype=dtype)
            if old:
                new[:old] = getattr(self, name)
            setattr(self, name, new)

        # every device-visible per-node field comes from the one layout
        # schema (core/schema.py) — same names, order, host dtypes and NULL
        # fills the packed node image is defined over.  svals lane 0 holds
        # the child LID on interior nodes; svallen doubles as overflow tag.
        for spec in NODE_SCHEMA:
            grow(spec.name, spec.shape(c), np.dtype(spec.host), spec.fill)
        # host-only lock/seqno word (Section 3.4): never crosses the bus,
        # so it lives outside the schema
        grow("lockword", (), np.int64)

        self._free.extend(range(capacity - 1, old - 1, -1))
        self.capacity = capacity
        self.generation += 1

    # device-visible per-node fields, in schema/layout order
    ARRAY_FIELDS = FIELD_NAMES

    # -- alloc / free ----------------------------------------------------------
    def alloc(self) -> int:
        if not self._free:
            self._alloc_arrays(self.capacity * 2)
        slot = self._free.pop()
        self.dirty.add(slot)       # caller fills the buffer next
        return slot

    def free(self, slot: int):
        self._wipe(slot)
        self.dirty.add(slot)
        self._free.append(slot)

    def mark_dirty(self, slot: int):
        """Record an in-place mutation of a published buffer (log append,
        sibling relink) for the next delta sync."""
        self.dirty.add(slot)

    def _wipe(self, s: int):
        self.ntype[s] = 0
        self.nitems[s] = 0
        self.version[s] = 0
        self.oldptr[s] = NULL
        self.left_child[s] = NULL
        self.lsib[s] = NULL
        self.rsib[s] = NULL
        self.lockword[s] = 0
        self.n_shortcuts[s] = 0
        self.nlog[s] = 0
        self.skeylen[s] = 0
        self.svallen[s] = 0

    @property
    def live_slots(self) -> int:
        return self.capacity - len(self._free)

    # -- lock word (Section 3.4) ----------------------------------------------
    def seqno(self, s: int) -> int:
        return int((self.lockword[s] >> _SEQ_SHIFT) & _SEQ_MASK)

    def is_locked(self, s: int) -> bool:
        return bool(self.lockword[s] & _LOCK_BIT)

    def try_lock(self, s: int, expected_seqno: int) -> bool:
        """CAS(lock=0, seqno=expected) -> lock=1.  Single host process, so a
        plain check-and-set is an atomic CAS; the protocol (restart on seqno
        mismatch) is what the tests exercise."""
        if self.is_locked(s) or self.seqno(s) != expected_seqno:
            return False
        self.lockword[s] |= _LOCK_BIT
        return True

    def unlock_bump(self, s: int):
        """Paper: size/seqno/lock packed in one word so the update is a single
        store — here: clear lock, increment seqno."""
        seq = (self.seqno(s) + 1) & int(_SEQ_MASK)
        self.lockword[s] = (np.int64(seq) << _SEQ_SHIFT)

    def unlock(self, s: int):
        self.lockword[s] &= ~_LOCK_BIT


class OverflowHeap:
    """Out-of-node value storage (paper: values > 469 B live outside the
    node).  Values are immutable once written; slots are recycled via GC."""

    def __init__(self, cfg: HoneycombConfig, capacity: int = 256):
        self.cfg = cfg
        self.vals = np.zeros((capacity, cfg.overflow_words), np.uint32)
        self.lens = np.zeros((capacity,), np.int32)
        self._free = list(range(capacity - 1, -1, -1))

    def alloc(self, data: bytes) -> int:
        if not self._free:
            cap = len(self.lens)
            self.vals = np.concatenate([self.vals, np.zeros_like(self.vals)])
            self.lens = np.concatenate([self.lens, np.zeros_like(self.lens)])
            self._free.extend(range(2 * cap - 1, cap - 1, -1))
        slot = self._free.pop()
        buf = data + b"\x00" * (-len(data) % 4)
        lanes = np.frombuffer(buf, dtype=">u4").astype(np.uint32)
        self.vals[slot, :] = 0
        self.vals[slot, : len(lanes)] = lanes
        self.lens[slot] = len(data)
        return slot

    def read(self, slot: int) -> bytes:
        n = int(self.lens[slot])
        return self.vals[slot].astype(">u4").tobytes()[:n]

    def free(self, slot: int):
        self.lens[slot] = 0
        self._free.append(slot)
