"""Replication subsystem — follower shards fed by the primary's deltas.

Honeycomb's export path already produces exactly the artifact a replica
needs: a resident device snapshot plus an incremental delta stream (paper
Sections 3-4).  Reads scale with accelerator lanes while writes stay on the
CPU (Sections 3.4/5), so for the read-dominated workloads the paper targets
the natural next scaling axis is to serve each range-shard from MORE THAN
ONE device image and spread read batches over them — the same offload shape
"Reliable Replication Protocols on SmartNICs" (Katebzadeh et al.) puts on
the NIC data path, and exactly where F2 (Kanellis et al.) shows skewed
read-heavy workloads win.

Design
======

**FollowerReplica** — a device-resident copy of the primary's snapshot with
its own buffers (its own device lane): an active image, a standby image,
its own ``SyncStats`` and an epoch/read-version watermark.  A follower has
NO tree of its own — it is fed exclusively by the primary's staged sync
payloads (``StagedSync``, core/shard.py):

  * under the LOG feed (``ReplicationConfig.feed="log"``, the default) a
    replayable delta epoch ships its ``LogPayload`` — the epoch's writes
    wire-encoded ONCE by the core/api.py codec plus a 24 B/entry placement
    sidecar — and the follower applies it with the ``log_replay_scatter``
    Pallas kernel: each entry's ~(key_words + val_words + 6) words scatter
    into the follower's packed image at static ``NodeImageLayout``
    offsets.  Per follower the feed costs O(log_wire_bytes), typically
    tens of bytes per write, instead of re-issuing the primary's
    5 KB-per-dirty-node image-row DMAs — the same slow-bus argument that
    drives Honeycomb's own batching, applied to the replication fan-out;
  * an epoch whose tree shape changed (split/root growth/GC moves/pending
    page-table commands, or an overflow-length value) has NO wire-replay
    representation, so it falls back per-epoch to the image-row delta —
    metered as ``FeedStats.log_fallback_epochs`` so benchmarks report the
    fallback fraction.  ``feed="delta"`` pins every epoch to the image
    delta (the pre-log feed, kept as the byte-accounting reference);
  * a "full" payload (first export, heap growth, dirty fraction over the
    delta threshold) device-copies the primary's staged standby;
  * a follower that missed a payload (paused, attached late, or cut off
    behind a paused relay) is OUT OF SYNC: neither deltas nor log replays
    apply to its base, so it catches up with a full copy at the next
    reachable staging (or ``resync_follower``), and until then its
    published read version lags and the router never serves it.

**Relay tree** (``FeedTopology(fanout, depth)``, core/config.py) — with
``depth >= 1`` the one encoded payload routes primary -> up to ``fanout``
relays -> their children instead of primary -> everyone: each follower
receives its bytes from ``topology.parents()``'s parent edge, so the
feeder's egress (``FeedStats.primary_egress_bytes``) is O(fanout) while
downstream edges are metered as ``relay_hop_bytes``.  Relays are ordinary
followers that forward the payload they received; a PAUSED relay cuts off
its whole subtree (descendants miss the payload, go out of sync, and are
routed around by the freshness rule until a live path lets them take a
full catch-up).  ``depth=0`` is the flat O(replicas)-egress feed.

**ReplicaGroup** — one primary ``StoreShard`` plus N-1 followers behind the
shard facade (attribute access falls through to the primary, so a group is
drop-in wherever a shard was).  The group wires the primary's ``on_staged``
/ ``on_flip`` hooks, so a replication round is exactly the epoch pipeline's
sync: ``begin_export`` encodes the epoch once and stages the SAME payload
into every reachable follower's standby (each replay an independently
enqueued device op), and ``flip`` publishes the whole group — whichever
path triggered it (facade export, scheduler stage_export, or an "every_k"
policy auto-sync).  ``FeedStats`` meters the whole transport: feed bytes
by edge class, epochs by feed kind, and catch-up traffic.

**Freshness rule (no stale reads).**  Writes always go to the primary.  A
dispatched read batch is pinned to a replica whose published read version
covers the version the group currently serves (the primary's active
snapshot read version — the scheduler's admitted read version after
stage_export).  A lagging follower is SKIPPED — the batch silently serves
from the primary instead (metered as ``lagging_skips``) — so spread reads
are indistinguishable from primary reads: linearizable, never stale.
``replica_lag_epochs`` / ``replica_staleness`` meter each follower's epoch
and read-version lag.

**Equivalence invariant (mirroring PR 2's shards=1 and PR 3's serial
mode).**  ``replicas=1, policy="primary_only"`` is operation-for-operation
identical to the unreplicated store, including sync byte counts: the
follower list is empty, the hooks are no-ops, and every read delegates
straight to the primary (enforced by tests/test_replica.py).

The read-spreading POLICY (round_robin / least_loaded / primary_only)
lives in the router (core/router.py, ``replica_for_dispatch``); the group
only enforces freshness and executes the batch against the chosen image
through the primary's dispatch machinery (``_device_get``/``_device_scan``
— key packing, pow2 bucket padding, GC epoch pins, value decode).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .api import Routing, decode_wire_stream
from ..analysis import epochsan as _epochsan
from .config import ReplicationConfig, bucket_pow2
from .heap import LOG_DELETE, LOG_INSERT, LOG_UPDATE
from .read_path import NODE_FIELDS, TreeSnapshot, attach_cache_image
from .schema import NodeImageLayout
from .shard import (LogPayload, StagedSync, StoreShard, SyncStats,
                    _DELTA_BACKEND, _jit_apply_delta)
from .telemetry import CLOCK, merge_stats, samples_from

_now = CLOCK            # THE injectable monotonic clock (core/telemetry.py)

# wire op kind -> heap log op code (the decode half of the feed)
_LOG_CODES = {"put": LOG_INSERT, "update": LOG_UPDATE, "delete": LOG_DELETE}

_LOG_BACKEND = _DELTA_BACKEND     # TPU -> compiled Pallas, else jnp oracle


@functools.partial(jax.jit, static_argnames=("offs", "backend"))
def _jit_log_replay(image, rows, slots, entries, offs, backend):
    from repro.kernels import ops as kernel_ops
    return kernel_ops.log_replay_scatter(image, rows, slots, entries,
                                         offs=offs, backend=backend)


# rebuild a follower's VMEM cache tier from its own replayed image (the
# log feed ships no cache rows — replayable epochs preserve the tree
# shape, so the base's cache_lids frontier stays valid and only the row
# CONTENTS must be re-gathered)
_jit_attach_cache = jax.jit(attach_cache_image, static_argnames="cfg")


@dataclasses.dataclass
class FeedStats:
    """Transport meters of one ReplicaGroup's replication feed (summed
    across shards by ``router.aggregate_stats``).  Byte counters meter
    EDGES (one increment per follower delivery); epoch counters meter
    STAGINGS (one increment per ``begin_export`` that fed followers)."""
    feed_bytes: int = 0           # total bytes over all feed edges
    wire_bytes: int = 0           # exact op wire stream bytes shipped
    log_bytes: int = 0            # edge bytes of log-replay deliveries
    fallback_bytes: int = 0       # edge bytes of image deltas shipped on
    #   fallback epochs (log feed only; the fallback-fraction numerator)
    primary_egress_bytes: int = 0  # bytes on primary->child edges — the
    #   feeder bandwidth the relay tree bounds at O(fanout)
    relay_hop_bytes: int = 0      # bytes on relay->child edges
    log_feed_epochs: int = 0      # stagings shipped as a log payload
    log_fallback_epochs: int = 0  # log-feed stagings that had to ship the
    #   image delta (tree shape changed / GC / overflow value)
    delta_feed_epochs: int = 0    # stagings shipped as deltas by choice
    #   (feed="delta", or legacy layout with no packed image to replay into)
    full_feed_epochs: int = 0     # full-publish stagings
    full_catchups: int = 0        # out-of-sync followers refed a full copy
    catchup_bytes: int = 0        # bytes those full catch-ups moved

    def collect(self):
        """Registry samples (core/telemetry.py collect protocol):
        ``replication_*`` counters for every feed-transport meter."""
        return samples_from(self, "replication", "replica")


def _snapshot_nbytes(snap) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(snap))


def _image_feed_cost(snap) -> tuple[int, int]:
    """(DMA invocations, node-image bytes) of device-copying a whole
    snapshot into a follower: the packed layout moves ONE contiguous image
    (core/schema.py); legacy moves one array per field — same bytes."""
    if isinstance(snap, TreeSnapshot):
        return 1, snap.image.nbytes
    return len(NODE_FIELDS), sum(getattr(snap, f).nbytes
                                 for f in NODE_FIELDS)


class FollowerReplica:
    """One follower's device-resident state: its own active/standby snapshot
    buffers, SyncStats, and epoch/read-version watermark.  Fed only by the
    primary's ``StagedSync`` payloads; never written directly."""

    def __init__(self, replica_id: int, in_sync: bool = True, cfg=None):
        self.replica_id = replica_id
        self.cfg = cfg                 # layout schema for cache re-attach
        self.sync_stats = SyncStats()
        self.epoch = 0                 # primary epoch at our last publish
        self.paused = False            # fault injection / maintenance
        # True iff our scatter base equals the primary's scatter base, i.e.
        # we applied every payload since the last full copy — only then may
        # a delta payload be replayed here
        self.in_sync = in_sync
        self.snapshot: TreeSnapshot | None = None
        self.snapshot_rv: int | None = None
        self._standby: TreeSnapshot | None = None
        self._standby_rv: int | None = None
        self.served_ops = 0

    def stage(self, payload: StagedSync) -> tuple[int, bool]:
        """Replay one primary staging into our standby buffer: re-apply the
        delta scatter on our own base when in sync, otherwise device-copy
        the primary's staged standby (full catch-up).  Returns the bytes
        this delivery moved over our feed edge and whether it was full."""
        base = self._standby if self._standby is not None else self.snapshot
        stats = self.sync_stats
        stats.snapshots += 1
        if payload.kind == "delta" and self.in_sync and base is not None:
            # independent device scatter per replica: O(dirty_rows) traffic
            # (one image-row DMA per dirty node on the packed layout — the
            # delta type carries the layout, so the replay is layout-free)
            self._standby = _jit_apply_delta(base, payload.delta,
                                             backend=_DELTA_BACKEND,
                                             cfg=self.cfg)
            stats.delta_syncs += 1
            stats.delta_rows += payload.delta_rows
            stats.bytes_synced += payload.nbytes
            stats.image_dma_count += payload.image_dmas
            stats.image_bytes += payload.image_bytes
            nbytes, was_full = payload.nbytes, False
        else:
            # full feed: first publish, primary full republish, or catch-up
            # after a missed payload (a delta would land on the wrong base)
            self._standby = jax.tree.map(jnp.copy, payload.snapshot)
            stats.full_syncs += 1
            nbytes = (payload.nbytes if payload.kind == "full"
                      else _snapshot_nbytes(payload.snapshot))
            stats.bytes_synced += nbytes
            dmas, ibytes = _image_feed_cost(payload.snapshot)
            stats.image_dma_count += dmas
            stats.image_bytes += ibytes
            self.in_sync = True
            was_full = True
        self._standby_rv = payload.read_version
        san = _epochsan.get()
        if san is not None:
            san.note_staged(self, self._standby)
        return nbytes, was_full

    def stage_log(self, payload: StagedSync, marshalled) -> int:
        """Replay one staging from its LOG payload: scatter the epoch's
        marshalled wire entries into our own standby image with the
        ``log_replay_scatter`` kernel — O(entry words) device traffic, no
        image-row DMAs (the feed's whole point; ``image_dma_count`` and
        ``image_bytes`` do NOT move).  By induction our base image equals
        the primary's scatter base, so the replayed standby is
        bit-identical to the primary's staged standby (tested).  Only
        callable in sync with an existing base; returns edge bytes."""
        lp = payload.log_payload
        base = self._standby if self._standby is not None else self.snapshot
        stats = self.sync_stats
        stats.snapshots += 1
        if marshalled is None:           # forced epoch with zero writes:
            image = base.image           # only the read version advances
        else:
            rows, slots, entries, offs = marshalled
            image = _jit_log_replay(base.image, rows, slots, entries, offs,
                                    _LOG_BACKEND)
        snap = base._replace(
            image=image, read_version=jnp.int32(lp.read_version))
        if self.cfg is not None:
            snap = _jit_attach_cache(snap, cfg=self.cfg)
        self._standby = snap
        self._standby_rv = payload.read_version
        stats.log_replays += 1
        stats.log_entries += lp.entries
        stats.log_wire_bytes += lp.wire_nbytes
        stats.bytes_synced += lp.nbytes
        san = _epochsan.get()
        if san is not None:
            san.note_staged(self, self._standby)
        return lp.nbytes

    def flip(self, primary_epoch: int) -> bool:
        """Publish the staged standby; no-op when nothing is staged (the
        follower keeps lagging and the router keeps skipping it)."""
        if self._standby is None:
            return False
        self.snapshot = self._standby
        self.snapshot_rv = self._standby_rv
        self._standby = None
        self._standby_rv = None
        self.epoch = primary_epoch
        san = _epochsan.get()
        if san is not None:
            san.note_flip(self, self.snapshot)
        return True


class ReplicaGroup:
    """One primary ``StoreShard`` plus N-1 ``FollowerReplica``s behind the
    shard facade.  Writes and host reads hit the primary (attribute
    fallthrough); device read batches can be pinned to any FRESH replica;
    every sync staging/flip feeds the whole group."""

    def __init__(self, primary: StoreShard,
                 replication: ReplicationConfig | None = None):
        self.primary = primary
        self.replication = replication or ReplicationConfig()
        fresh = (primary._snapshot is None and primary._standby is None)
        self.followers = [FollowerReplica(i + 1, in_sync=fresh,
                                          cfg=primary.cfg)
                          for i in range(self.replication.replicas - 1)]
        self.lagging_skips = 0         # batches redirected off a stale follower
        self.replication_s = 0.0       # wall time spent feeding followers
        self.feed_stats = FeedStats()
        # relay tree: follower id -> feeding parent id (0 = primary); ids
        # ascend level by level, so walking followers in order always
        # visits a parent before its children
        self._parents = self.replication.topology.parents(len(self.followers))
        # the log feed needs the packed image (the replay kernel's one
        # destination buffer); the legacy per-field layout keeps the delta
        # feed.  Capture costs the unreplicated store nothing: the flag
        # stays False with no followers.
        self._log_enabled = (self.replication.feed == "log"
                             and bool(self.followers)
                             and primary.cfg.layout == "packed")
        primary.log_capture = self._log_enabled
        self._primary_served = 0       # device requests the primary served
        # read-spreading policy state (the pick lives HERE; the router
        # delegates): round_robin cursor, and least_loaded's pick-time
        # assignment counts so submit-time bursts still spread
        self._rr = 0
        self._assigned = [0] * self.replication.replicas
        # (replica_served, serving_version) of the latest device batch —
        # the stamp the scheduler reads right after each dispatch
        self.last_dispatch: tuple[int, int] = (0, 0)
        primary.on_staged = self._on_primary_staged
        primary.on_flip = self._on_primary_flip
        if not fresh and self.followers and primary._snapshot is not None:
            for f in self.followers:   # late attach: full-copy the active
                f.stage(StagedSync("full", primary._snapshot, None,
                                   _snapshot_nbytes(primary._snapshot), 0,
                                   primary._snapshot_rv))
                f.flip(primary.epoch)
                f.in_sync = primary._standby is None

    def __getattr__(self, name: str):
        # facade fallthrough: anything not replica-specific is the primary's
        # (put/get/scan/deferred_sync/export_snapshot/sync_stats/tree/...)
        if name == "primary" or name.startswith("__"):
            raise AttributeError(name)
        return getattr(self.primary, name)

    @property
    def n_replicas(self) -> int:
        return 1 + len(self.followers)

    # --------------------------------------------------- replication feed
    def _marshal_log_payload(self, lp: LogPayload):
        """Decode the one encoded wire stream and marshal it into the
        dense device block ``log_replay_scatter`` consumes — ONCE per
        staging, shared by every follower lane (each lane still runs its
        own independently enqueued replay).  Entries pad to the shared
        pow2 bucket schedule with idempotent repeats of the last record."""
        if lp.entries == 0:
            return None
        layout = NodeImageLayout.for_config(self.primary.cfg)
        ops = decode_wire_stream(lp.wire)
        blk = layout.pack_log_entries(
            ops, [_LOG_CODES[op.KIND] for op in ops],
            lp.backptrs, lp.hints, lp.vdeltas)
        size = bucket_pow2(lp.entries)
        rows = StoreShard._pad_index(lp.rows, size)
        slots = StoreShard._pad_index(lp.slots, size)
        if size > lp.entries:
            blk = np.concatenate(
                [blk, np.repeat(blk[-1:], size - lp.entries, axis=0)])
        return (jnp.asarray(rows), jnp.asarray(slots), jnp.asarray(blk),
                layout.log_replay_offsets())

    def _on_primary_staged(self, payload: StagedSync) -> None:
        """Feed one staging to the group through the relay tree: encode
        the log payload's device block once, then deliver parent-first —
        a follower whose parent is paused or itself undelivered misses the
        payload (out of sync until a reachable staging full-copies it).
        Every edge's bytes are metered into ``FeedStats`` by edge class."""
        t0 = _now()
        fs = self.feed_stats
        lp = payload.log_payload
        marshalled = None
        if self.followers:
            if payload.kind == "full":
                fs.full_feed_epochs += 1
            elif lp is not None:
                fs.log_feed_epochs += 1
                marshalled = self._marshal_log_payload(lp)
            elif self._log_enabled:
                fs.log_fallback_epochs += 1
            else:
                fs.delta_feed_epochs += 1
        delivered = {0}
        for f in self.followers:
            parent = self._parents.get(f.replica_id, 0)
            if f.paused or parent not in delivered:
                f.in_sync = False      # missed payload: next feed is full
                continue
            can_replay = (lp is not None and f.in_sync
                          and (f._standby is not None
                               or f.snapshot is not None))
            if can_replay:
                nbytes = f.stage_log(payload, marshalled)
                fs.wire_bytes += lp.wire_nbytes
                fs.log_bytes += nbytes
            else:
                nbytes, was_full = f.stage(payload)
                if was_full and payload.kind != "full":
                    fs.full_catchups += 1
                    fs.catchup_bytes += nbytes
                elif self._log_enabled and payload.kind == "delta":
                    fs.fallback_bytes += nbytes
            fs.feed_bytes += nbytes
            if parent == 0:
                fs.primary_egress_bytes += nbytes
            else:
                fs.relay_hop_bytes += nbytes
            delivered.add(f.replica_id)
        self.replication_s += _now() - t0

    def _on_primary_flip(self) -> None:
        """Publish the group: every follower with a staged standby flips to
        the primary's new epoch; paused followers fall behind.  A follower
        that missed an intermediate staging (in_sync False) must NOT
        publish its older standby under the new epoch — its lag meters
        would read caught-up while its content is stale — so it also waits
        for the full catch-up feed."""
        for f in self.followers:
            if not f.paused and f.in_sync:
                f.flip(self.primary.epoch)

    # ------------------------------------------------- fault injection /
    # lag control (tests, maintenance drains)
    def pause_follower(self, replica: int) -> None:
        self.followers[replica - 1].paused = True

    def resume_follower(self, replica: int) -> None:
        self.followers[replica - 1].paused = False

    def resync_follower(self, replica: int) -> None:
        """Immediate full catch-up from the primary's ACTIVE snapshot
        (metered as a full sync); the follower serves again right away."""
        f = self.followers[replica - 1]
        snap = self.primary._snapshot
        if snap is None:
            return
        f.snapshot = jax.tree.map(jnp.copy, snap)
        f.snapshot_rv = self.primary._snapshot_rv
        f._standby = None
        f._standby_rv = None
        f.epoch = self.primary.epoch
        # deltas only resume if the primary has nothing staged mid-air
        # (an unflipped standby is a base we did not copy)
        f.in_sync = self.primary._standby is None
        f.sync_stats.snapshots += 1
        f.sync_stats.full_syncs += 1
        nbytes = _snapshot_nbytes(snap)
        f.sync_stats.bytes_synced += nbytes
        dmas, ibytes = _image_feed_cost(snap)
        f.sync_stats.image_dma_count += dmas
        f.sync_stats.image_bytes += ibytes
        # an admin resync is a primary-direct full catch-up on the feed
        self.feed_stats.full_catchups += 1
        self.feed_stats.catchup_bytes += nbytes
        self.feed_stats.feed_bytes += nbytes
        self.feed_stats.primary_egress_bytes += nbytes

    # ------------------------------------------------- replica dispatch
    def replica_for_dispatch(self) -> int:
        """Read-spreading policy pick for the next read batch —
        ``primary_only`` always serves the primary, ``round_robin`` rotates
        over the currently ELIGIBLE replicas, ``least_loaded`` picks the
        eligible replica with the fewest pick-time assignments.  The pick
        is a ROUTING decision only; dispatch still enforces the freshness
        rule (a lagging follower is skipped, never served stale)."""
        if (self.replication.policy == "primary_only"
                or self.n_replicas == 1):
            return 0
        elig = self.eligible_replicas()        # always contains the primary
        if self.replication.policy == "round_robin":
            r = elig[self._rr % len(elig)]
            self._rr += 1
            return r
        # least_loaded: fewest batches assigned so far (assignment counts
        # move at pick time, so a burst of submit-time picks still spreads)
        r = min(elig, key=self._assigned.__getitem__)
        self._assigned[r] += 1
        return r

    def routing(self) -> Routing:
        """Single-shard replicated wiring for the service (core/api.py):
        shard 0 everywhere, the group's own read-spreading pick, reads
        stamped with the serving replica + its snapshot read version."""
        return Routing(
            shard_of=lambda key: 0,
            replica_of=((lambda shard: self.replica_for_dispatch())
                        if self.n_replicas > 1 else None),
            report=lambda shard: self.last_dispatch,
            live_version=lambda shard: int(
                self.primary.tree.versions.read_version()))

    def eligible_replicas(self) -> list[int]:
        """Replica indices a read batch may be pinned to right now: the
        primary always, plus every follower that is unpaused and whose
        published read version covers the serving version.  The router's
        spreading policies pick over this set so dead/lagging lanes are
        routed around at pick time (the dispatch-time freshness check in
        ``_serving_follower`` still backstops races)."""
        return [0] + [i for i, f in enumerate(self.followers, start=1)
                      if not f.paused and self._covers(f)]

    def _covers(self, f: FollowerReplica) -> bool:
        """Freshness rule: the follower's published read version must cover
        what the group currently serves (the primary's active snapshot read
        version) — otherwise a spread read could observe stale state."""
        need = self.primary._snapshot_rv
        return (f.snapshot is not None and need is not None
                and f.snapshot_rv is not None and f.snapshot_rv >= need)

    def _serving_follower(self, replica: int | None,
                          n: int) -> FollowerReplica | None:
        """Resolve a dispatch to a follower, or None for the primary —
        enforcing the freshness rule (a lagging follower is skipped, the
        batch serves from the primary, and the skip is metered)."""
        if not replica or not self.followers:
            self._primary_served += n
            return None
        if self.primary.cfg.sync_policy != "explicit":
            # lazy-sync policies: freshen the whole group first, exactly as
            # the primary's own read path would (no-op when clean)
            self.primary.export_snapshot()
        f = self.followers[(replica - 1) % len(self.followers)]
        if not self._covers(f):
            self.lagging_skips += 1
            self._primary_served += n
            return None
        f.served_ops += n
        return f

    @property
    def replica_ops(self) -> list[int]:
        """Requests served per replica (primary first) — the least_loaded
        policy's signal and the read-spread imbalance meter."""
        return [self._primary_served] + [f.served_ops for f in self.followers]

    def get_batch(self, keys, replica: int | None = None):
        keys = list(keys)
        if not keys:
            return []
        f = self._serving_follower(replica, len(keys))
        if f is None:
            res = self.primary.get_batch(keys)
            self.last_dispatch = (0, self.primary.serving_version)
            return res
        san = _epochsan.get()
        if san is not None:   # re-derive the freshness rule at dispatch
            san.check_follower_dispatch(self, f)
        res = self.primary._device_get(f.snapshot, keys)
        self.last_dispatch = (f.replica_id,
                              f.snapshot_rv if f.snapshot_rv is not None
                              else 0)
        return res

    def scan_batch(self, ranges, replica: int | None = None):
        ranges = list(ranges)
        if not ranges:
            return []
        f = self._serving_follower(replica, len(ranges))
        if f is None:
            res = self.primary.scan_batch(ranges)
            self.last_dispatch = (0, self.primary.serving_version)
            return res
        san = _epochsan.get()
        if san is not None:   # re-derive the freshness rule at dispatch
            san.check_follower_dispatch(self, f)
        # eligibility pinned the follower at the primary snapshot's read
        # version, so truncated-scan host fallbacks use the primary's rule
        res = self.primary._device_scan(f.snapshot, ranges,
                                        self.primary._fallback_read_version())
        self.last_dispatch = (f.replica_id,
                              f.snapshot_rv if f.snapshot_rv is not None
                              else 0)
        return res

    # ------------------------------------------------------------- meters
    @property
    def replica_lag_epochs(self) -> list[int]:
        """Per-follower epoch lag behind the primary (0 = fully caught up)."""
        return [self.primary.epoch - f.epoch for f in self.followers]

    @property
    def replica_staleness(self) -> list[int]:
        """Per-follower read-version lag behind the primary's published
        snapshot (staleness in read-versions, 0 = serving-fresh)."""
        need = self.primary._snapshot_rv
        if need is None:
            return [0] * len(self.followers)
        return [need - (f.snapshot_rv if f.snapshot_rv is not None else 0)
                for f in self.followers]

    @property
    def replication_stats(self) -> SyncStats:
        """Aggregate follower SyncStats — the replication amplification the
        delta feed generated on top of the primary's own sync traffic."""
        return merge_stats((f.sync_stats for f in self.followers),
                           SyncStats)

    @property
    def replication_bytes(self) -> int:
        return sum(f.sync_stats.bytes_synced for f in self.followers)

    @property
    def per_replica_sync_stats(self) -> list[SyncStats]:
        return ([self.primary.sync_stats]
                + [f.sync_stats for f in self.followers])
