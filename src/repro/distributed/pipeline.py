"""Pipeline parallelism: GPipe-style microbatch schedule over a mesh axis.

For depth-dominated models at >512 chips, a third parallelism axis becomes
necessary (DP x TP saturates).  This wrapper maps *stages* onto an existing
mesh axis: stage s holds layers [s*L/S, (s+1)*L/S); microbatches stream
through a ``collective_permute`` ring, so at steady state every stage
computes a different microbatch (classic GPipe fill/drain bubble of
(S-1)/(M+S-1)).

Expressed as shard_map + lax.fori_loop + ppermute — the jax-native
translation of the send/recv pipelines of Megatron/DeepSpeed.  Stages whose
slot is empty during fill/drain compute masked work (the standard SPMD
formulation; the bubble is wall-clock, not correctness).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(fn: Callable, stage_params, x_micro, *, mesh: Mesh,
                   stage_axis: str):
    """Run ``fn(params_s, x)`` through S pipeline stages.

    fn:           shape-preserving stage function (e.g. a block of layers)
    stage_params: pytree with leading dim S, sharded P(stage_axis) — stage
                  s's parameters live on stage s's shard
    x_micro:      [M, mb, ...] microbatched input (replicated)
    returns       [M, mb, ...] outputs (replicated)
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    S = sizes[stage_axis]
    M = x_micro.shape[0]
    T = M + S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]

    def body(params_local, xs):
        p = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        cur = jnp.zeros_like(xs[0])
        out = jnp.zeros_like(xs)

        def step(t, carry):
            cur, out = carry
            # receive the previous stage's last output (ring permute)
            recv = jax.lax.ppermute(cur, stage_axis, perm)
            m_in = jnp.clip(t, 0, M - 1)
            inp = jnp.where(sid == 0, xs[m_in], recv)
            active = (t >= sid) & (t - sid < M)
            y = fn(p, inp)
            cur = jnp.where(active, y, cur)
            # the last stage emits microbatch (t - sid)
            m_out = jnp.clip(t - sid, 0, M - 1)
            write = active & (sid == S - 1)
            out = out.at[m_out].set(jnp.where(write, y, out[m_out]))
            return cur, out

        _, out = jax.lax.fori_loop(0, T, step, (cur, out))
        # only the last stage holds real outputs; replicate via psum
        out = out * (sid == S - 1)
        return jax.lax.psum(out, stage_axis)

    spec = jax.tree.map(lambda _: P(stage_axis), stage_params)
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec, P()), out_specs=P(),
        check_vma=False,
    )(stage_params, x_micro)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """GPipe fill/drain overhead: (S-1) / (M+S-1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
