"""Gradient compression for the cross-pod (DCN) hop, with error feedback.

At 2+ pods, in-pod reduction rides 50 GB/s ICI while the pod axis crosses
the datacenter network — often <10% of ICI bandwidth.  Compressing only the
pod-axis all-reduce cuts that hop's bytes 4x (int8) to ~50x (top-k) while
error feedback keeps the optimizer unbiased in the long run.

``ef_int8`` / ``ef_topk`` are pure functions usable inside jit; the
``GradCompressor`` carries the error-feedback residual as explicit state
(a params-shaped pytree) so the train step stays functional.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def int8_quantize(x: jax.Array):
    """Symmetric per-tensor int8: (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def topk_sparsify(x: jax.Array, frac: float):
    """Keep the largest-|x| fraction; returns (sparse x, kept mask)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(x) >= thresh
    return jnp.where(mask, x, 0.0), mask


class CompressorState(NamedTuple):
    residual: object     # params-shaped pytree of error-feedback residuals


class GradCompressor:
    """Error-feedback compressor: g' = C(g + r); r <- (g + r) - g'."""

    def __init__(self, mode: str = "int8", topk_frac: float = 0.02):
        assert mode in ("int8", "topk", "none")
        self.mode = mode
        self.topk_frac = topk_frac

    def init(self, params) -> CompressorState:
        return CompressorState(residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def __call__(self, grads, state: CompressorState):
        if self.mode == "none":
            return grads, state

        def comp(g, r):
            x = g.astype(jnp.float32) + r
            if self.mode == "int8":
                q, s = int8_quantize(x)
                out = int8_dequantize(q, s)
            else:
                out, _ = topk_sparsify(x, self.topk_frac)
            return out, x - out

        flat = jax.tree.map(comp, grads, state.residual)
        outs = jax.tree.map(lambda t: t[0], flat,
                            is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
        return outs, CompressorState(residual=res)

    def wire_bytes_per_value(self) -> float:
        """Bytes on the DCN per gradient value (roofline accounting)."""
        return {"int8": 1.0,
                "topk": 8.0 * self.topk_frac,   # value+index pairs
                "none": 4.0}[self.mode]
