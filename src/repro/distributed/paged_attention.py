"""Distributed paged decode attention: shard_map-local page pools.

The baseline decode path gathers KV pages through XLA's global-gather
semantics: with pools sharded over (data x model) and block tables holding
global page ids, GSPMD cannot prove locality, so it all-gathers the pools
(collective-bound) and replicates the attention math on the model axis
(compute/memory waste).  Every decode cell in the baseline roofline table
is collective-dominated because of this.

This module is the beyond-paper optimization (EXPERIMENTS.md §Perf): the
same data-locality insight Honeycomb applies across PCIe — *place the data
so the fast path never crosses the slow link* — applied to ICI.  Pages are
placed in the pool shard that owns the sequence (the serving engine's
allocator is per-host anyway), and the gather + attention run inside a
``shard_map`` where every reference is provably local:

  * batch and pool page-dim shard together on ("pod","data") — a sequence's
    pages live with its lanes; block-table ids are rebased to local rows;
  * the model axis shards KV heads when divisible (q heads follow; zero
    collectives), else head_dim (one [B,KVH,G,S] logits psum per step);
  * the new token's KV scatter happens on the owning shard only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

F32 = jnp.float32
NEG_INF = -1e30


def paged_attention_local(q, k_pages, v_pages, block_tables, seq_lens,
                          start_pos, k_new, v_new, *, mesh: Mesh,
                          batch_axes, kv_head_axis: str | None,
                          head_dim_axis: str | None, page_size: int,
                          scale: float, softcap: float = 0.0):
    """Locality-preserving paged decode attention + KV scatter.

    q:            [B, H, D]
    k/v_pages:    [NP, P, KVH, D] — NP sharded on ``batch_axes`` aligned
                  with B (sequence i's pages live in shard i's rows)
    block_tables: [B, PPS] GLOBAL page ids (engine layout: shard-contiguous)
    seq_lens:     [B] history length (the new token's position)
    k_new/v_new:  [B, KVH, D] this step's KV (scattered locally)
    returns (out [B, H, D] f32, k_pages, v_pages)
    """
    B, H, D = q.shape
    NP = k_pages.shape[0]
    KVH = k_pages.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, D)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data = 1
    for a in batch_axes:
        n_data *= sizes[a]
    np_local = NP // n_data

    kv_spec = P(batch_axes, None, kv_head_axis, head_dim_axis)
    q_spec = P(batch_axes, kv_head_axis, None, head_dim_axis)
    new_spec = P(batch_axes, kv_head_axis, head_dim_axis)
    out_spec = P(batch_axes, kv_head_axis, None, head_dim_axis)

    def body(qg, kp, vp, bt, lens, start, kn, vn):
        # rebase global page ids to this shard's local pool rows
        shard = jnp.int32(0)
        for a in batch_axes:
            shard = shard * sizes[a] + jax.lax.axis_index(a)
        bt_loc = bt - shard * np_local
        rows = jnp.arange(bt.shape[0])
        page = bt_loc[rows, lens // page_size]
        slot = lens % page_size
        kp = kp.at[page, slot].set(kn.astype(kp.dtype))
        vp = vp.at[page, slot].set(vn.astype(vp.dtype))
        new_lens = lens + 1

        k = kp[bt_loc].reshape(bt.shape[0], -1, kp.shape[2], kp.shape[3])
        v = vp[bt_loc].reshape(bt.shape[0], -1, vp.shape[2], vp.shape[3])
        s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(F32) * scale,
                       k.astype(F32))
        if head_dim_axis is not None:
            # contraction dim was sharded: finish the dot before softmax
            s = jax.lax.psum(s, head_dim_axis)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        pos = jnp.arange(k.shape[1])[None, :]
        mask = (pos < new_lens[:, None]) & (pos >= start[:, None])
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(F32))
        return o, kp, vp

    out, kp, vp = shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, P(batch_axes, None),
                  P(batch_axes), P(batch_axes), new_spec, new_spec),
        out_specs=(out_spec, kv_spec, kv_spec),
        check_vma=False,
    )(qg, k_pages, v_pages, block_tables, seq_lens, start_pos, k_new, v_new)
    return out.reshape(B, H, D), kp, vp
