"""Sharding rules: logical axes -> production mesh axes.

Mesh axes (launch/mesh.py): ``data`` (DP/FSDP), ``model`` (TP/EP), and
``pod`` (cross-pod DP) in the multi-pod mesh.

Baseline layout (the dry-run default):
  * weights: 2D-sharded — "embed" on data (FSDP-style; GSPMD inserts the
    all-gathers), "heads"/"kv"/"mlp"/"vocab"/"expert-inner" on model (TP).
  * activations: batch on (pod, data), heads on model.
  * MoE experts: inner dims sharded (2D dense baseline); expert-parallel
    variants (experts on model) are hillclimb options where E % model == 0.
  * decode KV pools: page dim on (pod, data); kv_heads on model when
    divisible, else head_dim on model (GQA kv < 16 replicates heads the
    same way Megatron does).
  * mamba states: heads on model, batch on data when divisible.

Every mapping is divisibility-checked against the actual dims; indivisible
axes fall back to None (replicated) so every (arch x shape x mesh) cell
lowers — imbalances then show up in the roofline table rather than as
compile failures.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig, ShapeConfig
from repro.models import schema as sc


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Tunable knobs — the §Perf hillclimb flips these."""
    expert_parallel: bool = False   # experts on model axis (needs E % model)
    fsdp_embed: bool = True         # "embed" on data axis
    seq_parallel_pages: bool = True  # KV pages on data axis
    decode_impl: str = "gather"     # "gather" (baseline) | "local" (§Perf)


def _div(n: int, size: int) -> bool:
    return n > 0 and n % size == 0


def make_rules(cfg: ArchConfig, mesh: Mesh,
               shape: ShapeConfig | None = None,
               policy: ShardingPolicy = ShardingPolicy()) -> dict:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = axes.get("model", 1)
    data = axes.get("data", 1)
    has_pod = "pod" in axes
    dp = ("pod", "data") if has_pod else ("data",)
    dp_size = axes.get("pod", 1) * data

    batch = shape.global_batch if shape else 0
    rules: dict[str, object] = {
        "layers": None,
        "vocab": "model" if _div(cfg.vocab, model) else None,
        "embed": ("data" if policy.fsdp_embed and _div(cfg.d_model, data)
                  else None),
        "heads": ("model"
                  if _div(cfg.n_heads * cfg.head_dim, model) else None),
        "kv": ("model"
               if _div(cfg.n_kv_heads * cfg.head_dim, model) else None),
        "mlp": "model" if _div(max(cfg.d_ff, cfg.d_inner), model) else None,
        "expert": ("model" if policy.expert_parallel
                   and _div(cfg.n_experts, model) else None),
        # the MoE inner dim: TP normally; unsharded under EP (axis is taken)
        "moe_mlp": (None if (policy.expert_parallel
                             and _div(cfg.n_experts, model))
                    else ("model" if _div(cfg.d_ff, model) else None)),
        # activations / caches
        "batch": dp if _div(batch, dp_size) else (
            "data" if _div(batch, data) else None),
        "kv_pages": dp if policy.seq_parallel_pages else None,
        "kv_heads": "model" if _div(cfg.n_kv_heads, model) else None,
        "head_dim": (None if _div(cfg.n_kv_heads, model)
                     else ("model" if _div(cfg.head_dim, model) else None)),
        # activation constraint axes (with_sharding_constraint targets)
        "seq": None,
        # "heads_act" is used by attention ([B,S,H*hd]) and by mamba
        # ([B,S,H_ssm,P]); only shard when every user's dim divides
        "heads_act": ("model"
                      if ((not cfg.n_heads
                           or _div(cfg.n_heads * cfg.head_dim, model))
                          and (not cfg.ssm_state
                               or _div(cfg.n_ssm_heads, model))
                          and (cfg.n_heads or cfg.ssm_state))
                      else None),
        "kv_act": ("model"
                   if _div(cfg.n_kv_heads * cfg.head_dim, model) else None),
        "mlp_act": ("model"
                    if _div(max(cfg.d_ff, cfg.d_inner), model) else None),
        "vocab_act": "model" if _div(cfg.vocab, model) else None,
        "expert_act": ("model" if policy.expert_parallel
                       and _div(cfg.n_experts, model) else None),
    }
    return rules


def make_shard_fn(mesh: Mesh, rules: dict):
    """Activation-annotation callable threaded through the models."""
    def shard(x, logical_axes):
        spec = P(*[rules.get(a) if a is not None else None
                   for a in logical_axes])
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))
    return shard


def param_shardings(cfg: ArchConfig, mesh: Mesh, rules: dict):
    from repro.models import transformer as tf
    tree = tf.schema(cfg)
    return sc.shardings(tree, rules, mesh)


def named(mesh: Mesh, *axes) -> NamedSharding:
    return NamedSharding(mesh, P(*axes))


def batch_shardings(cfg: ArchConfig, mesh: Mesh, rules: dict,
                    batch_tree) -> dict:
    """Shard every batch input on its leading (batch) dimension."""
    b = rules.get("batch")
    return jax.tree.map(
        lambda x: NamedSharding(mesh, P(b, *([None] * (len(x.shape) - 1)))),
        batch_tree)


def constrain(x, mesh: Mesh, rules: dict, logical_axes: tuple):
    """with_sharding_constraint via logical names (activation annotations)."""
    spec = P(*[rules.get(a) if a else None for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
