"""Deterministic, shard-aware, resumable token pipeline.

Every (data_shard, step) pair maps to a unique deterministic sample, so
  * restarts resume mid-epoch exactly (step index is the only state),
  * elastic re-sharding (a different number of data shards) replays the
    same global batch order,
  * no shard ever reads another shard's bytes (bandwidth isolation).

Two sources: a seeded synthetic stream (benchmarks, smoke tests) and a
memory-mapped token file.  A background prefetch thread keeps ``depth``
batches ready — the host-side analogue of overlapping input DMA with
compute; a slow source therefore shows up as queue starvation (counted)
rather than a stalled step (straggler visibility).
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class TokenSource:
    def batch(self, step: int, shard: int, n_shards: int,
              batch_size: int, seq_len: int) -> dict[str, np.ndarray]:
        raise NotImplementedError


class SyntheticSource(TokenSource):
    """Seeded synthetic tokens — unique per (step, shard).

    Sequences follow a noisy affine recurrence (next = a*cur + c mod V with
    10% noise) so the stream is *learnable*: training-loop tests assert the
    loss actually falls, not just that steps run."""

    def __init__(self, vocab: int, seed: int = 0, noise: float = 0.1):
        self.vocab = vocab
        self.seed = seed
        self.noise = noise

    def batch(self, step, shard, n_shards, batch_size, seq_len):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        toks = np.empty((batch_size, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch_size)
        a, c = 31, 17
        for t in range(seq_len):
            nxt = (toks[:, t] * a + c) % self.vocab
            noise = rng.random(batch_size) < self.noise
            toks[:, t + 1] = np.where(
                noise, rng.integers(0, self.vocab, batch_size), nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class FileSource(TokenSource):
    """Flat int32 token file, memory-mapped; sequential epochs with a
    deterministic per-(step, shard) window."""

    def __init__(self, path: str):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")

    def batch(self, step, shard, n_shards, batch_size, seq_len):
        n = len(self.tokens)
        span = batch_size * (seq_len + 1)
        stride = span * n_shards
        start = (step * stride + shard * span) % max(n - span, 1)
        window = np.asarray(self.tokens[start: start + span])
        window = window.reshape(batch_size, seq_len + 1)
        return {"tokens": window[:, :-1], "labels": window[:, 1:]}


class DataPipeline:
    def __init__(self, source: TokenSource, *, global_batch: int,
                 seq_len: int, shard: int = 0, n_shards: int = 1,
                 start_step: int = 0, depth: int = 2):
        assert global_batch % n_shards == 0
        self.source = source
        self.global_batch = global_batch
        self.local_batch = global_batch // n_shards
        self.seq_len = seq_len
        self.shard = shard
        self.n_shards = n_shards
        self.step = start_step
        self.depth = depth
        self.starvations = 0
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self.step
        while not self._stop.is_set():
            b = self.source.batch(step, self.shard, self.n_shards,
                                  self.local_batch, self.seq_len)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        if self._q.empty():
            self.starvations += 1
        step, b = self._q.get()
        self.step = step + 1
        return b

    def __iter__(self):
        return self

    def seek(self, step: int):
        """Deterministic resume: restart the prefetch thread at ``step``
        (checkpoint restore / elastic reconfiguration)."""
        self._stop.set()
        self._thread.join()
        self._q = queue.Queue(maxsize=self.depth)
        self._stop = threading.Event()
        self.step = step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def close(self):
        self._stop.set()

    def state(self) -> dict:
        return {"step": self.step, "shard": self.shard,
                "n_shards": self.n_shards}
