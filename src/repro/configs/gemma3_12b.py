"""gemma3-12b [dense] — exact assigned config + reduced smoke config."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    pattern="LLLLLG", window=1024, rope_theta=1e6,
    notes="5:1 local:global, 128k context [hf:google/gemma-3].")

SMOKE_CONFIG = ArchConfig(
    arch_id="gemma3-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, pattern="LLLLLG", window=16, rope_theta=1e6)
