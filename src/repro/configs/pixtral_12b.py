"""pixtral-12b [vlm] — exact assigned config + reduced smoke config."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072,
    pattern="G", rope_theta=1e6, embeds_in=True,
    notes="pixtral-ViT frontend is a STUB (input_specs provides patch "
          "embeddings); backbone = mistral-nemo geometry "
          "[hf:mistralai/Pixtral-12B-2409].")

SMOKE_CONFIG = ArchConfig(
    arch_id="pixtral-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, pattern="G", embeds_in=True)
