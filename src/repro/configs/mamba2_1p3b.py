"""mamba2-1.3b [ssm] — exact assigned config + reduced smoke config."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=1, head_dim=0,
    d_ff=0, vocab=50304, raw_vocab=50280,
    pattern="M", ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    notes="SSD (state-space duality), attention-free [arXiv:2405.21060]; "
          "vocab padded 50280->50304 (model-axis multiple).")

SMOKE_CONFIG = ArchConfig(
    arch_id="mamba2-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=0, n_kv_heads=1, head_dim=0,
    d_ff=0, vocab=256, pattern="M", ssm_state=16, ssm_head_dim=16,
    ssm_expand=2)
