"""qwen2.5-3b [dense] — exact assigned config + reduced smoke config."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
    d_ff=11008, vocab=151936,
    pattern="G", qkv_bias=True, rope_theta=1e6,
    notes="GQA kv=2, QKV bias [hf:Qwen/Qwen2.5].")

SMOKE_CONFIG = ArchConfig(
    arch_id="qwen-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, pattern="G", qkv_bias=True)
