"""gemma2-27b [dense] — exact assigned config + reduced smoke config."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000,
    pattern="LG", window=4096, attn_softcap=50.0, final_softcap=30.0,
    notes="local+global alternating, logit softcaps [arXiv:2408.00118].")

SMOKE_CONFIG = ArchConfig(
    arch_id="gemma2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, pattern="LG", window=32, attn_softcap=50.0,
    final_softcap=30.0)
