"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

ARCH_IDS = [
    "mamba2_1p3b", "mixtral_8x22b", "olmoe_1b_7b", "stablelm_3b",
    "gemma2_27b", "gemma3_12b", "qwen2p5_3b", "pixtral_12b",
    "seamless_m4t_medium", "jamba_v0p1_52b",
]

# canonical ids as assigned (hyphens/dots) -> module names
ALIASES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "stablelm-3b": "stablelm_3b",
    "gemma2-27b": "gemma2_27b",
    "gemma3-12b": "gemma3_12b",
    "qwen2.5-3b": "qwen2p5_3b",
    "pixtral-12b": "pixtral_12b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-v0.1-52b": "jamba_v0p1_52b",
}


def get_config(arch: str) -> ArchConfig:
    mod = ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    mod = ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{mod}").SMOKE_CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
