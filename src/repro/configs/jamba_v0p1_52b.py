"""jamba-v0.1-52b [hybrid] — exact assigned config + reduced smoke config."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    pattern="MMMGMMMM", n_experts=16, top_k=2, moe_every=2,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2,
    notes="Mamba+attention 1:7 interleave, MoE 16e top-2 every other layer "
          "[arXiv:2403.19887]; mamba layers use the SSD formulation "
          "(DESIGN.md hardware-adaptation note).")

SMOKE_CONFIG = ArchConfig(
    arch_id="jamba-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, pattern="MMMGMMMM", n_experts=4, top_k=2,
    moe_every=2, ssm_state=16, ssm_head_dim=16)
