"""mixtral-8x22b [moe] — exact assigned config + reduced smoke config."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768,
    pattern="L", window=4096, n_experts=8, top_k=2,
    rope_theta=1e6,
    notes="8 experts top-2, sliding-window attention [arXiv:2401.04088].")

SMOKE_CONFIG = ArchConfig(
    arch_id="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, pattern="L", window=32, n_experts=4, top_k=2)
