"""seamless-m4t-medium [audio] — exact assigned config + reduced smoke config."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=256256, raw_vocab=256206,
    pattern="G", n_enc_layers=12, enc_seq_divisor=8, embeds_in=False,
    notes="encoder-decoder; audio frontend is a STUB (input_specs provides "
          "frame embeddings); vocab padded 256206->256256 "
          "[arXiv:2308.11596].")

SMOKE_CONFIG = ArchConfig(
    arch_id="seamless-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, pattern="G", n_enc_layers=2)
