"""stablelm-3b [dense] — exact assigned config + reduced smoke config."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab=50304,
    pattern="G",
    notes="dense MHA [hf:stabilityai/stablelm].")

SMOKE_CONFIG = ArchConfig(
    arch_id="stablelm-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, pattern="G")
