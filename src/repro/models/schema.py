"""Parameter schema: single source of truth for shapes, dtypes, logical
sharding axes and initializers.

A model declares its parameters once as a (nested) dict of ``ParamDef``;
from that one schema we derive
  * ``init(rng)``            — real parameters (smoke tests, examples)
  * ``abstract()``           — ShapeDtypeStructs (dry-run, no allocation)
  * ``specs(rules, mesh)``   — NamedShardings via logical-axis rules
so shapes and shardings can never drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names, len == ndim
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"                  # normal | zeros | ones | embed

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack(n: int, tree):
    """Prepend a scanned-layers dimension to every ParamDef in a tree."""
    return jax.tree.map(
        lambda d: ParamDef((n, *d.shape), ("layers", *d.axes), d.dtype,
                           d.init),
        tree, is_leaf=lambda x: isinstance(x, ParamDef))


def _is_def(x):
    return isinstance(x, ParamDef)


def init(tree, rng: jax.Array):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_def)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for d, r in zip(leaves, rngs):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, d.dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(r, d.shape, jnp.float32)
                        * scale).astype(d.dtype))
    return jax.tree.unflatten(treedef, out)


def abstract(tree):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
                        tree, is_leaf=_is_def)


def logical_specs(tree):
    """PartitionSpec pytree of *logical* axis names."""
    return jax.tree.map(lambda d: P(*d.axes), tree, is_leaf=_is_def)


def to_mesh_specs(logical_tree, rules: dict[str, str | tuple | None]):
    """Map logical axis names to mesh axis names via rules."""
    def conv(spec: P) -> P:
        out = []
        for ax in spec:
            if ax is None:
                out.append(None)
            else:
                out.append(rules.get(ax))
        return P(*out)
    return jax.tree.map(conv, logical_tree,
                        is_leaf=lambda x: isinstance(x, P))


def shardings(tree, rules, mesh: Mesh):
    mesh_specs = to_mesh_specs(logical_specs(tree), rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), mesh_specs,
                        is_leaf=lambda x: isinstance(x, P))


def n_params(tree) -> int:
    return sum(math.prod(d.shape)
               for d in jax.tree.leaves(tree, is_leaf=_is_def))
