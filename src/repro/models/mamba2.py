"""Mamba2 (SSD — state-space duality) blocks, arXiv:2405.21060.

Train/prefill uses the chunked SSD algorithm: the sequence is split into
chunks; within a chunk the output is the quadratic "attention-like" form,
across chunks a compact recurrent state [H, P, N] is passed (a lax.scan over
chunks).  Decode is the pure recurrence — the state is the "KV page" that
the Honeycomb-indexed serving cache stores per sequence.

Jamba's mamba layers reuse this module with its own (state=16) geometry; the
SSD formulation generalizes the S6 recurrence, noted in DESIGN.md.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .schema import ParamDef
from .layers import rmsnorm, rmsnorm_schema

F32 = jnp.float32


class MambaState(NamedTuple):
    ssm: jax.Array     # [B, H, P, N] recurrent state
    conv: jax.Array    # [B, W-1, conv_dim] causal-conv tail


def mamba_schema(cfg: ArchConfig):
    d = cfg.d_model
    din = cfg.d_inner
    H = cfg.n_ssm_heads
    N = cfg.ssm_state
    G = 1  # B/C groups
    conv_dim = din + 2 * G * N
    # in_proj emits [z, x, B, C, dt]
    d_proj = 2 * din + 2 * G * N + H
    return {
        "in_proj": ParamDef((d, d_proj), ("embed", "mlp")),
        "conv_w": ParamDef((cfg.conv_width, conv_dim), (None, "mlp")),
        "conv_b": ParamDef((conv_dim,), ("mlp",), jnp.float32, "zeros"),
        "A_log": ParamDef((H,), (None,), jnp.float32, "zeros"),
        "D": ParamDef((H,), (None,), jnp.float32, "ones"),
        "dt_bias": ParamDef((H,), (None,), jnp.float32, "zeros"),
        "out_norm": rmsnorm_schema(din)["scale"],
        "out_proj": ParamDef((din, d), ("mlp", "embed")),
    }


def _split_proj(cfg: ArchConfig, zxbcdt):
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(p, xbc, conv_tail=None):
    """Depthwise causal conv, width W.  xbc: [B, S, C]."""
    W = p["conv_w"].shape[0]
    if conv_tail is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_tail.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)           # [B, S+W-1, C]
    out = sum(xp[:, i: i + xbc.shape[1]] * p["conv_w"][i].astype(xbc.dtype)
              for i in range(W))
    out = out + p["conv_b"].astype(xbc.dtype)
    new_tail = xp[:, xp.shape[1] - (W - 1):]
    return jax.nn.silu(out), new_tail


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan (Mamba2 paper, Listing 1 adapted to JAX).

    xh: [B,S,H,P]; dt: [B,S,H] (post-softplus); A: [H] (negative);
    Bm/Cm: [B,S,N] (single group).  Returns y [B,S,H,P] and the final
    state [B,H,P,N].
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    Q = chunk

    dA = dt * A[None, None, :]                        # [B,S,H]
    xdt = xh * dt[..., None]                          # [B,S,H,P]

    r = lambda t: t.reshape(Bsz, nc, Q, *t.shape[2:])
    dA_c, xdt_c = r(dA), r(xdt)
    B_c, C_c = r(Bm), r(Cm)

    cs = jnp.cumsum(dA_c, axis=2)                     # [B,nc,Q,H]
    # intra-chunk ("diagonal block"): L[i,j] = exp(cs_i - cs_j) for i >= j.
    # Mask BEFORE the exp: above the diagonal cs_i - cs_j >= 0 overflows and
    # exp's cotangent would poison gradients through the where.
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # [B,nc,Q,Q,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    L = jnp.exp(seg)
    G = jnp.einsum("bcqn,bckn->bcqk", C_c.astype(F32), B_c.astype(F32))
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", G, L,
                        xdt_c.astype(F32))

    # chunk state contributions: decay from position to chunk end
    decay_out = jnp.exp(cs[:, :, -1:, :] - cs)        # [B,nc,Q,H]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", B_c.astype(F32),
                        decay_out, xdt_c.astype(F32))  # [B,nc,H,P,N]
    chunk_decay = jnp.exp(cs[:, :, -1, :])            # [B,nc,H]

    # inter-chunk recurrence (scan over chunks)
    def step(h, inp):
        st, dec = inp                                  # [B,H,P,N], [B,H]
        h_new = h * dec[:, :, None, None] + st
        return h_new, h                                # emit state BEFORE chunk

    h0 = jnp.zeros((Bsz, H, P, N), F32)
    hT, h_prev = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_prev = h_prev.swapaxes(0, 1)                    # [B,nc,H,P,N]

    # inter-chunk ("off-diagonal"): contribution of the carried-in state
    decay_in = jnp.exp(cs)                            # [B,nc,Q,H]
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", C_c.astype(F32),
                       decay_in, h_prev)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, hT


def _noshard(x, axes):
    return x


def mamba_block(p, x, cfg: ArchConfig, chunk: int = 64,
                return_state: bool = False, shard=_noshard):
    """Full Mamba2 block for train/prefill.  x: [B,S,d] -> [B,S,d].

    With ``return_state`` also returns the MambaState after the last token
    (the prefill -> decode handoff; the state is the serving cache's "page"
    for SSM layers)."""
    B, S, _ = x.shape
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc_conv, conv_tail = _causal_conv(p, xbc)
    xin, Bm, Cm = jnp.split(xbc_conv, [cfg.d_inner, cfg.d_inner + N],
                            axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])
    dt = shard(dt, ("batch", "seq", "heads_act"))
    A = -jnp.exp(p["A_log"])
    xh = shard(xin.reshape(B, S, H, P), ("batch", "seq", "heads_act", None))
    y, hT = _ssd_chunked(xh, dt, A, Bm, Cm, chunk=min(chunk, S))
    y = y + xh.astype(F32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(shard(z, ("batch", "seq", "mlp_act")))
    y = rmsnorm({"scale": p["out_norm"]}, y)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    if return_state:
        return out, MambaState(ssm=hT, conv=conv_tail)
    return out


def mamba_decode(p, x, state: MambaState, cfg: ArchConfig):
    """Single-token recurrence.  x: [B,1,d] -> ([B,1,d], new state)."""
    B = x.shape[0]
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, conv_tail = _causal_conv(p, xbc, state.conv)
    xin, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])   # [B,1,H]
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(B, H, P)
    dA = jnp.exp(dt[:, 0] * A[None, :])                   # [B,H]
    dBx = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, 0].astype(F32),
                     dt[:, 0], xh.astype(F32))
    h = state.ssm * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(F32), h)
    y = y + xh.astype(F32) * p["D"][None, :, None]
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": p["out_norm"]}, y)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, MambaState(ssm=h, conv=conv_tail)


def init_state(cfg: ArchConfig, batch: int) -> MambaState:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return MambaState(
        ssm=jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                       cfg.ssm_state), F32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), F32))
