"""Mixture-of-Experts FFN (mixtral / olmoe / jamba).

Two interchangeable implementations with identical math:

  * ``moe_dense``  — baseline: every expert computes every token, outputs
    weighted by the top-k router probabilities.  Simple, fully shardable by
    pjit (experts 2D-sharded over data x model), but compiles E/k more FLOPs
    than a token actually needs.  This surplus is *visible* in the roofline
    table (MODEL_FLOPS / HLO_FLOPs << 1) and is the target of the §Perf
    hillclimb.
  * ``moe_ragged`` — optimized: tokens sorted by expert, grouped matmuls via
    ``jax.lax.ragged_dot`` compute only routed tokens (FLOP-exact, with
    top-k expansion).  Used under shard_map expert parallelism.

Router: softmax over expert logits, top-k, renormalized — the mixtral
formulation (olmoe normalizes the same way).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .schema import ParamDef

from repro.compat import shard_map

F32 = jnp.float32


def moe_schema(cfg: ArchConfig):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": ParamDef((d, E), ("embed", None), jnp.float32),
        "w_gate": ParamDef((E, d, f), ("expert", "embed", "moe_mlp")),
        "w_up": ParamDef((E, d, f), ("expert", "embed", "moe_mlp")),
        "w_down": ParamDef((E, f, d), ("expert", "moe_mlp", "embed")),
    }


def router_probs(p, x, cfg: ArchConfig):
    """top-k routing -> (weights [B,S,k] f32, indices [B,S,k] i32)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_p, top_i


def _noshard(x, axes):
    return x


def moe_dense(p, x, cfg: ArchConfig, shard=_noshard):
    """All-experts compute, router-weighted combine (baseline)."""
    top_p, top_i = router_probs(p, x, cfg)
    g = shard(jnp.einsum("bsd,edf->besf", x, p["w_gate"]),
              ("batch", "expert_act", "seq", "mlp_act"))
    u = shard(jnp.einsum("bsd,edf->besf", x, p["w_up"]),
              ("batch", "expert_act", "seq", "mlp_act"))
    h = jax.nn.silu(g) * u
    y = shard(jnp.einsum("besf,efd->besd", h, p["w_down"]),
              ("batch", "expert_act", "seq", None))        # [B,E,S,d]
    # combine: sum over the k selected experts
    onehot = jax.nn.one_hot(top_i, cfg.n_experts, dtype=F32)  # [B,S,k,E]
    w = jnp.einsum("bske,bsk->bse", onehot, top_p)            # [B,S,E]
    return jnp.einsum("besd,bse->bsd", y.astype(F32),
                      w).astype(x.dtype)


def moe_ragged(p, x, cfg: ArchConfig):
    """Sorted dispatch + grouped matmul: FLOP-exact top-k MoE."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    top_p, top_i = router_probs(p, x, cfg)
    T = B * S * k
    xt = jnp.repeat(x.reshape(B * S, d), k, axis=0)           # [T, d]
    eid = top_i.reshape(-1)                                   # [T]
    gates = top_p.reshape(-1)

    order = jnp.argsort(eid)                                  # stable
    xs = xt[order]
    group_sizes = jnp.bincount(eid, length=E).astype(jnp.int32)

    gg = jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)
    uu = jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    hh = jax.nn.silu(gg) * uu
    yy = jax.lax.ragged_dot(hh, p["w_down"], group_sizes)     # [T, d]

    inv = jnp.argsort(order)
    y = yy[inv] * gates[:, None].astype(yy.dtype)
    return y.reshape(B, S, k, d).sum(axis=2).astype(x.dtype)


# --- ragged FFN with exact ragged gradients ---------------------------------
# jax.lax.ragged_dot's builtin VJP computes weight gradients densely (one
# [cap, d] x [cap, f] matmul per expert => E_loc x the active flops — measured
# in EXPERIMENTS.md §Perf iteration 3).  This custom VJP keeps every term
# ragged: dX via ragged_dot with transposed weights, dW via ragged_dot_general
# in ragged-CONTRACTING mode (groups over the token dim).

def _ragged_outer(a, b, group_sizes):
    """[m,p], [m,q], groups over m -> [E,p,q] (sum of outer products)."""
    dn = jax.lax.RaggedDotDimensionNumbers(
        dot_dimension_numbers=(((0,), (0,)), ((), ())),
        lhs_ragged_dimensions=[0], rhs_group_dimensions=[])
    return jax.lax.ragged_dot_general(a, b, group_sizes, dn)


@jax.custom_vjp
def _ragged_ffn(xs, wg, wu, wd, group_sizes):
    gg = jax.lax.ragged_dot(xs, wg, group_sizes)
    uu = jax.lax.ragged_dot(xs, wu, group_sizes)
    return jax.lax.ragged_dot(jax.nn.silu(gg) * uu, wd, group_sizes)


def _ragged_ffn_fwd(xs, wg, wu, wd, group_sizes):
    gg = jax.lax.ragged_dot(xs, wg, group_sizes)
    uu = jax.lax.ragged_dot(xs, wu, group_sizes)
    hh = jax.nn.silu(gg) * uu
    yy = jax.lax.ragged_dot(hh, wd, group_sizes)
    return yy, (xs, wg, wu, wd, gg, uu, hh, group_sizes)


def _ragged_ffn_bwd(res, dy):
    import numpy as np
    xs, wg, wu, wd, gg, uu, hh, gs = res
    wgt = jnp.swapaxes(wg, 1, 2)
    wut = jnp.swapaxes(wu, 1, 2)
    wdt = jnp.swapaxes(wd, 1, 2)
    dhh = jax.lax.ragged_dot(dy, wdt, gs)
    dwd = _ragged_outer(hh, dy, gs)
    sg = jax.nn.silu(gg)
    dsilu = jax.nn.sigmoid(gg) * (1 + gg * (1 - jax.nn.sigmoid(gg)))
    dgg = dhh * uu * dsilu
    duu = dhh * sg
    dxs = jax.lax.ragged_dot(dgg, wgt, gs) \
        + jax.lax.ragged_dot(duu, wut, gs)
    dwg = _ragged_outer(xs, dgg, gs)
    dwu = _ragged_outer(xs, duu, gs)
    dgs = np.zeros(gs.shape, jax.dtypes.float0)
    return dxs, dwg, dwu, dwd, dgs


_ragged_ffn.defvjp(_ragged_ffn_fwd, _ragged_ffn_bwd)


def moe_ep_ragged(p, x, cfg: ArchConfig, *, mesh, dp_axes,
                  expert_axis: str = "model"):
    """Expert-parallel ragged MoE under shard_map (the §Perf optimization).

    Experts shard on the model axis (replicated across data); each chip
    sorts ITS tokens by local expert, computes a capacity-bounded ragged
    matmul over only routed tokens (C = T*k*E_loc/E * capacity_factor rows
    — the 1/E_loc * cf of the dense-dispatch flops), and a single psum over
    the expert axis combines each token's top-k partial outputs.  Tokens
    beyond capacity are dropped (standard MoE capacity semantics).
    """
    from jax.sharding import PartitionSpec as P
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_exp_shards = sizes[expert_axis]
    E_loc = E // n_exp_shards
    n_data = 1
    for a in dp_axes:
        n_data *= sizes[a]
    T_loc = (B // n_data) * S
    cap = int(T_loc * k * E_loc / E * cfg.capacity_factor) + 1

    def body(x_loc, router, wg, wu, wd):
        Bl, S_, d_ = x_loc.shape
        T = Bl * S_
        xt = x_loc.reshape(T, d_)
        logits = jnp.einsum("td,de->te", xt.astype(F32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / top_p.sum(-1, keepdims=True)
        eid = top_i.reshape(-1)
        gates = top_p.reshape(-1)
        shard = jax.lax.axis_index(expert_axis)
        eloc = eid - shard * E_loc
        valid = (eloc >= 0) & (eloc < E_loc)
        # sort: local experts ascending, non-local last; take capacity rows
        order = jnp.argsort(jnp.where(valid, eloc, E_loc))
        sel = order[:cap]
        sel_valid = valid[sel]
        es = jnp.where(sel_valid, eloc[sel], E_loc - 1)
        # rows must stay grouped: invalid rows sit at the tail, and we fold
        # them into the last group with zero gates (bounded waste <= cap)
        group_sizes = jnp.bincount(es, length=E_loc).astype(jnp.int32)
        tok = sel // k                       # owning token of each row
        xs = xt[tok]                         # gather ONLY capacity rows
        gs = jnp.where(sel_valid, gates[sel], 0.0)

        yy = _ragged_ffn(xs, wg, wu, wd, group_sizes)
        yy = yy.astype(F32) * gs[:, None]

        # combine: scatter-add straight into [T, d] (duplicate tokens sum)
        out = jnp.zeros((T, d_), F32).at[tok].add(yy)
        out = jax.lax.psum(out, expert_axis)
        return out.reshape(Bl, S_, d_).astype(x_loc.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axes, None, None), P(None, None),
                  P(expert_axis, None, None), P(expert_axis, None, None),
                  P(expert_axis, None, None)),
        out_specs=P(dp_axes, None, None),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def moe_fsliced_ragged(p, x, cfg: ArchConfig, *, mesh, dp_axes,
                       f_axis: str = "model"):
    """f-sliced ragged MoE (§Perf-4's confirmed design): every model shard
    computes its d_ff slice of ALL routed rows.

    Unlike expert-parallel dispatch this needs no E % model divisibility and
    no capacity (total routed rows = T*k exactly — zero token drops): tokens
    sort by expert once per shard, three ragged matmuls run over the local
    f-slice, and one psum over the f axis completes the down-projection.
    FSDP weight gathers happen at the shard_map boundary (in_specs request
    f-sharded weights; the data-axis shards re-assemble d).
    """
    from jax.sharding import PartitionSpec as P
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    def body(x_loc, router, wg, wu, wd):
        Bl, S_, d_ = x_loc.shape
        T = Bl * S_
        xt = x_loc.reshape(T, d_)
        logits = jnp.einsum("td,de->te", xt.astype(F32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / top_p.sum(-1, keepdims=True)
        eid = top_i.reshape(-1)                       # [T*k]
        gates = top_p.reshape(-1)

        order = jnp.argsort(eid)                      # every row computed
        tok = order // k
        xs = xt[tok]
        group_sizes = jnp.bincount(eid, length=E).astype(jnp.int32)

        yy = _ragged_ffn(xs, wg, wu, wd, group_sizes)  # f-slice partials
        # combine in the model dtype: halves the [T*k, d] dispatch buffers
        # AND the f-axis psum bytes (§Perf-4 iter 3); the k-way token sum
        # and the f-slice psum are short reductions, bf16-safe
        yy = yy * gates[order][:, None].astype(yy.dtype)
        out = jnp.zeros((T, d_), yy.dtype).at[tok].add(yy)
        out = jax.lax.psum(out, f_axis)               # complete d_ff sums
        return out.reshape(Bl, S_, d_).astype(x_loc.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axes, None, None), P(None, None),
                  P(None, None, f_axis), P(None, None, f_axis),
                  P(None, f_axis, None)),
        out_specs=P(dp_axes, None, None),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def moe(p, x, cfg: ArchConfig, impl="dense", shard=_noshard):
    if callable(impl):
        return impl(p, x, cfg)
    if impl == "ragged":
        return moe_ragged(p, x, cfg)
    return moe_dense(p, x, cfg, shard=shard)


def moe_flops_per_token(cfg: ArchConfig, active_only: bool = True) -> int:
    """2*d*f*3 matmuls, per selected expert."""
    e = cfg.top_k if active_only else cfg.n_experts
    return 6 * cfg.d_model * cfg.d_ff * e
