"""Shared transformer layers: norms, RoPE, GQA attention (train/prefill and
paged decode), gated MLP.

Everything is a pure function of (params, inputs, cfg); parameter schemas
live next to the forward functions so shapes/axes cannot drift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .schema import ParamDef

F32 = jnp.float32
NEG_INF = -2.3819763e38


# ----------------------------------------------------------------- norms
def rmsnorm_schema(d: int):
    return {"scale": ParamDef((d,), (None,), jnp.float32, "ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


# ------------------------------------------------------------------ rope
def rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    angles = positions[..., None].astype(F32) * freq       # [..., S, half]
    angles = angles[..., None, :]                          # [..., S, 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ------------------------------------------------------------- attention
def attention_schema(cfg: ArchConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": ParamDef((d, h * hd), ("embed", "heads")),
        "wk": ParamDef((d, kv * hd), ("embed", "kv")),
        "wv": ParamDef((d, kv * hd), ("embed", "kv")),
        "wo": ParamDef((h * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamDef((h * hd,), ("heads",), jnp.float32, "zeros")
        s["bk"] = ParamDef((kv * hd,), ("kv",), jnp.float32, "zeros")
        s["bv"] = ParamDef((kv * hd,), ("kv",), jnp.float32, "zeros")
    return s


def _noshard(x, axes):
    return x


def _qkv(p, x, cfg: ArchConfig, positions, shard=_noshard):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = shard(q, ("batch", "seq", "heads_act"))
    k = shard(k, ("batch", "seq", "kv_act"))
    v = shard(v, ("batch", "seq", "kv_act"))
    q = rope(q.reshape(B, S, h, hd), positions, cfg.rope_theta)
    k = rope(k.reshape(B, S, kv, hd), positions, cfg.rope_theta)
    return q, k, v.reshape(B, S, kv, hd)


def _softcap(s, cap: float):
    return jnp.tanh(s / cap) * cap if cap else s


def attention(p, x, cfg: ArchConfig, *, local: bool, positions=None,
              seq_lens=None, shard=_noshard, q_chunk: int = 4096):
    """Causal self-attention for train/prefill.  ``local`` selects the
    sliding-window mask (cfg.window).

    KV heads are repeated to the query head count before the score einsum
    (Megatron-style GQA TP: the head dim shards cleanly on the model axis;
    each chip only materializes its own heads' repeats).  Sequences longer
    than ``q_chunk`` process query blocks through a lax.scan so the live
    score buffer is [B, H, q_chunk, S] instead of [B, H, S, S] — the knob
    that makes 32k prefill feasible.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = _qkv(p, x, cfg, positions, shard)
    g, hd = cfg.q_per_kv, cfg.head_dim
    kr = jnp.repeat(k, g, axis=2)            # [B, S, H, hd]
    vr = jnp.repeat(v, g, axis=2)
    kr = shard(kr, ("batch", "seq", "heads_act", None))
    vr = shard(vr, ("batch", "seq", "heads_act", None))
    scale = hd ** -0.5

    def block(q_blk, pos_blk):
        """q_blk: [B, Q, H, hd]; pos_blk: [B, Q] -> [B, Q, H, hd]."""
        s = jnp.einsum("bqhd,bshd->bhqs", q_blk.astype(F32) * scale,
                       kr.astype(F32))
        s = _softcap(s, cfg.attn_softcap)
        qp = pos_blk[:, None, :, None]
        kp = positions[:, None, None, :]
        mask = kp <= qp
        if local and cfg.window:
            mask &= kp > qp - cfg.window
        if seq_lens is not None:
            mask &= kp < seq_lens[:, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqs,bshd->bqhd", probs.astype(vr.dtype), vr)

    if S <= q_chunk:
        o = block(q, positions)
    else:
        nq = S // q_chunk
        qs = q.reshape(B, nq, q_chunk, cfg.n_heads, hd).swapaxes(0, 1)
        ps = positions.reshape(B, nq, q_chunk).swapaxes(0, 1)
        o = jax.lax.scan(
            lambda _, inp: (None, block(*inp)), None, (qs, ps))[1]
        o = o.swapaxes(0, 1).reshape(B, S, cfg.n_heads, hd)
    o = shard(o.reshape(B, S, cfg.n_heads * hd),
              ("batch", "seq", "heads_act"))
    return jnp.einsum("bsh,hd->bsd", o, p["wo"]), (k, v)


def decode_attention(p, x, cfg: ArchConfig, k_pages, v_pages, block_tables,
                     seq_lens, *, local: bool, page_size: int,
                     backend: str | None = None, shard=_noshard,
                     local_impl=None):
    """Single-token decode over a paged KV cache (scatter-then-attend).

    x: [B, 1, d]; k_pages/v_pages: [NP, P, KVH, HD] (this layer's pool);
    block_tables: [B, PPS] physical page ids (Honeycomb page-table lookups);
    seq_lens: [B] tokens already in cache (the new token's position).

    The new token's KV is scattered into its page slot first, then one paged
    attention pass covers history + self.  Returns
    (out [B, 1, d], (k_pages, v_pages)) with the updated pools.
    """
    from repro.kernels import ops as kops
    B = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = seq_lens[:, None]                    # [B, 1]
    q, k, v = _qkv(p, x, cfg, positions, shard)
    q = q[:, 0]                                      # [B, H, HD]
    k_new, v_new = k[:, 0], v[:, 0]                  # [B, KVH, HD]

    new_lens = seq_lens + 1
    if local and cfg.window:
        start = jnp.maximum(new_lens - cfg.window, 0)
    else:
        start = jnp.zeros_like(new_lens)

    if local_impl is not None:
        # §Perf path: shard_map-local pools (scatter happens inside)
        o, k_pages, v_pages = local_impl(
            q, k_pages, v_pages, block_tables, seq_lens, start,
            k_new, v_new, scale=hd ** -0.5, softcap=cfg.attn_softcap)
    else:
        rows = jnp.arange(B)
        page = block_tables[rows, seq_lens // page_size]
        slot = seq_lens % page_size
        k_pages = k_pages.at[page, slot].set(k_new.astype(k_pages.dtype))
        v_pages = v_pages.at[page, slot].set(v_new.astype(v_pages.dtype))
        o = kops.paged_attention(q, k_pages, v_pages, block_tables,
                                 new_lens, start, backend=backend,
                                 scale=hd ** -0.5,
                                 softcap=cfg.attn_softcap)
    o = o.reshape(B, 1, h * hd).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"]), (k_pages, v_pages)


# ------------------------------------------------------------------- mlp
def mlp_schema(cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDef((d, f), ("embed", "mlp")),
        "w_up": ParamDef((d, f), ("embed", "mlp")),
        "w_down": ParamDef((f, d), ("mlp", "embed")),
    }


def mlp(p, x, shard=_noshard):
    g = shard(jnp.einsum("bsd,df->bsf", x, p["w_gate"]),
              ("batch", "seq", "mlp_act"))
    u = shard(jnp.einsum("bsd,df->bsf", x, p["w_up"]),
              ("batch", "seq", "mlp_act"))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"])


# ------------------------------------------------------- cross attention
def cross_attention_schema(cfg: ArchConfig):
    return attention_schema(cfg)


def cross_attention(p, x, ctx, cfg: ArchConfig, ctx_lens=None,
                    shard=_noshard):
    """Encoder-decoder cross attention (seamless): queries from x, keys and
    values from the encoder output ctx [B, Senc, d]."""
    B, S, _ = x.shape
    Senc = ctx.shape[1]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = shard(jnp.einsum("bsd,dh->bsh", x, p["wq"]),
              ("batch", "seq", "heads_act")).reshape(B, S, h, hd)
    k = jnp.einsum("bsd,dh->bsh", ctx, p["wk"]).reshape(B, Senc, kv, hd)
    v = jnp.einsum("bsd,dh->bsh", ctx, p["wv"]).reshape(B, Senc, kv, hd)
    g = cfg.q_per_kv
    kr = shard(jnp.repeat(k, g, axis=2), ("batch", "seq", "heads_act", None))
    vr = shard(jnp.repeat(v, g, axis=2), ("batch", "seq", "heads_act", None))
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(F32) * hd ** -0.5,
                   kr.astype(F32))
    if ctx_lens is not None:
        mask = jnp.arange(Senc)[None, :] < ctx_lens[:, None]
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshd->bqhd", probs.astype(vr.dtype), vr)
    o = shard(o.reshape(B, S, h * hd), ("batch", "seq", "heads_act"))
    return jnp.einsum("bsh,hd->bsd", o, p["wo"])
