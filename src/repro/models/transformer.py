"""Model assembly for the 10-arch zoo.

Depth is organized as *superblocks*: the layer pattern (cfg.pattern, e.g.
gemma3's "LLLLLG", jamba's "MAMMMMMM"-style 1:7) defines one superblock; the
model is a ``lax.scan`` over ``n_superblocks`` stacked parameter pytrees.
Scan keeps the HLO small (one superblock body regardless of depth) — the
knob that keeps 32 dry-run cells compilable on one CPU core — and remat is
applied at superblock granularity.

Layer kinds: 'G' global attention, 'L' local (sliding-window) attention,
'M' mamba(2) mixer.  FFN per layer: dense MLP, MoE (every cfg.moe_every-th
layer), or none (mamba2's pure-mixer blocks, d_ff == 0).

Decode is paged: attention layers carry per-layer KV page pools indexed by
Honeycomb-managed block tables; mamba layers carry recurrent states (their
"page" analogue).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import layers as ll
from . import mamba2 as mm
from . import moe as me
from .config import ArchConfig
from .schema import ParamDef, stack

F32 = jnp.float32


# ---------------------------------------------------------------- structure
def layer_kinds(cfg: ArchConfig) -> list[tuple[str, str | None]]:
    """[(mixer_kind, ffn_kind)] for one superblock."""
    out = []
    for i, kind in enumerate(cfg.pattern):
        if cfg.d_ff == 0:
            ffn = None
        elif cfg.n_experts and (i % cfg.moe_every == cfg.moe_every - 1
                                or cfg.moe_every == 1):
            ffn = "moe"
        else:
            ffn = "mlp"
        out.append((kind, ffn))
    return out


def _layer_schema(cfg: ArchConfig, kind: str, ffn: str | None):
    s: dict[str, Any] = {"ln1": ll.rmsnorm_schema(cfg.d_model)}
    if kind == "M":
        s["mamba"] = mm.mamba_schema(cfg)
    else:
        s["attn"] = ll.attention_schema(cfg)
    if cfg.n_enc_layers and kind != "M":
        s["ln_x"] = ll.rmsnorm_schema(cfg.d_model)
        s["xattn"] = ll.cross_attention_schema(cfg)
    if ffn is not None:
        s["ln2"] = ll.rmsnorm_schema(cfg.d_model)
        s["ffn"] = me.moe_schema(cfg) if ffn == "moe" else ll.mlp_schema(cfg)
    return s


def superblock_schema(cfg: ArchConfig):
    return {f"l{i}": _layer_schema(cfg, kind, ffn)
            for i, (kind, ffn) in enumerate(layer_kinds(cfg))}


def _encoder_layer_schema(cfg: ArchConfig):
    return {"ln1": ll.rmsnorm_schema(cfg.d_model),
            "attn": ll.attention_schema(cfg),
            "ln2": ll.rmsnorm_schema(cfg.d_model),
            "mlp": ll.mlp_schema(cfg)}


def schema(cfg: ArchConfig):
    d, v = cfg.d_model, cfg.vocab
    s: dict[str, Any] = {
        "embed": ParamDef((v, d), ("vocab", "embed"), jnp.bfloat16, "embed"),
        "blocks": stack(cfg.n_superblocks, superblock_schema(cfg)),
        "final_norm": ll.rmsnorm_schema(d),
        "lm_head": ParamDef((d, v), ("embed", "vocab")),
    }
    if cfg.n_enc_layers:
        s["enc_blocks"] = stack(cfg.n_enc_layers, _encoder_layer_schema(cfg))
        s["enc_norm"] = ll.rmsnorm_schema(d)
    return s


def moe_param_count(cfg: ArchConfig) -> int:
    if not cfg.n_experts:
        return 0
    from .schema import n_params
    per_layer = n_params(me.moe_schema(cfg)) - cfg.d_model * cfg.n_experts
    n_moe_layers = sum(1 for _, f in layer_kinds(cfg)
                       if f == "moe") * cfg.n_superblocks
    return per_layer * n_moe_layers


# ----------------------------------------------------------------- forward
def _apply_layer_train(p, x, cfg: ArchConfig, kind: str, ffn: str | None,
                       enc_out=None, moe_impl: str = "dense",
                       positions=None, shard=ll._noshard):
    h = ll.rmsnorm(p["ln1"], x)
    if kind == "M":
        x = x + mm.mamba_block(p["mamba"], h, cfg, shard=shard)
    else:
        a, _ = ll.attention(p["attn"], h, cfg, local=(kind == "L"),
                            positions=positions, shard=shard)
        x = x + a
    if enc_out is not None and kind != "M":
        h = ll.rmsnorm(p["ln_x"], x)
        x = x + ll.cross_attention(p["xattn"], h, enc_out, cfg, shard=shard)
    if ffn is not None:
        h = ll.rmsnorm(p["ln2"], x)
        f = me.moe(p["ffn"], h, cfg, impl=moe_impl, shard=shard) \
            if ffn == "moe" else ll.mlp(p["ffn"], h, shard=shard)
        x = x + f
    return shard(x, ("batch", "seq", None))


def forward(params, cfg: ArchConfig, tokens=None, embeds=None, enc_out=None,
            moe_impl: str = "dense", remat: bool = True, shard=ll._noshard,
            unroll: bool = False):
    """Train/prefill forward -> logits [B, S, V]."""
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds.astype(params["lm_head"].dtype)
    x = shard(x, ("batch", "seq", None))
    kinds = layer_kinds(cfg)

    def sb(x, blk):
        for i, (kind, ffn) in enumerate(kinds):
            x = _apply_layer_train(blk[f"l{i}"], x, cfg, kind, ffn,
                                   enc_out=enc_out, moe_impl=moe_impl,
                                   shard=shard)
        return x, None

    body = jax.checkpoint(sb) if remat else sb
    x, _ = jax.lax.scan(body, x, params["blocks"],
                        unroll=cfg.n_superblocks if unroll else 1)
    x = ll.rmsnorm(params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(F32)
    logits = shard(logits, ("batch", "seq", "vocab_act"))
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


def encode(params, cfg: ArchConfig, enc_embeds, remat: bool = True,
           shard=ll._noshard, unroll: bool = False):
    """Encoder stack (seamless): bidirectional attention over frames."""
    x = enc_embeds.astype(params["lm_head"].dtype)
    x = shard(x, ("batch", "seq", None))

    def layer(x, p):
        h = ll.rmsnorm(p["ln1"], x)
        # full (non-causal) self-attention via the cross-attn primitive
        a = ll.cross_attention(p["attn"], h, h, cfg, shard=shard)
        x = x + a
        h = ll.rmsnorm(p["ln2"], x)
        return shard(x + ll.mlp(p["mlp"], h, shard=shard),
                     ("batch", "seq", None)), None

    body = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                        unroll=cfg.n_enc_layers if unroll else 1)
    return ll.rmsnorm(params["enc_norm"], x)


def lm_loss(params, cfg: ArchConfig, batch, moe_impl: str = "dense",
            remat: bool = True, shard=ll._noshard, unroll: bool = False):
    """Next-token cross entropy.  batch: {tokens|embeds, labels, [enc_embeds]}.

    CE via (logsumexp - gold logit): avoids materializing a second
    [B, S, V] log-probability array next to the logits."""
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = encode(params, cfg, batch["enc_embeds"], remat=remat,
                         shard=shard, unroll=unroll)
    logits = forward(params, cfg, tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"), enc_out=enc_out,
                     moe_impl=moe_impl, remat=remat, shard=shard,
                     unroll=unroll)
    labels = batch["labels"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(F32)
    return ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def prefill(params, cfg: ArchConfig, tokens=None, embeds=None, enc_out=None,
            page_size: int = 256, moe_impl: str = "dense",
            remat: bool = True, shard=ll._noshard, unroll: bool = False,
            last_pos=None):
    """Prefill: forward over the prompt, returning last-token logits and the
    decode caches (KV paged with identity block tables; mamba states).

    ``last_pos`` ([B] or scalar) selects which position's logits to return
    (page-padded prompts: the real last token, not the pad tail).
    Returns (logits [B, V], DecodeCache).
    """
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds.astype(params["lm_head"].dtype)
    x = shard(x, ("batch", "seq", None))
    B, S, _ = x.shape
    assert S % page_size == 0
    pps = S // page_size
    kinds = layer_kinds(cfg)

    def sb(x, blk):
        caches = {}
        for i, (kind, ffn) in enumerate(kinds):
            p = blk[f"l{i}"]
            h = ll.rmsnorm(p["ln1"], x)
            if kind == "M":
                y, st = mm.mamba_block(p["mamba"], h, cfg, return_state=True,
                                       shard=shard)
                x = x + y
                caches[f"l{i}"] = {"ssm": st.ssm, "conv": st.conv}
            else:
                a, (k, v) = ll.attention(p["attn"], h, cfg,
                                         local=(kind == "L"), shard=shard)
                x = x + a
                kv_shape = (B * pps, page_size, cfg.n_kv_heads, cfg.head_dim)
                caches[f"l{i}"] = {"k_pages": k.reshape(kv_shape),
                                   "v_pages": v.reshape(kv_shape)}
            if enc_out is not None and kind != "M":
                h = ll.rmsnorm(p["ln_x"], x)
                x = x + ll.cross_attention(p["xattn"], h, enc_out, cfg,
                                           shard=shard)
            if ffn is not None:
                h = ll.rmsnorm(p["ln2"], x)
                f = me.moe(p["ffn"], h, cfg, impl=moe_impl, shard=shard) \
                    if ffn == "moe" else ll.mlp(p["ffn"], h, shard=shard)
                x = x + f
        return shard(x, ("batch", "seq", None)), caches

    body = jax.checkpoint(sb) if remat else sb
    x, layer_caches = jax.lax.scan(body, x, params["blocks"],
                                   unroll=cfg.n_superblocks if unroll else 1)
    if last_pos is None:
        xl = x[:, -1:]
    else:
        idx = jnp.broadcast_to(jnp.asarray(last_pos), (B,))
        xl = x[jnp.arange(B), idx][:, None]
    xl = ll.rmsnorm(params["final_norm"], xl)
    logits = jnp.einsum("bsd,dv->bsv", xl, params["lm_head"]).astype(F32)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    block_tables = jnp.arange(B * pps, dtype=jnp.int32).reshape(B, pps)
    seq_lens = jnp.full((B,), S, jnp.int32)
    return logits[:, 0], DecodeCache(layer_caches, block_tables, seq_lens)


# ------------------------------------------------------------------ decode
class DecodeCache(NamedTuple):
    """Scan-stacked per-superblock caches + shared block tables."""
    layers: Any          # pytree: per-layer pools / mamba states
    block_tables: Any    # i32 [B, PPS] — Honeycomb page-table lookups
    seq_lens: Any        # i32 [B]


def layer_cache_schema(cfg: ArchConfig, batch: int, pages_per_seq: int,
                       page_size: int):
    """ParamDef tree for one superblock's caches (stacked by the caller)."""
    n_pages = batch * pages_per_seq
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    # logical axes; per-arch sharding rules decide whether kv_heads or
    # head_dim maps onto the mesh's model axis (divisibility-dependent)
    kv_axes = ("kv_pages", None, "kv_heads", "head_dim")
    out = {}
    for i, (kind, _) in enumerate(layer_kinds(cfg)):
        if kind == "M":
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            out[f"l{i}"] = {
                "ssm": ParamDef((batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                                 cfg.ssm_state), ("batch", "heads", None,
                                                  None), jnp.float32,
                                "zeros"),
                "conv": ParamDef((batch, cfg.conv_width - 1, conv_dim),
                                 ("batch", None, "mlp"), jnp.float32,
                                 "zeros"),
            }
        else:
            out[f"l{i}"] = {
                "k_pages": ParamDef((n_pages, page_size, kv, hd), kv_axes),
                "v_pages": ParamDef((n_pages, page_size, kv, hd), kv_axes),
            }
    return out


def decode_step(params, cfg: ArchConfig, cache: DecodeCache, tokens,
                page_size: int, enc_out=None, attn_backend: str | None = None,
                shard=ll._noshard, unroll: bool = False,
                attn_local_impl=None):
    """One decode token for the whole batch.

    tokens: [B, 1] int32; returns (logits [B, V], new DecodeCache).
    """
    x = shard(params["embed"][tokens], ("batch", "seq", None))
    kinds = layer_kinds(cfg)
    bt, lens = cache.block_tables, cache.seq_lens

    def sb(x, inp):
        blk, lcache = inp
        new_cache = {}
        for i, (kind, _ffn) in enumerate(kinds):
            p = blk[f"l{i}"]
            c = lcache[f"l{i}"]
            h = ll.rmsnorm(p["ln1"], x)
            if kind == "M":
                y, st = mm.mamba_decode(
                    p["mamba"], h, mm.MambaState(c["ssm"], c["conv"]), cfg)
                x = x + y
                new_cache[f"l{i}"] = {"ssm": st.ssm, "conv": st.conv}
            else:
                y, (kp, vp) = ll.decode_attention(
                    p["attn"], h, cfg, c["k_pages"], c["v_pages"], bt, lens,
                    local=(kind == "L"), page_size=page_size,
                    backend=attn_backend, shard=shard,
                    local_impl=attn_local_impl)
                x = x + y
                new_cache[f"l{i}"] = {"k_pages": kp, "v_pages": vp}
            if enc_out is not None and kind != "M":
                h = ll.rmsnorm(p["ln_x"], x)
                x = x + ll.cross_attention(p["xattn"], h, enc_out, cfg,
                                           shard=shard)
            if _ffn is not None:
                h = ll.rmsnorm(p["ln2"], x)
                f = me.moe(p["ffn"], h, cfg) if _ffn == "moe" \
                    else ll.mlp(p["ffn"], h)
                x = x + f
        return x, new_cache

    x, new_layers = jax.lax.scan(sb, x, (params["blocks"], cache.layers),
                                 unroll=cfg.n_superblocks if unroll else 1)
    x = ll.rmsnorm(params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(F32)
    logits = shard(logits, ("batch", "seq", "vocab_act"))
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits[:, 0], DecodeCache(new_layers, bt, lens + 1)
