"""Architecture configuration for the assigned model zoo."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int                    # padded to shardable multiple; see configs
    raw_vocab: int = 0            # the published vocab before padding

    # attention features
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    window: int = 0               # sliding-window size for local layers
    # layer pattern, repeated across depth: 'G' global attn, 'L' local attn,
    # 'M' mamba block.  Must divide n_layers.
    pattern: str = "G"
    attn_softcap: float = 0.0     # gemma2-style logit soft-capping
    final_softcap: float = 0.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1            # MoE MLP every k-th layer (jamba: 2)
    capacity_factor: float = 1.25

    # SSM (mamba2 / jamba mamba layers)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4

    # encoder-decoder (seamless)
    n_enc_layers: int = 0         # 0 => decoder-only
    enc_seq_divisor: int = 8      # encoder frames = seq // divisor

    # modality frontend stub: inputs arrive as embeddings, not token ids
    embeds_in: bool = False

    dtype: str = "bfloat16"
    notes: str = ""

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def block_pattern(self) -> str:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.arch_id}: pattern {self.pattern!r} must divide "
            f"n_layers={self.n_layers}")
        return self.pattern

    @property
    def n_superblocks(self) -> int:
        return self.n_layers // len(self.pattern)

    def param_count(self) -> int:
        """Total parameters (analytic, matches the schema)."""
        from . import transformer
        from .schema import n_params
        return n_params(transformer.schema(self))

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        total = self.param_count()
        if not self.n_experts:
            return total
        from . import transformer
        from .schema import n_params
        moe = transformer.moe_param_count(self)
        return total - moe + int(moe * self.top_k / self.n_experts)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                     # train_4k | prefill_32k | ...
    kind: str                     # train | prefill | decode
    seq_len: int
    global_batch: int
    page_size: int = 256          # KV page granularity (honeycomb-indexed)


LM_SHAPES = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def long_context_ok(cfg: ArchConfig) -> bool:
    """long_500k runs only for sub-quadratic families (DESIGN.md Section 6)."""
    return cfg.family in ("ssm", "hybrid")
